"""Protocol micro-benchmarks: network messages per operation type.

The paper's core claim in microcosm: DRust needs ZERO control messages for
cached reads and exactly one one-sided READ for cold ones; directory
protocols pay multi-hop lookups and invalidation rounds; delegation pays a
round trip for everything.
"""

from __future__ import annotations

from repro.core import Cluster


def _fresh(backend: str):
    cl = Cluster(4, backend=backend)
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    t2 = cl.main_thread(0); t2.server = 2
    box = cl.backend.alloc(t0, 512, b"x" * 512)
    return cl, (t0, t1, t2), box


def _msgs(cl) -> int:
    """Critical-path (synchronous) messages; DRust's invalidation/dealloc
    traffic is asynchronous by design and reported separately."""
    return cl.sim.net.total_msgs() - cl.sim.net.async_msgs


def rows_for(backend: str):
    out = []
    # cold remote read
    cl, (t0, t1, t2), box = _fresh(backend)
    m0 = _msgs(cl)
    cl.backend.read(t1, box)
    out.append((f"proto_{backend}_cold_read_msgs", 0.0, _msgs(cl) - m0))
    # warm (cached) read
    m0 = _msgs(cl)
    cl.backend.read(t1, box)
    out.append((f"proto_{backend}_warm_read_msgs", 0.0, _msgs(cl) - m0))
    # remote write after 2 readers cached it (invalidation pressure)
    cl.backend.read(t2, box)
    m0 = _msgs(cl)
    cl.backend.write(t2, box, b"y" * 512)
    out.append((f"proto_{backend}_write_2sharers_msgs", 0.0, _msgs(cl) - m0))
    # read-after-write from the other server (stale-copy handling)
    m0 = _msgs(cl)
    cl.backend.read(t1, box)
    out.append((f"proto_{backend}_read_after_write_msgs", 0.0,
                _msgs(cl) - m0))
    return out


def all_rows():
    rows = []
    for backend in ("drust", "gam", "grappa"):
        rows += rows_for(backend)
    return rows


if __name__ == "__main__":
    for name, _, n in all_rows():
        print(f"{name}: {n}")
