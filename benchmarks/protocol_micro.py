"""Protocol micro-benchmarks: network messages per operation type.

The paper's core claim in microcosm: DRust needs ZERO control messages for
cached reads and exactly one one-sided READ for cold ones; directory
protocols pay multi-hop lookups and invalidation rounds; delegation pays a
round trip for everything.

The batched I/O plane sweeps measure what doorbell coalescing buys:
round-trips and makespan for TBox group fetches (group size sweep), batched
remote reads (server count sweep), and pipelined write-backs (depth sweep),
each against the equivalent unbatched op sequence with identical final
heap/cache state.

The multi-QP sweeps (``qp_writeback_sweep``/``qp_readmany_sweep``) measure
the out-of-order completion plane: makespan vs QP count at 8 servers, with
round trips held constant — the NIC's per-QP message rate is the serial
bottleneck that striping doorbells across QPs removes.

The coalesce-budget sweep (``coalesce_budget_sweep``) drives the runtime
deref coalescer (``Cluster(coalesce="auto")``) across static quantum
budgets and three request mixes (small / bulk / mixed object sizes) on the
multi-QP plane, and pins that the *adaptive* policy tracks the best static
batch size per mix — large quanta when the per-QP message rate dominates,
knee-bounded quanta when bandwidth does.

The recovery sweep (``recovery_sweep``/``recovery_summary``) crashes one
server under the OOO plane (with a dirty unflushed write and an in-flight
speculative READ to quiesce) at 2/4/8/16 servers x two working-set sizes,
and gates the paper-shaped SLO: the fail-over makespan scales with the
dead server's restored working set, not with cluster size.

The serving sweep (``_serve_run``/``serve_summary``) replays seeded
open-loop arrival traces (Poisson and bursty) against a ``ServeFleet``
of DSM-backed engine replicas at 1/4/8 servers and reports the tail
latency (p50/p99, queueing included) and SLO-met goodput that
``check_regression.py`` gates — serving SLOs, not just protocol counters.

The lock-contention sweep (``_lock_run``/``lock_sweep_summary``) hammers
16 distributed locks under zipf(0.99) skew at 2/8/64 servers in three
synchronization designs (``docs/sync.md``): spin DMutex (remote verbs
per data access while holding the lock), delegation/combining DMutex
(critical sections ship to the lock home; one amortized round trip per
convoy), and DRwLock reader leases (reads free after the grant until a
writer revokes).  Delegation must beat spin on makespan AND round trips
at 8+ servers with the gap widening in cluster size — the scalable-
synchronization acceptance criterion, pinned by the gate.
"""

from __future__ import annotations

import time

from repro.core import Cluster, CoalescePolicy


def _fresh(backend: str):
    cl = Cluster(4, backend=backend)
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    t2 = cl.main_thread(0); t2.server = 2
    box = cl.backend.alloc(t0, 512, b"x" * 512)
    return cl, (t0, t1, t2), box


def _msgs(cl) -> int:
    """Critical-path (synchronous) messages; DRust's invalidation/dealloc
    traffic and pipelined write-backs are asynchronous by design and
    reported separately."""
    return cl.sim.net.critical_path_msgs()


def rows_for(backend: str):
    out = []
    # cold remote read
    cl, (t0, t1, t2), box = _fresh(backend)
    m0 = _msgs(cl)
    cl.backend.read(t1, box)
    out.append((f"proto_{backend}_cold_read_msgs", 0.0, _msgs(cl) - m0))
    # warm (cached) read
    m0 = _msgs(cl)
    cl.backend.read(t1, box)
    out.append((f"proto_{backend}_warm_read_msgs", 0.0, _msgs(cl) - m0))
    # remote write after 2 readers cached it (invalidation pressure)
    cl.backend.read(t2, box)
    m0 = _msgs(cl)
    cl.backend.write(t2, box, b"y" * 512)
    out.append((f"proto_{backend}_write_2sharers_msgs", 0.0, _msgs(cl) - m0))
    # read-after-write from the other server (stale-copy handling)
    m0 = _msgs(cl)
    cl.backend.read(t1, box)
    out.append((f"proto_{backend}_read_after_write_msgs", 0.0,
                _msgs(cl) - m0))
    return out


# --------------------------------------------------------------------------
#  Batched I/O plane sweeps
# --------------------------------------------------------------------------
def group_fetch_sweep(group_sizes=(1, 4, 16, 64)):
    """TBox affinity group of N chunks fetched through the head: the batched
    plane issues ONE coalesced READ (1 doorbell, N verbs); the naive plane
    expands the same group into N independent READ verbs."""
    rows = []
    for n in group_sizes:
        for batch_io in (True, False):
            cl = Cluster(2, batch_io=batch_io)
            t0 = cl.main_thread(0)
            t1 = cl.main_thread(0); t1.server = 1
            prev, head = None, None
            for _ in range(n):
                prev = cl.backend.alloc(t0, 256, b"c" * 256, tie_to=prev)
                head = head or prev
            rt0, t_us0 = cl.sim.net.round_trips, t1.t_us
            cl.backend.read(t1, head)
            mode = "batched" if batch_io else "unbatched"
            rows.append((f"group{n}_fetch_{mode}_rtt", t1.t_us - t_us0,
                         cl.sim.net.round_trips - rt0))
    return rows


def read_many_sweep(n_objects=32, server_counts=(1, 2, 4, 8)):
    """Doorbell-batched reads of objects spread over K source servers:
    round trips collapse to K (one doorbell per server)."""
    rows = []
    for backend in ("drust", "gam", "grappa"):
        for k in server_counts:
            cl = Cluster(k + 1, backend=backend)
            t0 = cl.main_thread(k)           # reader lives on the last server
            boxes = [cl.backend.alloc(t0, 256, b"x" * 256, server=i % k)
                     for i in range(n_objects)]
            rt0, t_us0 = cl.sim.net.round_trips, t0.t_us
            cl.backend.read_many(t0, boxes)
            rows.append((f"readmany_{backend}_{k}srv_rtt", t0.t_us - t_us0,
                         cl.sim.net.round_trips - rt0))
    return rows


def writeback_depth_sweep(depths=(1, 8, 64)):
    """Pipelined DropMutRef write-backs: N remote writes post N async 8-byte
    WRITEs; the critical path pays only the issue cost, round trips stay 0
    until the fence (compare the seed's 1 sync round trip per write)."""
    rows = []
    for d in depths:
        for batch_io in (True, False):
            cl = Cluster(2, batch_io=batch_io)
            t0 = cl.main_thread(0)
            t1 = cl.main_thread(0); t1.server = 1
            boxes = [cl.backend.alloc(t1, 64, i, server=1) for i in range(d)]
            for b in boxes:                  # move every object to server 0
                cl.backend.write(t0, b, 0)   # once: owner home stays t1
            rt0, t_us0 = cl.sim.net.round_trips, t0.t_us
            wb0 = cl.sim.net.async_writebacks
            for i, b in enumerate(boxes):
                cl.backend.write(t0, b, i)   # local write + 8B write-back
            mode = "batched" if batch_io else "unbatched"
            rows.append((f"wb_depth{d}_{mode}_critpath_rtt",
                         t0.t_us - t_us0, cl.sim.net.round_trips - rt0))
            if batch_io:
                rows.append((f"wb_depth{d}_async_posted", cl.makespan_us(),
                             cl.sim.net.async_writebacks - wb0))
    return rows


def _qp_wb_run(qps: int, depth: int, n_servers: int = 8,
               mixed_sizes: bool = False):
    """One multi-QP write-back trace: a writer on server 0 retires mutable
    borrows of ``depth`` objects owned across the other servers; each drop
    posts an async 8 B owner write-back.  ``mixed_sizes`` first posts a
    burst of 16 KiB stack write-backs (D.1) — those backlog the QPs they
    land on, so the small verbs striped onto sibling QPs complete out of
    order.  Ends with an ownership transfer so the completion-id fence path
    runs.  Returns (cluster, writer)."""
    cl = Cluster(n_servers, backend="drust", ooo=True, qps_per_thread=qps)
    t0 = cl.main_thread(0)
    owners = []
    for s in range(1, n_servers):
        th = cl.main_thread(0)
        th.server = s
        owners.append(th)
    boxes = []
    for i in range(depth):
        th = owners[i % len(owners)]
        b = cl.backend.alloc(th, 64, i)          # owner slot lives remotely
        cl.backend.write(t0, b, 0)               # move payload to the writer
        boxes.append(b)
    cl.sim.reset()                               # measure only the wb phase
    for th in owners + [t0]:
        th.t_us = 0.0
    if mixed_sizes:                              # D.1 stack write-back burst
        for j in range(3):
            cl.sim.wb.post(t0, 1 + j % (n_servers - 1), 16384)
    for i, b in enumerate(boxes):
        cl.backend.write(t0, b, i)               # local write + async 8B wb
    cl.drust.transfer(t0, boxes[0], 1)           # fence only boxes[0]'s cids
    return cl, t0


def qp_writeback_sweep(qp_counts=(1, 2, 4), depths=(8, 56), n_servers=8):
    """Multi-QP out-of-order completion plane: with one QP the NIC's per-QP
    message rate serializes the write-back completion tail; striping the
    doorbells across QPs overlaps it.  Round trips stay constant (only the
    trailing transfer is synchronous) — the makespan is what moves."""
    rows = []
    for d in depths:
        for q in qp_counts:
            cl, t0 = _qp_wb_run(q, d, n_servers)
            net = cl.sim.net
            rows.append((f"qp{q}_wbdepth{d}_makespan", cl.makespan_us(),
                         net.round_trips))
            rows.append((f"qp{q}_wbdepth{d}_ooo", 0.0, net.ooo_completions))
            rows.append((f"qp{q}_wbdepth{d}_fenced", 0.0, net.fenced_verbs))
    return rows


def qp_readmany_sweep(qp_counts=(1, 2, 4, 8), n_objects=56, n_servers=8):
    """Sync doorbell path under the out-of-order plane: one batched read of
    ``n_objects`` spread over the other servers.  One QP serializes the
    per-doorbell WQE processing; multiple QPs overlap the doorbells again
    (round trips: one per source server, identical at every QP count)."""
    rows = []
    for q in qp_counts:
        cl = Cluster(n_servers, backend="drust", ooo=True, qps_per_thread=q)
        t0 = cl.main_thread(n_servers - 1)
        boxes = [cl.backend.alloc(t0, 256, b"x" * 256, server=i % (n_servers - 1))
                 for i in range(n_objects)]
        cl.sim.reset()
        t0.t_us = 0.0
        cl.backend.read_many(t0, boxes)
        rows.append((f"qp{q}_readmany_makespan", cl.makespan_us(),
                     cl.sim.net.round_trips))
    return rows


def qp_sweep_summary(qp_counts=(1, 2, 4), depths=(8, 56)) -> dict:
    """Deterministic multi-QP trajectory for ``BENCH_protocol.json`` — every
    value here comes from the virtual clock / message counters, so the
    regression gate can pin them exactly."""
    out = {}
    for d in depths:
        for q in qp_counts:
            cl, _ = _qp_wb_run(q, d, mixed_sizes=True)
            net = cl.sim.net
            out[f"qps{q}_depth{d}"] = {
                "makespan_us": round(cl.makespan_us(), 3),
                "round_trips": net.round_trips,
                "ooo_completions": net.ooo_completions,
                "fences": net.fences,
                "fenced_verbs": net.fenced_verbs,
                "qp_switches": net.qp_switches,
            }
    return out


COALESCE_MIXES = ("small", "bulk", "mixed")
COALESCE_BUDGETS = (1, 4, 16, 64)
EXPOSE_SLO_US = 2.0        # latency-exposure SLO column: flush at 2us pending
EXPOSE_THINK_CYCLES = 1300  # ~0.5us of per-request work between derefs


def _coalesce_run(mix: str, budget, n_objects: int = 96, n_servers: int = 8,
                  qps: int = 4, think_cycles: int = 0):
    """One coalescer trace: a reader on the last server issues plain
    per-object derefs of ``n_objects`` spread over the other servers; the
    runtime registers and flushes them under the given quantum budget
    (``"auto"`` = the adaptive policy, ``"expose"`` = adaptive + the
    ``max_expose_us`` latency-SLO cap).  ``think_cycles`` inserts compute
    between derefs — the exposure SLO only has something to bound when
    virtual time passes inside the quantum.  Returns (cluster, reader)."""
    policy = (CoalescePolicy() if budget == "auto"
              else CoalescePolicy(max_expose_us=EXPOSE_SLO_US)
              if budget == "expose"
              else CoalescePolicy(max_pending=budget))
    cl = Cluster(n_servers, backend="drust", ooo=True, qps_per_thread=qps,
                 coalesce="auto", coalesce_policy=policy)
    t0 = cl.main_thread(n_servers - 1)
    sizes = {
        "small": [256] * n_objects,
        "bulk": [16384] * n_objects,
        "mixed": [256 if i % 2 else 16384 for i in range(n_objects)],
    }[mix]
    boxes = [cl.backend.alloc(t0, sz, bytes(min(sz, 64)),
                              server=i % (n_servers - 1))
             for i, sz in enumerate(sizes)]
    cl.sim.reset()                               # measure only the deref phase
    t0.t_us = 0.0
    for b in boxes:
        cl.backend.read(t0, b)
        if think_cycles:
            cl.sim.compute(t0, think_cycles)
    return cl, t0


def coalesce_budget_sweep():
    """Makespan vs static quantum budget per request mix, plus the adaptive
    policy and the adaptive+latency-SLO column (``expose``: the coalescer
    force-flushes once the oldest registered deref has been pending longer
    than ``EXPOSE_SLO_US``): the ``derived`` column is the round-trip count
    (doorbells), the headline is that ``auto`` lands at the best static
    budget's makespan on every mix — big quanta for small objects,
    knee-bounded for bulk — while ``expose`` trades some of that makespan
    for a bounded deref-latency exposure."""
    rows = []
    for mix in COALESCE_MIXES:
        for budget in COALESCE_BUDGETS + ("auto", "expose"):
            think = EXPOSE_THINK_CYCLES if budget == "expose" else 0
            cl, _ = _coalesce_run(mix, budget, think_cycles=think)
            rows.append((f"coalesce_{mix}_budget{budget}",
                         cl.makespan_us(), cl.sim.net.round_trips))
    return rows


def coalesce_summary() -> dict:
    """Deterministic coalesce-sweep trajectory for ``BENCH_protocol.json``:
    per mix, the adaptive policy's makespan/round-trips/flushes and its
    ratio to the best static budget — the regression gate holds the ratio's
    makespan within tolerance and pins the counters exactly."""
    out = {}
    for mix in COALESCE_MIXES:
        best = None
        for budget in COALESCE_BUDGETS:
            cl, _ = _coalesce_run(mix, budget)
            span = cl.makespan_us()
            best = span if best is None else min(best, span)
        cl, _ = _coalesce_run(mix, "auto")
        span = cl.makespan_us()
        co = cl.drust.coalescer
        out[mix] = {
            "makespan_us": round(span, 3),
            "best_static_us": round(best, 3),
            "auto_over_best": round(span / best, 4),
            "round_trips": cl.sim.net.round_trips,
            "flushes": co.flushes,
            "coalesced_derefs": co.flushed_derefs,
        }
    return out


# --------------------------------------------------------------------------
#  Crash-recovery sweep (fail-over SLO)
# --------------------------------------------------------------------------
def _recovery_run(n_servers: int, n_boxes: int, size: int = 4096):
    """One fail-over trace: server 1 owns ``n_boxes`` objects of ``size``
    bytes (flushed), plus one dirty unflushed write and one in-flight
    speculative READ out of it — so the quiesce, the epoch revert, AND the
    promote-restore paths all run.  Returns (cluster, RecoveryReport)."""
    cl = Cluster(n_servers, backend="drust", replicate=True,
                 qps_per_thread=2, ooo=True)
    t0 = cl.main_thread(0)
    tv = cl.main_thread(0); tv.server = 1
    boxes = [cl.backend.alloc(tv, size, i, server=1) for i in range(n_boxes)]
    cl.replicator.flush_epoch()
    cl.backend.write(tv, boxes[0], -1)           # dirty at crash time
    cl.drust.prefetch(t0, [boxes[1]])            # orphaned speculative READ
    cl.recovery.crash(1)
    rep = cl.recovery.fail_over(1, t0)
    return cl, rep


def recovery_sweep(server_counts=(2, 4, 8, 16), box_counts=(8, 64)):
    """Fail-over makespan vs (cluster size, lost working set): the derived
    column is the restored partition image in bytes — the quantity the
    makespan must track (SLO), while the server-count axis only adds the
    per-survivor restripe handshake."""
    rows = []
    for n in server_counts:
        for nb in box_counts:
            cl, rep = _recovery_run(n, nb)
            rows.append((f"recovery_{n}srv_{nb}boxes_makespan",
                         rep.makespan_us, rep.restored_bytes))
    return rows


def recovery_summary() -> dict:
    """Deterministic recovery trajectory for ``BENCH_protocol.json``: the
    per-point counters are pinned exactly, the makespans within tolerance,
    and the SLO ratio pair — working-set scaling must dominate
    cluster-size scaling — is gated as a boolean."""
    out = {}
    for n in (2, 4, 8, 16):
        for nb in (8, 64):
            cl, rep = _recovery_run(n, nb)
            out[f"srv{n}_boxes{nb}"] = {
                "makespan_us": round(rep.makespan_us, 3),
                "restored_bytes": rep.restored_bytes,
                "rehomed_boxes": rep.rehomed_boxes,
                "orphaned_cids": rep.orphaned_cids,
                "lost_writes": rep.lost_writes,
                "broken_locks": rep.broken_locks,
                "dead_threads": rep.dead_threads,
            }
    return out


def recovery_slo() -> dict:
    """The SLO gate: growing the WORKING SET 8x at fixed cluster must move
    the makespan more than growing the CLUSTER 8x at fixed working set."""
    spans = {}
    for n, nb in ((4, 8), (4, 64), (2, 8), (16, 8)):
        _, rep = _recovery_run(n, nb)
        spans[(n, nb)] = rep.makespan_us
    ws_scale = spans[(4, 64)] / spans[(4, 8)]
    srv_scale = spans[(16, 8)] / spans[(2, 8)]
    return {
        "ws_scale_4srv_8to64_boxes": round(ws_scale, 3),
        "srv_scale_8boxes_2to16_srv": round(srv_scale, 3),
        "slo_ok": bool(ws_scale > srv_scale),
    }


# --------------------------------------------------------------------------
#  Lock-contention sweep (spin vs delegation vs reader leases)
# --------------------------------------------------------------------------
def _lock_run(n_servers: int, mode: str, skew: float = 0.99,
              n_locks: int = 16, ops_per_server: int = 16, reads: int = 2,
              seed: int = 0):
    """One contention run: one worker per server, ``ops_per_server`` ops
    each over ``n_locks`` lock-protected counters (homes striped across
    servers) under zipf(``skew``) lock choice.  ``mode="spin"`` /
    ``"delegate"`` run identical critical sections (bump the counter,
    ``reads`` data accesses on the lock home) through ``DMutex``;
    ``mode="lease"`` runs a 90/10 read/write mix through ``DRwLock``.
    Returns ``(cluster, primitives)`` — final counter values must be
    identical across DMutex modes (the equivalence oracle)."""
    from repro.apps.common import zipf_keys
    from repro.core import DMutex, DRwLock

    cl = Cluster(n_servers, backend="drust")
    boot = cl.main_thread(0)
    if mode == "lease":
        prims = [DRwLock(cl, boot, value=0, server=i % n_servers)
                 for i in range(n_locks)]
    else:
        prims = [DMutex(cl, boot, value=0, mode=mode, server=i % n_servers)
                 for i in range(n_locks)]
    boot.t_us = 0.0
    for s in cl.sim.servers:
        s.cpu_busy_us = 0.0
    ths = []
    for s in range(n_servers):
        th = cl.main_thread(0)
        th.server = s
        ths.append(th)
    n_ops = n_servers * ops_per_server
    hot = zipf_keys(n_ops, n_locks, alpha=skew, seed=seed)

    def bump(o):
        o.data += 1
        return o.data

    for i in range(n_ops):
        th = ths[i % n_servers]
        lk = prims[int(hot[i])]
        if mode == "lease":
            if i % 10 == 7:                      # 10% writers
                lk.write(th, i)
            else:
                with lk.read(th):
                    pass
        else:
            lk.with_lock(th, bump, reads=reads, read_bytes=256)
    return cl, prims


def lock_sweep_summary(server_counts=(2, 8, 64)) -> dict:
    """Deterministic contention trajectory for ``BENCH_protocol.json``:
    makespan within tolerance, the synchronization counters pinned
    exactly.  The ``spin_over_delegate`` ratio in each delegate row is
    the acceptance criterion made visible (must exceed 1.0 at 8+ servers,
    widening with cluster size); it is derived, not gated."""
    out: dict = {}
    for n in server_counts:
        for mode in ("spin", "delegate", "lease"):
            cl, _prims = _lock_run(n, mode)
            net = cl.sim.net
            row = {
                "makespan_us": round(cl.makespan_us(), 2),
                "round_trips": net.round_trips,
                "atomics": net.atomics,
            }
            if mode == "delegate":
                row.update(
                    delegated_sections=net.delegated_sections,
                    convoy_completions=net.convoy_completions,
                    closure_ships=net.closure_ships,
                    spin_over_delegate=round(
                        out[f"spin_{n}srv"]["makespan_us"]
                        / max(1e-9, cl.makespan_us()), 2))
            elif mode == "lease":
                row.update(lease_grants=net.lease_grants,
                           lease_revokes=net.lease_revokes)
            out[f"{mode}_{n}srv"] = row
    return out


# --------------------------------------------------------------------------
#  Placement sweep (static layouts vs telemetry-driven live migration)
# --------------------------------------------------------------------------
def _placement_run(n_servers: int, mode: str, app: str):
    """One zipf-skewed phase-rotating run (``apps.common.run_skewed_phases``)
    of a skewed app under one placement mode.  ``spread``/``packed`` are
    static layouts on the byte-identical default plane; ``auto`` installs
    the telemetry tracker (``core/runtime.PlacementTracker``) and lets hot
    objects migrate to their phase-dominant reader.  Returns the
    ``AppResult`` — the payload digest folds every value read in schedule
    order, so all three modes must produce the same digest."""
    from repro.apps.dataframe import run_dataframe
    from repro.apps.socialnet import run_socialnet

    if app == "socialnet":
        return run_socialnet(n_servers, "drust", n_requests=1200,
                             placement=mode, skew=0.99)
    return run_dataframe(n_servers, "drust", n_ops=38,
                         placement=mode, skew=0.99)


PLACEMENT_SIZES = (2, 8, 16, 64)
PLACEMENT_GATED_SIZES = (8, 16, 64)    # auto must strictly win here


def placement_summary(server_counts=PLACEMENT_SIZES) -> dict:
    """Deterministic placement trajectory for ``BENCH_protocol.json``:
    makespan within tolerance, the placement counters (round trips, owner
    migrations, migration round trips, quantum merges) pinned exactly.
    Each ``auto`` row carries the best static layout's makespan/round
    trips and the ``auto_beats_static`` acceptance bool (strict win on
    BOTH at 8+ servers, with identical digests) that the gate must not
    see flip to false."""
    out: dict = {}
    for app in ("socialnet", "dataframe"):
        for n in server_counts:
            static = {}
            for mode in ("spread", "packed", "auto"):
                res = _placement_run(n, mode, app)
                net = res.net
                digest = res.extra.get("payload_digest",
                                       res.extra.get("result_digest"))
                row = {
                    "makespan_us": round(res.makespan_us, 2),
                    "round_trips": net["round_trips"],
                    "owner_migrations": net["owner_migrations"],
                    "migration_round_trips": net["migration_round_trips"],
                    "quantum_merges": net["quantum_merges"],
                    "digest": digest,
                }
                if mode == "auto":
                    best_span = min(v["makespan_us"] for v in static.values())
                    best_rts = min(v["round_trips"] for v in static.values())
                    row.update(
                        best_static_makespan_us=best_span,
                        best_static_round_trips=best_rts,
                        auto_beats_static=bool(
                            n not in PLACEMENT_GATED_SIZES
                            or (res.makespan_us < best_span
                                and net["round_trips"] < best_rts
                                and all(v["digest"] == digest
                                        for v in static.values()))))
                else:
                    static[mode] = row
                out[f"{app}_{mode}_{n}srv"] = row
    return out


# --------------------------------------------------------------------------
#  Serving SLO sweep (open-loop tail latency + goodput)
# --------------------------------------------------------------------------
SERVE_SLO_US = 5000.0        # per-request latency SLO (arrival -> last token)
SERVE_DECODE_CYCLES = 390_000.0          # ~150 us/decode tick at 2.6 GHz


def _serve_run(n_servers: int, trace: str = "poisson",
               n_requests: int = 72, rate_per_s: float = 2500.0,
               seed: int = 11, wire: str = "int8",
               weight_push_every: int = 8):
    """One open-loop serving trace: a ``ServeFleet`` (one engine replica
    per server, shared DSM page table) replayed against a seeded arrival
    trace.  The decode function is a deterministic stub — the trajectory
    measured here is purely the protocol + queueing behavior on virtual
    clocks, so the SLO columns are byte-reproducible.  Weight pushes every
    ``weight_push_every`` steps bump the published color, forcing real
    int8 wire refreshes mid-load.  Returns (cluster, fleet, driver)."""
    import numpy as np

    from repro.core.jaxstate import OwnedState
    from repro.serve import (OpenLoopDriver, ServeFleet, bursty_trace,
                             poisson_trace, synth_prompts)

    cl = Cluster(n_servers, backend="drust", ooo=True, qps_per_thread=2)
    weights = OwnedState("bench_w", {"w": np.ones((128, 128), np.float32)})

    def stub_step(params, cache, tokens):
        return (tokens * 13 + 7) % 997, cache

    fleet = ServeFleet(cl, step_fn=stub_step, page_size=8, slots=4,
                       max_len=64, weights=weights, wire=wire,
                       weights_server=0,
                       decode_cycles=SERVE_DECODE_CYCLES)
    prompts = synth_prompts(n_requests, seed=seed)
    mk = poisson_trace if trace == "poisson" else bursty_trace
    arrivals = mk(rate_per_s, n_requests, seed=seed + 1)
    drv = OpenLoopDriver(fleet, arrivals, prompts, max_new=8,
                         weight_push_every=weight_push_every)
    drv.run()
    return cl, fleet, drv


SERVE_POINTS = (("poisson_1srv", 1, "poisson"),
                ("poisson_4srv", 4, "poisson"),
                ("poisson_8srv", 8, "poisson"),
                ("bursty_4srv", 4, "bursty"))


def serve_slo_sweep():
    """Row view (CSV) of the serving sweep: p99 in the time column, SLO-met
    goodput in the derived column."""
    rows = []
    for name, n, trace in SERVE_POINTS:
        _, _, drv = _serve_run(n, trace)
        r = drv.result(SERVE_SLO_US)
        rows.append((f"serve_{name}_p99", r.p99_us,
                     round(r.goodput_tok_s, 1)))
    return rows


def serve_summary() -> dict:
    """Deterministic serving trajectory for ``BENCH_protocol.json``: tail
    latency (p50/p99, higher is worse) and goodput (SLO-met tokens per
    virtual second, LOWER is worse) within tolerance, plus the protocol
    counters (round trips, KV hit/miss, int8 wire bytes, weight
    refreshes) pinned exactly — everything runs on virtual clocks over
    seeded traces, so any drift is a behavior change."""
    out = {}
    for name, n, trace in SERVE_POINTS:
        cl, fleet, drv = _serve_run(n, trace)
        r = drv.result(SERVE_SLO_US)
        st = fleet.stats()
        out[name] = {
            "p50_us": r.p50_us,
            "p99_us": r.p99_us,
            "goodput_tok_s": r.goodput_tok_s,
            "completed": r.completed,
            "slo_met": r.slo_met,
            "steps": st["steps"],
            "round_trips": cl.sim.net.round_trips,
            "kv_hits": st["kv"]["hits"],
            "kv_misses": st["kv"]["misses"],
            "wire_bytes": st["wire_bytes"],
            "weight_refreshes": st["weight_refreshes"],
        }
    return out


def clone_fastpath_guard(n_elems: int = 4096, reps: int = 30):
    """Microbenchmark guard for ``ownership._clone``: flat scalar containers
    must take the shallow fast path, not ``deepcopy``.  ``derived`` is the
    speedup of ``_clone`` over ``copy.deepcopy`` — regressions show up as a
    ratio near (or below) 1."""
    import copy
    from repro.core.ownership import _clone

    payloads = {
        "list": list(range(n_elems)),
        "dict": {i: float(i) for i in range(n_elems)},
    }
    rows = []
    for kind, data in payloads.items():
        t0 = time.perf_counter()
        for _ in range(reps):
            _clone(data)
        fast = time.perf_counter() - t0
        t0 = time.perf_counter()
        for _ in range(reps):
            copy.deepcopy(data)
        deep = time.perf_counter() - t0
        rows.append((f"clone_{kind}_fastpath_speedup", fast / reps * 1e6,
                     round(deep / max(fast, 1e-9), 1)))
    return rows


def sanitize_overhead_summary(n_requests: int = 80, n_ops: int = 400) -> dict:
    """Wall-clock cost of the runtime borrow/cid sanitizer
    (``Cluster(sanitize=True)``, ``docs/analysis.md``) on two app kernels.

    Diagnostics only — **never gated**: wall-clock varies across runners,
    and the *simulated* trajectory is identical by construction (the
    sanitizer observes guard/verb events, it charges no cost).  The
    ``span_identical`` bools are the interesting part: they assert the
    observation-only contract on every refresh of ``BENCH_protocol.json``.
    """
    import os

    from repro.analysis.sanitizer import Sanitizer
    from repro.apps.kvstore import run_kvstore
    from repro.apps.socialnet import run_socialnet

    out: dict = {}
    prev = os.environ.get("REPRO_SANITIZE")
    try:
        for name, fn, kw in (
            ("socialnet", run_socialnet, dict(n_requests=n_requests)),
            ("kvstore", run_kvstore,
             dict(n_keys=256, n_ops=n_ops, txn_frac=0.3)),
        ):
            runs = {}
            for mode in ("off", "on"):
                os.environ["REPRO_SANITIZE"] = "1" if mode == "on" else "0"
                t0 = time.perf_counter()
                r = fn(4, "drust", **kw)
                wall = time.perf_counter() - t0
                runs[mode] = (wall, r.makespan_us)
            out[name] = {
                "wall_ms_off": round(runs["off"][0] * 1e3, 1),
                "wall_ms_on": round(runs["on"][0] * 1e3, 1),
                "overhead_x": round(
                    runs["on"][0] / max(runs["off"][0], 1e-9), 2),
                "trace_events": len(Sanitizer.last.trace),
                "span_identical": runs["off"][1] == runs["on"][1],
            }
    finally:
        if prev is None:
            os.environ.pop("REPRO_SANITIZE", None)
        else:
            os.environ["REPRO_SANITIZE"] = prev
    return out


def all_rows():
    rows = []
    for backend in ("drust", "gam", "grappa"):
        rows += rows_for(backend)
    rows += group_fetch_sweep()
    rows += read_many_sweep()
    rows += writeback_depth_sweep()
    rows += qp_writeback_sweep()
    rows += qp_readmany_sweep()
    rows += coalesce_budget_sweep()
    rows += recovery_sweep()
    rows += serve_slo_sweep()
    rows += clone_fastpath_guard()
    return rows


if __name__ == "__main__":
    for name, us, n in all_rows():
        print(f"{name}: {n}  ({us:.2f} us)")
