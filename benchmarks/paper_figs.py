"""Benchmarks reproducing the paper's tables/figures (DRust, ATC'24).

Each function returns rows of (name, us_per_call, derived) where ``derived``
is the figure's reported quantity (normalized throughput, overhead %, ...).
"""

from __future__ import annotations

import time

from repro.apps import APPS
from repro.apps.dataframe import plain_dataframe_us, run_dataframe
from repro.apps.gemm import plain_gemm_us
from repro.apps.kvstore import plain_kvstore_us
from repro.apps.socialnet import plain_socialnet_us, run_socialnet
from repro.core import CostModel, Cluster

PLAIN = {
    "gemm": plain_gemm_us,
    "dataframe": plain_dataframe_us,
    "kvstore": plain_kvstore_us,
    "socialnet": plain_socialnet_us,
}
BACKENDS = ("drust", "gam", "grappa")
NODES = (1, 2, 4, 8)

# Paper Fig. 5 values at 8 nodes (normalized throughput), for the comparison
# column in EXPERIMENTS.md.
PAPER_8N = {
    ("gemm", "drust"): 5.93, ("gemm", "gam"): 3.82, ("gemm", "grappa"): 2.02,
    ("dataframe", "drust"): 5.57, ("dataframe", "gam"): 2.18,
    ("dataframe", "grappa"): 1.69,
    ("kvstore", "drust"): 3.34, ("kvstore", "gam"): 2.50,
    ("socialnet", "drust"): 3.51, ("socialnet", "gam"): 1.33,
    ("socialnet", "grappa"): 1.39,
}


def fig5_scaling(nodes=NODES, backends=BACKENDS):
    """Fig. 5: strong scaling of 4 apps × 3 DSM systems, normalized to the
    original single-node program."""
    rows = []
    for app, fn in APPS.items():
        plain = PLAIN[app]()
        for backend in backends:
            for n in nodes:
                r = fn(n, backend=backend)
                rows.append((f"fig5_{app}_{backend}_{n}n", r.makespan_us,
                             round(plain / r.makespan_us, 3)))
    for n in nodes:                      # Fig. 5b extra baseline
        r = run_socialnet(n, backend="drust", by_value=True)
        rows.append((f"fig5_socialnet_original_{n}n", r.makespan_us,
                     round(PLAIN["socialnet"]() / r.makespan_us, 3)))
    return rows


def fig6_affinity():
    """Fig. 6: TBox / spawn_to ablation on DataFrame, 8 nodes.  Pinned to
    the manual plane so the figure isolates the affinity annotations (the
    runtime coalescer has its own sweep)."""
    base = run_dataframe(8, "drust", coalesce="manual").makespan_us
    tb = run_dataframe(8, "drust", use_tbox=True,
                       coalesce="manual").makespan_us
    both = run_dataframe(8, "drust", use_tbox=True, use_spawn_to=True,
                         coalesce="manual").makespan_us
    return [
        ("fig6_dataframe_base", base, 1.0),
        ("fig6_dataframe_tbox", tb, round(base / tb, 3)),
        ("fig6_dataframe_tbox_spawnto", both, round(base / both, 3)),
    ]


def fig7_coherence_cost():
    """Fig. 7: fixed total resources (16 cores) — 1 node vs 8 nodes.
    ``derived`` is the slowdown (%) of the 8-node split; the paper reports
    4-32% for DRust and 10-98% for the baselines."""
    rows = []
    for app, fn in APPS.items():
        if app == "socialnet":           # omitted in the paper's Fig. 7 too
            continue
        for backend in BACKENDS:
            one = fn(1, backend=backend, workers_per_server=16, cores=16)
            eight = fn(8, backend=backend, workers_per_server=2, cores=2)
            slow = (eight.makespan_us - one.makespan_us) / eight.makespan_us
            rows.append((f"fig7_{app}_{backend}", eight.makespan_us,
                         round(100 * slow, 1)))
    return rows


def table2_deref_latency():
    """Table 2: pointer-deref cost — DRust's check adds ~31 cycles."""
    cost = CostModel()
    plain_cycles = cost.local_access_us * cost.ghz * 1e3
    drust_cycles = (cost.local_access_us + cost.deref_check_us) * cost.ghz * 1e3
    # Wall-clock of the actual protocol fast path (hashmap hit), for context.
    cl = Cluster(2, backend="drust")
    th0 = cl.main_thread(0)
    th1 = cl.main_thread(0); th1.server = 1
    box = cl.backend.alloc(th0, 64, b"x" * 64)
    cl.backend.read(th1, box)                 # warm the cache
    t0 = time.perf_counter()
    n = 2000
    for _ in range(n):
        cl.backend.read(th1, box)
    wall_us = (time.perf_counter() - t0) / n * 1e6
    return [
        ("table2_deref_rust_cycles", 0.0, round(plain_cycles)),
        ("table2_deref_drust_cycles", 0.0, round(drust_cycles)),
        ("table2_deref_fastpath_wall", wall_us, round(drust_cycles)),
    ]


def sec3_breakdown():
    """§3: GAM uncached 512 B read — total vs pure-network time."""
    from repro.core.baselines import GamBackend
    cost = CostModel()
    total = GamBackend.COLD_READ_BASE_US + cost.xfer_us(512)
    network = cost.one_sided_base_us + cost.xfer_us(512)
    coherence_pct = 100 * (total - network) / total
    return [
        ("sec3_gam_read_512B_total", total, round(coherence_pct, 1)),
        ("sec3_net_read_512B", network, 0.0),
    ]


def batch_plane_sweep(n_servers: int = 8):
    """Batched I/O plane ablation (this repo's addition, not a paper figure):
    socialnet/dataframe with the doorbell-coalesced plane on vs the *naive*
    per-object-verb plane (``batch_io=False``: one READ verb per group
    member, synchronous write-backs, per-request sends — NOT the seed's
    cost model, which already coalesced group fetches).  ``derived`` is the
    naive/batched round-trip ratio (the acceptance target is >= 2x on these
    TBox-heavy apps); makespan rows carry the virtual wall clock."""
    rows = []
    for app, fn, kw in (("socialnet", run_socialnet, {}),
                        ("dataframe", run_dataframe, {"use_tbox": True})):
        on = fn(n_servers, "drust", batch_io=True, coalesce="manual",
                **kw)
        off = fn(n_servers, "drust", batch_io=False, coalesce="manual",
                 **kw)
        ratio = off.net["round_trips"] / max(1, on.net["round_trips"])
        rows.append((f"batchio_{app}_rtt_batched", on.makespan_us,
                     on.net["round_trips"]))
        rows.append((f"batchio_{app}_rtt_unbatched", off.makespan_us,
                     off.net["round_trips"]))
        rows.append((f"batchio_{app}_rtt_ratio", 0.0, round(ratio, 2)))
        rows.append((f"batchio_{app}_bytes_batched", 0.0,
                     on.net["bytes_moved"]))
        rows.append((f"batchio_{app}_bytes_unbatched", 0.0,
                     off.net["bytes_moved"]))
    return rows


def qp_depth_sweep(qp_counts=(1, 2, 4, 8), depths=(16, 64, 224),
                   n_servers: int = 8):
    """QP-count × write-back-depth sweep on the out-of-order completion
    plane (this repo's addition): ``derived`` is the makespan speedup over
    the single-QP plane at the same depth — the NIC's per-QP message rate
    is the serial bottleneck multi-QP striping removes.  Round trips are
    identical at every QP count (asserted by the test suite)."""
    from benchmarks.protocol_micro import _qp_wb_run
    rows = []
    for d in depths:
        base = None
        for q in qp_counts:
            cl, _ = _qp_wb_run(q, d, n_servers)
            span = cl.makespan_us()
            if base is None:
                base = span
            rows.append((f"qpsweep_depth{d}_qps{q}", span,
                         round(base / span, 3)))
    return rows


def link_congestion_fairness(n_servers: int = 4):
    """All three backends under the same shared-link congestion model:
    ``derived`` is the narrow-link (4 Gbps) / wide-link (40 Gbps) makespan
    ratio on the dataframe trace, with the completion model (``ooo=True``,
    2 QPs) held fixed on *both* legs so only the link width varies — the
    fairness check that DRust's QP-sweep wins are not an artifact of
    charging congestion to the baselines only.  (At the default 40 Gbps
    none of these traces saturates a link; the narrow link makes the
    capacity floor visible.)"""
    rows = []
    narrow = CostModel(link_bw_bytes_per_us=500.0)
    kw = dict(n_columns=4, chunks_per_column=8, n_ops=4,
              ooo=True, qps_per_thread=2)
    for backend in BACKENDS:
        plain = run_dataframe(n_servers, backend, **kw).makespan_us
        congested = run_dataframe(n_servers, backend, cost=narrow,
                                  **kw).makespan_us
        rows.append((f"linkcong_dataframe_{backend}", congested,
                     round(congested / plain, 3)))
    return rows


def sec73_migration():
    """§7.3: thread-migration latency (paper: ~218 us average)."""
    cl = Cluster(8, backend="drust")
    th = cl.main_thread(0)
    th.stack_bytes = 1 << 20
    lat = cl.scheduler.migrate(th, 3)
    return [("sec73_thread_migration", lat, round(lat, 1))]


def all_rows(fast: bool = False):
    rows = []
    rows += fig5_scaling(nodes=(1, 8) if fast else NODES)
    rows += fig6_affinity()
    rows += fig7_coherence_cost()
    rows += batch_plane_sweep()
    rows += qp_depth_sweep(depths=(16, 64) if fast else (16, 64, 224))
    rows += link_congestion_fairness()
    rows += table2_deref_latency()
    rows += sec3_breakdown()
    rows += sec73_migration()
    return rows
