"""Bench-regression gate: fail CI when the protocol trajectory regresses.

    PYTHONPATH=src python -m benchmarks.check_regression \
        [--baseline BENCH_protocol.json] [--tolerance 0.10] [--out current.json]

Runs ``benchmarks/run.py --quick`` (protocol micro-benchmarks + the
batched-I/O app sweep + the multi-QP sweep + the coalesce/prefetch
sweeps) and compares the *deterministic* metrics against the committed
``BENCH_protocol.json``:

  * per-app round trips and virtual makespan (manual batched/unbatched
    planes AND the runtime coalescer's ``auto`` mode) — the paper's
    headline trajectory;
  * protocol message counts (``proto_*_msgs`` derived values);
  * the multi-QP completion plane (``qp_sweep``): virtual makespan within
    tolerance, and the fence/ooo counters (``fences``, ``fenced_verbs``,
    ``ooo_completions``, ``qp_switches``, ``round_trips``) pinned
    *exactly* — they are fully deterministic, so any drift is a behavior
    change that must be intentional (regenerate the baseline);
  * the speculative-prefetch counters (``speculative_fetches``,
    ``late_fences``, ``wasted_prefetches``) — pinned exactly everywhere
    they appear (app modes and the ``prefetch`` section);
  * the coalesce-budget sweep (``coalesce_sweep``): the adaptive policy's
    makespan within tolerance of its committed value, and its
    round-trip/flush counters exactly;
  * the crash-recovery sweep (``recovery``): fail-over makespan within
    tolerance per (cluster size, lost working set) point, with the
    disposition counters (``restored_bytes``, ``rehomed_boxes``,
    ``orphaned_cids``, ``lost_writes``, ``broken_locks``,
    ``dead_threads``) pinned exactly — plus the ``recovery_slo`` pair:
    working-set scaling must keep dominating cluster-size scaling
    (``slo_ok`` may never flip to false);
  * the lock-contention sweep (``lock_sweep``, see ``docs/sync.md``):
    spin/delegate/lease makespans within tolerance per (mode, cluster
    size) point, with the synchronization counters (``round_trips``,
    ``atomics``, ``delegated_sections``, ``convoy_completions``,
    ``closure_ships``, ``lease_grants``, ``lease_revokes``) pinned
    exactly — delegation's amortized-convoy advantage over spin is held
    by the makespan gate on both rows;
  * the placement sweep (``placement_sweep``, see ``docs/placement.md``):
    static spread/packed layouts vs telemetry-driven live owner migration
    on the zipf-skewed apps at 2-64 servers — makespans within tolerance,
    the placement counters (``round_trips``, ``owner_migrations``,
    ``migration_round_trips``, ``quantum_merges``) pinned exactly in BOTH
    directions, and each committed ``auto_beats_static`` acceptance bool
    (auto strictly under the best static on makespan AND round trips at
    8+ servers, with identical digests) may never flip to false;
  * the serving SLOs (``serve``, see ``docs/serving.md``): open-loop
    p50/p99 tail latency within tolerance in the *upward* direction,
    goodput within tolerance in the *downward* direction, and the
    protocol counters underneath (round trips, KV hit/miss, wire bytes,
    weight refreshes, completions) pinned exactly.

Wall-clock microsecond columns are ignored — they are noise on shared CI
runners; everything gated here comes from the deterministic simulator.
A metric more than ``tolerance`` (default 10%) above its baseline fails
the gate (exit 1).  After an intentional perf change, regenerate the
baseline with ``PYTHONPATH=src python -m benchmarks.run --quick`` and
commit the updated ``BENCH_protocol.json``.
"""

from __future__ import annotations

import argparse
import json
import sys

APP_METRICS = ("round_trips", "makespan_us")
APP_MODES = ("batched", "unbatched", "auto")
# Deterministic completion-plane counters: pinned exactly, both directions.
# (App round_trips stay on the 10%-tolerance path above; the qp_sweep adds
# round_trips to the exact set because the sweep holds them constant by
# construction.)
APP_EXACT = ("fences", "fenced_verbs", "ooo_completions", "qp_switches",
             "speculative_fetches", "late_fences", "wasted_prefetches")
QP_EXACT = ("fences", "fenced_verbs", "ooo_completions", "qp_switches",
            "round_trips")
COALESCE_EXACT = ("round_trips", "flushes", "coalesced_derefs")
PREFETCH_EXACT = ("round_trips", "speculative_fetches", "late_fences",
                  "wasted_prefetches")
RECOVERY_EXACT = ("restored_bytes", "rehomed_boxes", "orphaned_cids",
                  "lost_writes", "broken_locks", "dead_threads")
LOCK_EXACT = ("round_trips", "atomics", "delegated_sections",
              "convoy_completions", "closure_ships", "lease_grants",
              "lease_revokes")
PLACEMENT_EXACT = ("round_trips", "owner_migrations", "migration_round_trips",
                   "quantum_merges")
# Serving SLO columns (open-loop sweep): tail latency regresses UPWARD,
# goodput regresses DOWNWARD — both gated within tolerance; the protocol
# counters underneath are deterministic and pinned exactly.
SERVE_WORSE_UP = ("p50_us", "p99_us")
SERVE_WORSE_DOWN = ("goodput_tok_s",)
SERVE_EXACT = ("completed", "slo_met", "steps", "round_trips", "kv_hits",
               "kv_misses", "wire_bytes", "weight_refreshes")


def compare(baseline: dict, current: dict, tolerance: float) -> list[str]:
    """Return a list of human-readable regression descriptions (empty = OK)."""
    failures = []
    for app, base_entry in sorted(baseline.get("apps", {}).items()):
        cur_entry = current.get("apps", {}).get(app)
        if cur_entry is None:
            failures.append(f"apps/{app}: missing from current run")
            continue
        for mode in APP_MODES:
            base_mode = base_entry.get(mode)
            if base_mode is None:
                continue                   # pre-coalescer baseline
            for metric in APP_METRICS:
                base = base_mode[metric]
                cur = cur_entry.get(mode, {}).get(metric)
                if cur is None:
                    failures.append(f"apps/{app}/{mode}/{metric}: missing")
                elif cur > base * (1.0 + tolerance):
                    failures.append(
                        f"apps/{app}/{mode}/{metric}: {cur} vs baseline "
                        f"{base} (+{100 * (cur / base - 1):.1f}%, "
                        f"tol {100 * tolerance:.0f}%)")
            for metric in APP_EXACT:
                base = base_mode.get(metric)
                if base is None:
                    continue               # pre-multi-QP baseline
                cur = cur_entry.get(mode, {}).get(metric)
                if cur != base:
                    failures.append(
                        f"apps/{app}/{mode}/{metric}: {cur} != baseline "
                        f"{base} (deterministic counter, pinned exactly)")
    for name, base_entry in sorted(baseline.get("qp_sweep", {}).items()):
        cur_entry = current.get("qp_sweep", {}).get(name)
        if cur_entry is None:
            failures.append(f"qp_sweep/{name}: missing from current run")
            continue
        base, cur = base_entry["makespan_us"], cur_entry.get("makespan_us")
        if cur is None:
            failures.append(f"qp_sweep/{name}/makespan_us: missing")
        elif cur > base * (1.0 + tolerance):
            failures.append(
                f"qp_sweep/{name}/makespan_us: {cur} vs baseline {base} "
                f"(+{100 * (cur / base - 1):.1f}%, tol {100 * tolerance:.0f}%)")
        for metric in QP_EXACT:
            base = base_entry.get(metric)
            cur = cur_entry.get(metric)
            if cur != base:
                failures.append(
                    f"qp_sweep/{name}/{metric}: {cur} != baseline {base} "
                    f"(deterministic counter, pinned exactly)")
    for section, exact in (("coalesce_sweep", COALESCE_EXACT),
                           ("prefetch", PREFETCH_EXACT),
                           ("recovery", RECOVERY_EXACT),
                           ("lock_sweep", LOCK_EXACT),
                           ("placement_sweep", PLACEMENT_EXACT)):
        for name, base_entry in sorted(baseline.get(section, {}).items()):
            cur_entry = current.get(section, {}).get(name)
            if cur_entry is None:
                failures.append(f"{section}/{name}: missing from current run")
                continue
            base, cur = base_entry["makespan_us"], cur_entry.get("makespan_us")
            if cur is None:
                failures.append(f"{section}/{name}/makespan_us: missing")
            elif cur > base * (1.0 + tolerance):
                failures.append(
                    f"{section}/{name}/makespan_us: {cur} vs baseline {base} "
                    f"(+{100 * (cur / base - 1):.1f}%, "
                    f"tol {100 * tolerance:.0f}%)")
            for metric in exact:
                if base_entry.get(metric) is None:
                    continue
                if cur_entry.get(metric) != base_entry[metric]:
                    failures.append(
                        f"{section}/{name}/{metric}: {cur_entry.get(metric)} "
                        f"!= baseline {base_entry[metric]} (deterministic "
                        f"counter, pinned exactly)")
    for name, base_entry in sorted(baseline.get("serve", {}).items()):
        cur_entry = current.get("serve", {}).get(name)
        if cur_entry is None:
            failures.append(f"serve/{name}: missing from current run")
            continue
        for metric in SERVE_WORSE_UP:
            base, cur = base_entry[metric], cur_entry.get(metric)
            if cur is None:
                failures.append(f"serve/{name}/{metric}: missing")
            elif cur > base * (1.0 + tolerance):
                failures.append(
                    f"serve/{name}/{metric}: {cur} vs baseline {base} "
                    f"(+{100 * (cur / base - 1):.1f}%, "
                    f"tol {100 * tolerance:.0f}%) — tail latency SLO")
        for metric in SERVE_WORSE_DOWN:
            base, cur = base_entry[metric], cur_entry.get(metric)
            if cur is None:
                failures.append(f"serve/{name}/{metric}: missing")
            elif cur < base * (1.0 - tolerance):
                failures.append(
                    f"serve/{name}/{metric}: {cur} vs baseline {base} "
                    f"(-{100 * (1 - cur / base):.1f}%, "
                    f"tol {100 * tolerance:.0f}%) — goodput SLO")
        for metric in SERVE_EXACT:
            if base_entry.get(metric) is None:
                continue
            if cur_entry.get(metric) != base_entry[metric]:
                failures.append(
                    f"serve/{name}/{metric}: {cur_entry.get(metric)} != "
                    f"baseline {base_entry[metric]} (deterministic counter, "
                    f"pinned exactly)")
    # Placement acceptance: each auto row whose committed baseline says
    # live migration strictly beats the best static layout (makespan AND
    # round trips, identical digests) must keep saying so — the bool may
    # never flip to false.  Exact-counter pins above already catch drift
    # in BOTH directions; this catches a current run whose fresh
    # trajectory no longer wins.
    for name, base_entry in sorted(baseline.get("placement_sweep", {}).items()):
        if not base_entry.get("auto_beats_static"):
            continue
        cur_entry = current.get("placement_sweep", {}).get(name)
        if cur_entry is None:
            continue                       # already reported missing above
        if not cur_entry.get("auto_beats_static"):
            failures.append(
                f"placement_sweep/{name}: auto_beats_static flipped false — "
                f"auto {cur_entry.get('makespan_us')}us/"
                f"{cur_entry.get('round_trips')}rt vs best static "
                f"{cur_entry.get('best_static_makespan_us')}us/"
                f"{cur_entry.get('best_static_round_trips')}rt")
    # Recovery SLO: not a counter comparison — the committed baseline says
    # working-set scaling dominates cluster-size scaling, and it must stay
    # that way on the current run (schema has no makespan_us, so it stays
    # out of the generic section loop above).
    if baseline.get("recovery_slo", {}).get("slo_ok"):
        cur_slo = current.get("recovery_slo")
        if cur_slo is None:
            failures.append("recovery_slo: missing from current run")
        elif not cur_slo.get("slo_ok"):
            failures.append(
                f"recovery_slo: slo_ok flipped false — working-set scale "
                f"{cur_slo.get('ws_scale_4srv_8to64_boxes')} no longer "
                f"dominates cluster scale "
                f"{cur_slo.get('srv_scale_8boxes_2to16_srv')}")
    for name, meta in sorted(baseline.get("micro", {}).items()):
        if not name.endswith("_msgs"):
            continue                       # wall-clock rows: not gated
        cur_meta = current.get("micro", {}).get(name)
        if cur_meta is None:
            failures.append(f"micro/{name}: missing from current run")
            continue
        base, cur = meta["derived"], cur_meta["derived"]
        if cur > base * (1.0 + tolerance):
            failures.append(
                f"micro/{name}: {cur} msgs vs baseline {base} "
                f"(tol {100 * tolerance:.0f}%)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_protocol.json")
    ap.add_argument("--tolerance", type=float, default=0.10)
    ap.add_argument("--out", default="/tmp/BENCH_current.json",
                    help="where the fresh --quick summary is written")
    ap.add_argument("--current", default=None,
                    help="compare an existing summary instead of re-running "
                    "(debugging the gate itself)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    if args.current:
        with open(args.current) as f:
            current = json.load(f)
    else:
        from benchmarks.run import quick
        current = quick(out_path=args.out)

    failures = compare(baseline, current, args.tolerance)
    if failures:
        print(f"BENCH REGRESSION vs {args.baseline}:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    n_gated = sum(1 for n in baseline.get("micro", {}) if n.endswith("_msgs"))
    n_gated += len(baseline.get("apps", {})) * len(APP_MODES) * (
        len(APP_METRICS) + len(APP_EXACT))
    n_gated += len(baseline.get("qp_sweep", {})) * (1 + len(QP_EXACT))
    n_gated += len(baseline.get("coalesce_sweep", {})) * (
        1 + len(COALESCE_EXACT))
    n_gated += len(baseline.get("prefetch", {})) * (1 + len(PREFETCH_EXACT))
    n_gated += len(baseline.get("recovery", {})) * (1 + len(RECOVERY_EXACT))
    n_gated += len(baseline.get("lock_sweep", {})) * (1 + len(LOCK_EXACT))
    n_gated += len(baseline.get("placement_sweep", {})) * (
        1 + len(PLACEMENT_EXACT))
    n_gated += sum(1 for v in baseline.get("placement_sweep", {}).values()
                   if v.get("auto_beats_static"))
    n_gated += len(baseline.get("serve", {})) * (
        len(SERVE_WORSE_UP) + len(SERVE_WORSE_DOWN) + len(SERVE_EXACT))
    n_gated += 1 if baseline.get("recovery_slo", {}).get("slo_ok") else 0
    print(f"bench gate OK: {n_gated} metrics within "
          f"{100 * args.tolerance:.0f}% of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
