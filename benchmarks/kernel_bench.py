"""Kernel micro-benchmarks: wall time of the XLA reference path on CPU (the
Pallas kernels themselves are TPU-targeted; interpret mode is not a timing
proxy) plus the oracle-vs-kernel agreement as the derived column."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, iters=3):
    f(*args)                              # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def all_rows(fast: bool = False):
    rng = np.random.default_rng(0)
    arr = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    rows = []

    B, H, Hkv, T, hd = 1, 4, 2, 256, 64
    q, k, v = arr(B, H, T, hd), arr(B, Hkv, T, hd), arr(B, Hkv, T, hd)
    ref_attn = jax.jit(lambda q, k, v: ref.attention(q, k, v))
    us = _time(ref_attn, q, k, v)
    out = ops.flash_attention(q, k, v, block_q=128, block_k=128)
    err = float(jnp.max(jnp.abs(out - ref.attention(q, k, v))))
    rows.append(("kernel_flash_attention_ref_xla", us, round(err, 6)))

    S = 512
    q1, k1, v1 = arr(B, H, hd), arr(B, Hkv, S, hd), arr(B, Hkv, S, hd)
    lengths = jnp.full((B,), S, jnp.int32)
    ref_dec = jax.jit(ref.decode_attention)
    us = _time(ref_dec, q1, k1, v1, lengths)
    out = ops.decode_attention(q1, k1, v1, lengths, block_k=128)
    err = float(jnp.max(jnp.abs(out - ref.decode_attention(q1, k1, v1,
                                                           lengths))))
    rows.append(("kernel_decode_attention_ref_xla", us, round(err, 6)))

    E, C, D, F = 4, 128, 256, 128
    x, w = arr(E, C, D), arr(E, D, F)
    ref_gmm = jax.jit(ref.moe_gmm)
    us = _time(ref_gmm, x, w)
    out = ops.moe_gmm(x, w, block_c=64, block_f=64, block_d=64)
    err = float(jnp.max(jnp.abs(out - ref.moe_gmm(x, w))))
    rows.append(("kernel_moe_gmm_ref_xla", us, round(err, 5)))

    if not fast:
        B2, H2, T2, M = 1, 2, 128, 32
        r = arr(B2, H2, T2, M); k2 = arr(B2, H2, T2, M); v2 = arr(B2, H2, T2, M)
        logw = -0.105 * jax.nn.sigmoid(arr(B2, H2, T2, M))
        u = arr(H2, M) * 0.1
        ref_rwkv = jax.jit(ref.rwkv_scan)
        us = _time(ref_rwkv, r, k2, v2, logw, u)
        o, _ = ops.rwkv_scan(r, k2, v2, logw, u, chunk=32)
        oe, _ = ref.rwkv_scan(r, k2, v2, logw, u)
        rows.append(("kernel_rwkv_scan_ref_xla", us,
                     round(float(jnp.max(jnp.abs(o - oe))), 6)))

        a = jax.nn.sigmoid(arr(2, 256, 128))
        b = arr(2, 256, 128)
        ref_lru = jax.jit(ref.rglru_scan)
        us = _time(ref_lru, a, b)
        h = ops.rglru_scan(a, b, chunk=64, block_d=64)
        rows.append(("kernel_rglru_scan_ref_xla", us,
                     round(float(jnp.max(jnp.abs(h - ref.rglru_scan(a, b)))),
                           6)))
    return rows
