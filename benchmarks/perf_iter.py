import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""§Perf iteration driver: lower one cell with config overrides, print the
three roofline terms and the delta vs the stored baseline artifact.

    PYTHONPATH=src python -m benchmarks.perf_iter --arch granite-34b \
        --shape decode_32k --set decode_shard_s=true [--save tag]
"""

import argparse
import json
from pathlib import Path


def parse_val(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        try:
            return float(v)
        except ValueError:
            return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--save", default=None,
                    help="store artifact as artifacts/perf/<arch>_<shape>_<tag>.json")
    args = ap.parse_args()

    from benchmarks.roofline import analyze, ARTIFACTS
    from repro import configs
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)

    key = configs.ALIASES.get(args.arch,
                              args.arch.replace("-", "_").replace(".", "_"))
    mesh = make_production_mesh()
    rec = lower_cell(key, args.shape, mesh, overrides=overrides or None,
                     microbatches=args.microbatches)
    a = analyze(rec)
    print(json.dumps({k: (round(v, 6) if isinstance(v, float) else v)
                      for k, v in a.items()}, indent=1))
    base_file = ARTIFACTS / f"{key}_{args.shape}_pod1.json"
    if base_file.exists():
        b = analyze(json.loads(base_file.read_text()))
        for term in ("compute_s", "memory_s", "collective_s", "mem_gb"):
            if b[term]:
                print(f"  {term:13s} {b[term]:10.4f} -> {a[term]:10.4f} "
                      f"({a[term]/b[term]:.3f}x)")
        print(f"  roofline      {b['roofline_frac']:.4f} -> "
              f"{a['roofline_frac']:.4f}")
    if args.save:
        out = Path("artifacts/perf")
        out.mkdir(parents=True, exist_ok=True)
        rec["overrides"] = overrides
        (out / f"{key}_{args.shape}_{args.save}.json").write_text(
            json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
