"""Roofline analysis over the dry-run artifacts (EXPERIMENTS §Roofline).

Hardware model (TPU v5e-class target):
    peak bf16 compute : 197 TFLOP/s per chip
    HBM bandwidth     : 819 GB/s per chip
    ICI link          : ~50 GB/s per link (we charge one link per chip —
                        conservative; collective bytes are per-device *wire*
                        bytes with ring-algorithm factors, see dryrun.py)

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs_per_dev / 197e12        [s]
    memory term     = HLO_bytes_per_dev / 819e9          [s]
    collective term = wire_bytes_per_dev / 50e9          [s]
    bottleneck      = argmax of the three
    MODEL_FLOPS     = 6*N*D (train) | 2*N*D (prefill) | 2*N_act*B (decode)
    usefulness      = MODEL_FLOPS_per_dev / HLO_FLOPs_per_dev
"""

from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ARTIFACTS = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def model_flops(rec: dict) -> float:
    """Cluster-total useful FLOPs for this cell's step."""
    n_active = rec["active_params"]
    tokens = rec["batch"] * rec["seq"]
    if rec["mode"] == "train":
        return 6.0 * n_active * tokens
    if rec["mode"] == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * rec["batch"]          # decode: one token/seq


def analyze(rec: dict) -> dict:
    n_dev = 1
    for v in rec["mesh"].values():
        n_dev *= v
    flops = rec["cost"].get("flops", 0.0)
    bytes_acc = max(rec["cost"].get("bytes_accessed", 0.0), 0.0)
    coll = sum(rec["collectives"].values())
    mem = rec.get("memory", {})
    # HLO bytes on the CPU backend are an *unfused* upper bound; the floor
    # moves every resident byte once (+ temp written & read).
    floor_bytes = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("output_size_in_bytes", 0)
                   + 2 * mem.get("temp_size_in_bytes", 0))
    t_c = flops / PEAK_FLOPS
    t_m = bytes_acc / HBM_BW
    t_mf = floor_bytes / HBM_BW
    t_n = coll / ICI_BW
    # bottleneck judged with the fused memory floor (actionable); the raw
    # HLO memory term is reported alongside.
    terms = {"compute": t_c, "memory": t_mf, "collective": t_n}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec) / n_dev
    step_time = max(terms.values())
    return {
        "cell": f"{rec['arch']}x{rec['shape']}",
        "mesh": "x".join(str(v) for v in rec["mesh"].values()),
        "compute_s": t_c, "memory_s": t_m, "memory_floor_s": t_mf,
        "collective_s": t_n,
        "bottleneck": bottleneck,
        "model_flops_dev": mf,
        "useful_frac": (mf / flops) if flops else 0.0,
        "roofline_frac": (mf / PEAK_FLOPS) / step_time if step_time else 0.0,
        "mem_gb": (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)) / 1e9,
    }


def load_all(pattern: str = "*_pod1.json", include_opt: bool = False):
    out = []
    for f in sorted(ARTIFACTS.glob(pattern)):
        if "smoke" in f.name:
            continue
        if ("_opt" in f.name) != include_opt:
            continue
        rec = json.loads(f.read_text())
        out.append(analyze(rec))
    return out


def opt_comparison() -> str:
    """Baseline vs --optimized table (only cells with an _opt artifact)."""
    base = {a["cell"]: a for a in load_all("*_pod1.json")}
    rows = ["| cell | step s (base→opt) | collective s (base→opt) "
            "| roofline (base→opt) | gain |",
            "|---|---|---|---|---|"]
    for a in load_all("*_pod1_opt.json", include_opt=True):
        b = base.get(a["cell"])
        if b is None:
            continue
        sb = max(b["compute_s"], b["memory_floor_s"], b["collective_s"])
        so = max(a["compute_s"], a["memory_floor_s"], a["collective_s"])
        rows.append(
            f"| {a['cell']} | {sb:.4f} → {so:.4f} "
            f"| {b['collective_s']:.4f} → {a['collective_s']:.4f} "
            f"| {b['roofline_frac']:.4f} → {a['roofline_frac']:.4f} "
            f"| {sb/so:.2f}x |")
    return "\n".join(rows)


def all_rows():
    rows = []
    for mesh_pat in ("*_pod1.json", "*_pod2.json"):
        for a in load_all(mesh_pat):
            rows.append((
                f"roofline_{a['cell']}_{a['mesh']}",
                max(a["compute_s"], a["memory_floor_s"],
                    a["collective_s"]) * 1e6,
                round(a["roofline_frac"], 4)))
    for a in load_all("*_pod1_opt.json", include_opt=True):
        rows.append((
            f"roofline_opt_{a['cell']}_{a['mesh']}",
            max(a["compute_s"], a["memory_floor_s"],
                a["collective_s"]) * 1e6,
            round(a["roofline_frac"], 4)))
    return rows


def table(pattern: str = "*_pod1.json") -> str:
    rows = load_all(pattern)
    hdr = ("| cell | mesh | compute s | mem(HLO) s | mem(floor) s "
           "| collective s | bottleneck | useful | roofline | GB/dev |")
    sep = "|---|---|---|---|---|---|---|---|---|---|"
    lines = [hdr, sep]
    for a in rows:
        lines.append(
            f"| {a['cell']} | {a['mesh']} | {a['compute_s']:.4f} "
            f"| {a['memory_s']:.3f} | {a['memory_floor_s']:.4f} "
            f"| {a['collective_s']:.4f} | {a['bottleneck']} "
            f"| {a['useful_frac']:.3f} | {a['roofline_frac']:.4f} "
            f"| {a['mem_gb']:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    print(table(sys.argv[1] if len(sys.argv) > 1 else "*_pod1.json"))
