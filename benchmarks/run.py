"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--fast|--quick]``.

Prints ``name,us_per_call,derived`` CSV — one section per paper table/figure
plus the JAX-side kernel and roofline benches when their artifacts exist.

``--quick`` is the CI smoke mode: it runs only the protocol micro-benchmarks
and the batched-I/O-plane app sweep and writes a ``BENCH_protocol.json``
summary (round trips, makespan, doorbell stats, and the open-loop serving
SLO columns — p50/p99 tail latency + goodput) so successive PRs leave a
comparable perf trajectory.
"""

from __future__ import annotations

import json
import sys


def _app_stats(r) -> dict:
    return {
        "makespan_us": round(r.makespan_us, 2),
        "round_trips": r.net["round_trips"],
        "bytes_moved": r.net["bytes_moved"],
        "doorbell_batches": r.net["doorbell_batches"],
        "batched_verbs": r.net["batched_verbs"],
        "async_writebacks": r.net["async_writebacks"],
        "fences": r.net["fences"],
        "fenced_verbs": r.net["fenced_verbs"],
        "ooo_completions": r.net["ooo_completions"],
        "qp_switches": r.net["qp_switches"],
        "speculative_fetches": r.net["speculative_fetches"],
        "late_fences": r.net["late_fences"],
        "wasted_prefetches": r.net["wasted_prefetches"],
    }


def quick(out_path: str = "BENCH_protocol.json") -> dict:
    from benchmarks import protocol_micro
    from repro.apps.dataframe import run_dataframe
    from repro.apps.gemm import run_gemm
    from repro.apps.kvstore import run_kvstore
    from repro.apps.socialnet import run_socialnet

    rows = protocol_micro.all_rows()
    summary: dict = {
        "micro": {name: {"us": round(us, 3), "derived": derived}
                  for name, us, derived in rows},
        "apps": {},
        # Multi-QP / out-of-order completion plane trajectory: makespan plus
        # the deterministic fence/ooo counters, pinned by the gate.
        "qp_sweep": protocol_micro.qp_sweep_summary(),
        # Adaptive deref coalescer vs the best static quantum budget, per
        # request mix (makespan gated within tolerance, counters exactly).
        "coalesce_sweep": protocol_micro.coalesce_summary(),
        # Crash-recovery trajectory: fail-over makespan vs (cluster size,
        # lost working set), counters pinned exactly; the SLO pair gates
        # that working-set scaling dominates cluster-size scaling.
        "recovery": protocol_micro.recovery_summary(),
        "recovery_slo": protocol_micro.recovery_slo(),
        # Serving SLO trajectory: open-loop (Poisson/bursty) tail latency
        # and goodput over the DSM-backed ServeFleet — p50/p99 higher-is-
        # worse, goodput lower-is-worse, protocol counters pinned exactly.
        "serve": protocol_micro.serve_summary(),
        # Lock-contention trajectory (spin vs delegation vs reader leases
        # at 2/8/64 servers under zipf skew): makespan within tolerance,
        # synchronization counters pinned exactly.  Delegation must keep
        # beating spin at 8+ servers (spin_over_delegate, derived).
        "lock_sweep": protocol_micro.lock_sweep_summary(),
        # Placement trajectory (static spread/packed layouts vs telemetry-
        # driven live owner migration on the zipf-skewed apps at 2-64
        # servers): makespan within tolerance, placement counters pinned
        # exactly.  Each auto row's auto_beats_static bool (strict win on
        # makespan AND round trips at 8+ servers, identical digests) is
        # gated and must not flip false.
        "placement_sweep": protocol_micro.placement_summary(),
        # Runtime-sanitizer wall-clock overhead (docs/analysis.md).  Never
        # gated — wall-clock is runner-dependent; the span_identical bools
        # document the observation-only contract (identical simulated
        # trajectory with the sanitizer on).
        "sanitize_overhead": protocol_micro.sanitize_overhead_summary(),
        "prefetch": {},
    }
    for app, fn, kw in (
        ("socialnet", run_socialnet, dict(n_requests=120)),
        ("dataframe", run_dataframe, dict(n_columns=4, chunks_per_column=8,
                                          n_ops=4, use_tbox=True)),
    ):
        entry = {}
        # "batched"/"unbatched" keep the PR-1 manual choreography planes;
        # "auto" is the runtime coalescer with zero app choreography.
        for mode, mkw in (("batched", dict(batch_io=True, coalesce="manual")),
                          ("unbatched", dict(batch_io=False,
                                             coalesce="manual")),
                          ("auto", dict(batch_io=True, coalesce="auto"))):
            entry[mode] = _app_stats(fn(4, "drust", **mkw, **kw))
        entry["rtt_ratio"] = round(
            entry["unbatched"]["round_trips"]
            / max(1, entry["batched"]["round_trips"]), 2)
        summary["apps"][app] = entry
    # Speculative-prefetch trajectory: the deferred-fence/wasted counters
    # are fully deterministic — the gate pins them exactly.
    for name, r in (
        ("gemm_prefetch", run_gemm(4, "drust", n=256, tile=64,
                                   prefetch=True)),
        ("kvstore_window8", run_kvstore(4, "drust", n_keys=256, n_ops=600,
                                        prefetch_window=8)),
    ):
        summary["prefetch"][name] = {
            "makespan_us": round(r.makespan_us, 2),
            "round_trips": r.net["round_trips"],
            "speculative_fetches": r.net["speculative_fetches"],
            "late_fences": r.net["late_fences"],
            "wasted_prefetches": r.net["wasted_prefetches"],
        }
    with open(out_path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
    return summary


def main() -> None:
    if "--quick" in sys.argv:
        summary = quick()
        print("name,us_per_call,derived")
        for name, meta in summary["micro"].items():
            print(f"{name},{meta['us']:.2f},{meta['derived']}")
        for app, entry in summary["apps"].items():
            print(f"quick_{app}_rtt_ratio,0.00,{entry['rtt_ratio']}")
        for name, meta in summary["qp_sweep"].items():
            print(f"quick_qp_{name},{meta['makespan_us']:.2f},"
                  f"{meta['ooo_completions']}")
        for name, meta in summary["coalesce_sweep"].items():
            print(f"quick_coalesce_{name},{meta['makespan_us']:.2f},"
                  f"{meta['auto_over_best']}")
        for name, meta in summary["prefetch"].items():
            print(f"quick_prefetch_{name},{meta['makespan_us']:.2f},"
                  f"{meta['speculative_fetches']}")
        for name, meta in summary["recovery"].items():
            print(f"quick_recovery_{name},{meta['makespan_us']:.2f},"
                  f"{meta['restored_bytes']}")
        for name, meta in summary["lock_sweep"].items():
            print(f"quick_lock_{name},{meta['makespan_us']:.2f},"
                  f"{meta['round_trips']}")
        for name, meta in summary["serve"].items():
            print(f"quick_serve_{name}_p99,{meta['p99_us']:.2f},"
                  f"{meta['goodput_tok_s']}")
        for name, meta in summary["placement_sweep"].items():
            print(f"quick_placement_{name},{meta['makespan_us']:.2f},"
                  f"{meta['round_trips']}")
        slo = summary["recovery_slo"]
        print(f"quick_recovery_slo_ok,0.00,{slo['slo_ok']}")
        print("wrote BENCH_protocol.json", file=sys.stderr)
        return

    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")

    from benchmarks import paper_figs
    for name, us, derived in paper_figs.all_rows(fast=fast):
        print(f"{name},{us:.2f},{derived}")

    from benchmarks import protocol_micro
    for name, us, derived in protocol_micro.all_rows():
        print(f"{name},{us:.2f},{derived}")

    try:
        from benchmarks import kernel_bench
        for name, us, derived in kernel_bench.all_rows(fast=fast):
            print(f"{name},{us:.2f},{derived}")
    except Exception as e:                                 # pragma: no cover
        print(f"kernel_bench_skipped,0,{type(e).__name__}", file=sys.stderr)

    try:
        from benchmarks import roofline
        for name, us, derived in roofline.all_rows():
            print(f"{name},{us:.2f},{derived}")
    except Exception as e:                                 # pragma: no cover
        print(f"roofline_skipped,0,{type(e).__name__}", file=sys.stderr)


if __name__ == "__main__":
    main()
