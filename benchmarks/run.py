"""Benchmark driver: ``PYTHONPATH=src python -m benchmarks.run [--fast]``.

Prints ``name,us_per_call,derived`` CSV — one section per paper table/figure
plus the JAX-side kernel and roofline benches when their artifacts exist.
"""

from __future__ import annotations

import sys


def main() -> None:
    fast = "--fast" in sys.argv
    print("name,us_per_call,derived")

    from benchmarks import paper_figs
    for name, us, derived in paper_figs.all_rows(fast=fast):
        print(f"{name},{us:.2f},{derived}")

    from benchmarks import protocol_micro
    for name, us, derived in protocol_micro.all_rows():
        print(f"{name},{us:.2f},{derived}")

    try:
        from benchmarks import kernel_bench
        for name, us, derived in kernel_bench.all_rows(fast=fast):
            print(f"{name},{us:.2f},{derived}")
    except Exception as e:                                 # pragma: no cover
        print(f"kernel_bench_skipped,0,{type(e).__name__}", file=sys.stderr)

    try:
        from benchmarks import roofline
        for name, us, derived in roofline.all_rows():
            print(f"{name},{us:.2f},{derived}")
    except Exception as e:                                 # pragma: no cover
        print(f"roofline_skipped,0,{type(e).__name__}", file=sys.stderr)


if __name__ == "__main__":
    main()
