"""Serving driver: batched decode with the ownership-paged KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b \
        [--requests 12] [--slots 4] [--max-new 16] [--refresh-every 8]

Demonstrates the paper's coherence protocol in the serving path:
  * shared prompt prefixes are immutably-borrowed pages (refcounted);
  * each decode step appends under a mutable borrow (color bump);
  * weight refresh is a colored-cache fetch: a writer (simulated online
    trainer) bumps the weights' color and every replica refetches lazily —
    zero invalidation messages.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--refresh-every", type=int, default=0,
                    help="bump weight color every N engine steps "
                    "(simulated online trainer)")
    args = ap.parse_args()

    from repro import configs
    from repro.core.jaxstate import OwnedState
    from repro.models import init_params
    from repro.serve import ServeEngine

    cfg = configs.smoke(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    weights = OwnedState("weights", params)
    engine = ServeEngine(cfg, weights, slots=args.slots, max_len=256)

    rng = np.random.default_rng(0)
    shared_prefix = list(rng.integers(0, cfg.vocab, size=cfg.attn_chunk))
    reqs = []
    for i in range(args.requests):
        # half the requests share a prompt prefix (page-level sharing)
        prompt = shared_prefix + list(rng.integers(0, cfg.vocab, size=8)) \
            if i % 2 == 0 else list(rng.integers(0, cfg.vocab, size=12))
        reqs.append(engine.submit(prompt, max_new=args.max_new))

    step = 0
    while engine.queue or engine.active:
        engine.step()
        step += 1
        if args.refresh_every and step % args.refresh_every == 0:
            with weights.borrow_mut() as ref:      # online weight update
                ref.set(ref.deref_mut())
        if step > 10_000:
            raise RuntimeError("engine did not drain")

    done = sum(1 for r in reqs if r.done)
    st = engine.stats()
    print(f"served {done}/{len(reqs)} requests in {st['steps']} steps")
    print(f"kv pages: {st['kv']}")
    print(f"weight refreshes: {st['weight_refreshes']} "
          f"(hits {st['weight_hits']}) — zero invalidation messages")
    assert done == len(reqs)
    return st


if __name__ == "__main__":
    main()
