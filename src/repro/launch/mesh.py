"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax init).

``jax.sharding.AxisType`` (and ``make_mesh``'s ``axis_types`` kwarg) only
exist in newer JAX releases; older installs get plain meshes.
"""

from __future__ import annotations

import jax


def _axis_kw(n_axes: int) -> dict:
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, smoke dry-runs on few host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_kw(len(axes)))
