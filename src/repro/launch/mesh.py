"""Production mesh construction.

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, smoke dry-runs on few host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
