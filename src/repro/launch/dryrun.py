import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--multi-pod] [--mesh 2x4] [--smoke] [--out artifacts/dryrun]

For each cell this lowers the *real* train_step (params + optimizer update,
donated) or serve_step (one token against a seq_len cache), compiles it for
the 16x16 (or 2x16x16) mesh, and records:
  * compiled.memory_analysis()  -> per-device bytes (proves it fits)
  * compiled.cost_analysis()    -> HLO flops / bytes for the roofline
  * collective bytes by op kind -> parsed from the partitioned HLO
"""

import argparse
import json
import re
import time
from pathlib import Path


def _dtype_bytes(name: str) -> float:
    return {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
            "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
            "f64": 8, "c64": 8, "c128": 16}.get(name, 4)


_SHAPE_RE = re.compile(r"(pred|[us]\d+|bf16|f16|f32|f64|c64|c128)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?P<rtype>[^=]*?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start|-done)?\(")


def _group_size(line: str, default: int = 2) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)     # iota v2
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)      # explicit
    if m:
        return len(m.group(1).split(","))
    return default


def collective_bytes(hlo_text: str, while_mult: int = 1) -> dict:
    """Per-device *wire* bytes per collective kind, from partitioned HLO.

    Result-type bytes R, group size G, ring algorithms:
      all-reduce: 2(G-1)/G x R   all-gather: (G-1)/G x R_out
      reduce-scatter: (G-1) x R_out   all-to-all: (G-1)/G x R
      collective-permute: R
    Ops inside while bodies (scan over layers) are multiplied by
    ``while_mult`` (the scan trip count) — the body appears once in text
    but executes every step.
    """
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or m.group("start") == "-done":
            continue
        kind = m.group("kind")
        shapes = _SHAPE_RE.findall(m.group("rtype"))
        if not shapes:
            shapes = _SHAPE_RE.findall(line.split("(")[0])
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _dtype_bytes(dt)
        G = _group_size(line)
        ring = {"all-reduce": 2.0 * (G - 1) / G,
                "all-gather": (G - 1) / G,
                "reduce-scatter": float(G - 1),
                "all-to-all": (G - 1) / G,
                "collective-permute": 1.0}[kind]
        mult = while_mult if "/while/" in line or "while" in line.split(
            "metadata", 1)[-1] else 1
        out[kind] += nbytes * ring * mult
    return {k: int(v) for k, v in out.items()}


# §Perf-confirmed per-cell optimization policy (EXPERIMENTS §4): the
# paper-faithful rules stay the default; --optimized applies these.
SMALL_DENSE = {"qwen3_0_6b", "starcoder2_3b", "gemma_7b", "musicgen_medium",
               "rwkv6_3b", "pixtral_12b"}


def optimized_overrides(arch: str, shape: str) -> dict:
    from repro.configs import SHAPES
    mode = SHAPES[shape][2]
    ov = {}
    if mode == "decode":
        ov["serve_weights_tp_only"] = True
        if shape != "long_500k":
            ov["decode_shard_s"] = True
    elif mode == "train" and arch in SMALL_DENSE:
        ov["dp_only"] = True
    if arch in ("qwen3_moe_235b", "arctic_480b") and mode != "decode":
        ov["moe_a2a"] = True
    return ov


def input_specs(cfg, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    from repro.configs import SHAPES
    from repro.models import build_batch_spec
    seq, batch, mode = SHAPES[shape_name]
    return build_batch_spec(cfg, batch, seq, mode=mode), (seq, batch, mode)


def _cost_of(lowered_or_compiled) -> dict:
    try:
        ca = lowered_or_compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception:                                   # pragma: no cover
        return {}


def lower_cell(arch: str, shape_name: str, mesh, *, opt_name: str | None = None,
               smoke: bool = False, compile_: bool = True,
               microbatches: int = 1, verbose: bool = True,
               calibrate: bool = True, overrides: dict | None = None) -> dict:
    import functools

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro import configs
    from repro.dist.sharding import (batch_specs, cache_specs, param_specs,
                                     set_mesh)
    from repro.models import init_cache, init_params
    from repro.serve.serve_step import make_serve_step
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.train_step import make_train_step

    import dataclasses

    cfg = configs.smoke(arch) if smoke else configs.get(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    from repro.dist.sharding import set_rule_flags
    set_rule_flags(ulysses=cfg.ulysses,
                   serve_weights=cfg.serve_weights_tp_only,
                   dp_only=cfg.dp_only)
    batch_abs, (seq, batch, mode) = input_specs(cfg, shape_name)
    if smoke:
        seq, batch = min(seq, 256), min(batch, max(8, 1))
        from repro.models import build_batch_spec
        batch_abs = build_batch_spec(cfg, batch, seq, mode=mode)

    set_mesh(mesh)
    ns = lambda spec: NamedSharding(mesh, spec)
    is_spec = lambda x: isinstance(x, jax.sharding.PartitionSpec)
    opt = OptConfig(name=opt_name or
                    ("adafactor" if arch == "arctic_480b" else "adamw"))

    def build(cfg2):
        """Lower one variant; returns (lowered, abstract param tree)."""
        params_abs = jax.eval_shape(functools.partial(init_params, cfg2),
                                    jax.random.PRNGKey(0))
        p_shard = jax.tree.map(ns, param_specs(mesh, params_abs))
        b_shard = jax.tree.map(ns, batch_specs(mesh, batch_abs))
        if mode == "train":
            opt_abs = jax.eval_shape(functools.partial(init_opt_state, opt),
                                     params_abs)
            from repro.dist.sharding import opt_state_specs
            o_shard = jax.tree.map(ns, opt_state_specs(mesh, opt_abs,
                                                       params_abs),
                                   is_leaf=is_spec)
            fn = make_train_step(cfg2, opt, mesh=mesh,
                                 microbatches=microbatches)
            jitted = jax.jit(fn, in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            return jitted.lower(params_abs, opt_abs, batch_abs), params_abs
        if mode == "prefill":
            from repro.serve.serve_step import make_prefill
            fn = make_prefill(cfg2, mesh=mesh)
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard))
            return jitted.lower(params_abs, batch_abs), params_abs
        cache_abs = jax.eval_shape(
            functools.partial(init_cache, cfg2, batch, seq))
        c_shard = jax.tree.map(ns, cache_specs(mesh, cache_abs),
                               is_leaf=is_spec)
        fn = make_serve_step(cfg2, mesh=mesh)
        jitted = jax.jit(fn,
                         in_shardings=(p_shard, c_shard, b_shard["tokens"]),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,))
        return jitted.lower(params_abs, cache_abs,
                            batch_abs["tokens"]), params_abs

    t0 = time.time()
    lowered, params_abs = build(cfg)
    rec = {"arch": arch, "shape": shape_name, "mode": mode,
           "mesh": dict(mesh.shape), "seq": seq, "batch": batch,
           "params": int(sum(int(jnp.prod(jnp.array(l.shape)))
                             for l in jax.tree.leaves(params_abs))),
           "active_params": cfg.active_param_count(),
           "lower_s": round(time.time() - t0, 2)}
    if not compile_:
        set_mesh(None)
        return rec

    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    except Exception as e:                              # pragma: no cover
        rec["memory"] = {"error": str(e)}
    rec["cost_raw"] = {k: v for k, v in _cost_of(compiled).items()
                       if "flops" in k or "bytes accessed" == k}

    # XLA's cost analysis counts a while body ONCE, independent of trip
    # count.  True FLOPs are extrapolated from two *unrolled, single-device*
    # variants (1 and 2 layer-groups; einsum attention -- identical T^2 math
    # to the chunked path; python-loop experts/chunks): F(L) = base+slope*L.
    period = cfg.attn_every or 1
    n_steps = (cfg.n_layers // period) if cfg.scan_layers else 1
    n_dev = mesh.size
    if calibrate and n_steps > 2 and not smoke:
        def build_cal(n_layers):
            cfg2 = dataclasses.replace(
                cfg, n_layers=n_layers, scan_layers=False,
                attn_chunk=max(cfg.attn_chunk, seq + 1),
                unroll_chunks=True, unroll_experts=True)
            set_mesh(None)
            p_abs = jax.eval_shape(functools.partial(init_params, cfg2),
                                   jax.random.PRNGKey(0))
            if mode == "train":
                o_abs = jax.eval_shape(
                    functools.partial(init_opt_state, opt), p_abs)
                fn = make_train_step(cfg2, opt, mesh=None)
                return jax.jit(fn).lower(p_abs, o_abs, batch_abs)
            if mode == "prefill":
                from repro.serve.serve_step import make_prefill
                fn = make_prefill(cfg2, mesh=None)
                return jax.jit(fn).lower(p_abs, batch_abs)
            c_abs = jax.eval_shape(
                functools.partial(init_cache, cfg2, batch, seq))
            fn = make_serve_step(cfg2, mesh=None)
            return jax.jit(fn).lower(p_abs, c_abs, batch_abs["tokens"])

        c1 = _cost_of(build_cal(period).compile())
        c2 = _cost_of(build_cal(2 * period).compile())
        set_mesh(mesh)
        cal = {}
        for k in ("flops", "bytes accessed"):
            if k in c1 and k in c2:
                slope = (c2[k] - c1[k]) / period
                total = c1[k] - slope * period + slope * cfg.n_layers
                cal[k.replace(" ", "_")] = max(total / n_dev,
                                               rec["cost_raw"].get(k, 0.0))
        rec["cost"] = cal
        rec["cost"]["calibrated"] = True
    else:
        rec["cost"] = {
            "flops": rec["cost_raw"].get("flops", 0.0),
            "bytes_accessed": rec["cost_raw"].get("bytes accessed", 0.0),
            "calibrated": False}

    hlo = compiled.as_text()
    rec["collectives"] = collective_bytes(hlo, while_mult=max(
        1, n_steps * max(1, microbatches)))
    rec["hlo_lines"] = hlo.count("\n")
    set_mesh(None)
    if verbose:
        flops = rec.get("cost", {}).get("flops", 0)
        print(f"  [{arch} x {shape_name}] lower {rec['lower_s']}s "
              f"compile {rec['compile_s']}s flops/dev {flops:.3e} "
              f"coll {sum(rec['collectives'].values())/1e6:.1f}MB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--mesh", default=None,
                    help="override mesh, e.g. 2x4 (axes data,model) or "
                    "2x2x2 (pod,data,model)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf-confirmed per-cell flags; artifacts"
                    " are tagged _opt")
    args = ap.parse_args()

    from repro import configs
    from repro.launch.mesh import make_mesh, make_production_mesh

    cells = configs.cells()
    if args.arch:
        key = configs.ALIASES.get(args.arch,
                                  args.arch.replace("-", "_").replace(".", "_"))
        cells = [c for c in cells if c[0] == key]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    if not cells:
        raise SystemExit("no cells selected")

    meshes = []
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        meshes.append(("custom", make_mesh(dims, axes)))
    elif args.both:
        meshes = [("pod1", make_production_mesh()),
                  ("pod2", make_production_mesh(multi_pod=True))]
    elif args.multi_pod:
        meshes.append(("pod2", make_production_mesh(multi_pod=True)))
    else:
        meshes.append(("pod1", make_production_mesh()))

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = []
    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            tag = f"{arch}_{shape}_{mesh_name}" \
                + ("_opt" if args.optimized else "") \
                + ("_smoke" if args.smoke else "")
            if args.skip_existing and (outdir / f"{tag}.json").exists():
                print(f"== {tag} (cached)")
                continue
            print(f"== {tag} (mesh {dict(mesh.shape)})")
            try:
                ov = optimized_overrides(arch, shape) if args.optimized \
                    else None
                rec = lower_cell(arch, shape, mesh, smoke=args.smoke,
                                 microbatches=args.microbatches,
                                 overrides=ov)
                print(json.dumps({k: rec[k] for k in
                                  ("memory", "cost", "collectives")
                                  if k in rec}, indent=None)[:400])
                (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
            except Exception as e:
                import traceback
                traceback.print_exc()
                failures.append((tag, repr(e)))
    if failures:
        print(f"\nFAILED {len(failures)} cells:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print(f"\nALL {len(cells) * len(meshes)} cells OK")


if __name__ == "__main__":
    main()
