"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        [--smoke] [--steps 50] [--batch 8] [--seq 256] [--ckpt-dir DIR] \
        [--fail-at N]   (inject a failure: restore from the epoch backup)

Runs the real loop: synthetic data -> ownership-wrapped train state ->
jitted step (donated buffers, color bump per epoch) -> epoch-batched
checkpointing -> optional failure injection + recovery.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    from repro import configs
    from repro.checkpoint import CheckpointManager
    from repro.models import init_params
    from repro.train import OptConfig, TrainState, synthetic_batches

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"batch={args.batch}x{args.seq}")

    opt = OptConfig(lr=args.lr, warmup=5, decay_steps=args.steps * 2)
    ts = TrainState(cfg, opt, params, microbatches=args.microbatches)
    ts.replicate()                                # §4.2.3 backup slot
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, ts.state,
                                every_n_epochs=args.ckpt_every)

    data = synthetic_batches(cfg.vocab, args.batch, args.seq,
                             prefix_len=cfg.prefix_len, d_model=cfg.d_model)
    losses = []
    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = jax.tree.map(jax.numpy.asarray, next(data))
        m = ts.step(batch)
        losses.append(float(m["loss"]))
        if step % 5 == 0 or step == 1:
            dt = (time.time() - t0) / step
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"color {ts.color} {dt*1e3:.0f} ms/step")
        if args.fail_at and step == args.fail_at:
            print(f"!! injecting failure at step {step}; promoting backup")
            ts.restore_from_backup()

    print(f"first loss {losses[0]:.4f} -> last {losses[-1]:.4f} "
          f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")
    if mgr and mgr.latest():
        print(f"checkpoints: {len(mgr.saved)}, latest color {mgr.latest()[0]}")
    return losses


if __name__ == "__main__":
    main()
