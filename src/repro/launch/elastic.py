"""Elastic scaling: checkpoint reshard AND live protocol-runtime rescale.

    PYTHONPATH=src python -m repro.launch.elastic --arch qwen3-0.6b \
        --from-mesh 2x4 --to-mesh 4x2
    PYTHONPATH=src python -m repro.launch.elastic --protocol

Two paths to the same DSM promise (the global address space stays fixed
while the membership changes, DESIGN §2.2):

* **checkpoint reshard** (default) — checkpoints store logical
  (path -> global shape) leaves, the PGAS view, so restoring onto any mesh
  is just re-partitioning.
* **live protocol rescale** (``--protocol``) — no checkpoint round trip:
  a server *crashes* under a live drust runtime (shrink), the controller's
  probe loop declares it and the ``RecoveryManager`` fails it over
  (quiesce / re-home / restripe — flushed data stays readable at its
  original addresses), then the cluster *grows* with ``add_server`` and
  keeps allocating on the new member.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import tempfile

import jax
import numpy as np


def run(arch: str = "qwen3-0.6b", from_mesh=(2, 4), to_mesh=(4, 2),
        verbose: bool = True) -> bool:
    from repro import configs
    from repro.checkpoint import restore, save
    from repro.dist.sharding import param_specs, set_mesh
    from repro.launch.mesh import make_mesh
    from repro.models import init_params

    cfg = configs.smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))

    mesh_a = make_mesh(from_mesh, ("data", "model"))
    set_mesh(mesh_a)
    specs_a = param_specs(mesh_a, params)
    sharded_a = jax.tree.map(
        lambda x, s: jax.device_put(x, jax.sharding.NamedSharding(mesh_a, s)),
        params, specs_a)

    with tempfile.TemporaryDirectory() as d:
        save(f"{d}/ck", sharded_a, color=3)

        mesh_b = make_mesh(to_mesh, ("data", "model"))
        set_mesh(mesh_b)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        specs_b = param_specs(mesh_b, like)
        restored, manifest = restore(f"{d}/ck", like, mesh=mesh_b,
                                     specs=specs_b)

    ok = manifest["color"] == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        ok &= bool(np.allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-6))
    set_mesh(None)
    if verbose:
        print(f"elastic reshard {from_mesh} -> {to_mesh}: "
              f"{'OK' if ok else 'MISMATCH'} (epoch color {manifest['color']})")
    return ok


def run_protocol(n_servers: int = 4, verbose: bool = True) -> bool:
    """Live rescale of a running protocol cluster: crash server ``n-1``,
    probe-detect + fail over, verify flushed data survives at its original
    addresses, then grow by one server and allocate on it."""
    from repro.core import Cluster, ServerLostError

    cl = Cluster(n_servers, "drust", replicate=True, qps_per_thread=2,
                 ooo=True, coalesce="auto")
    ths = [cl.main_thread(s) for s in range(n_servers)]
    victim = n_servers - 1

    # Populate every server, mutate, and flush the epoch (train-step edge).
    boxes = []
    for s, th in enumerate(ths):
        for i in range(8):
            b = cl.backend.alloc(th, 256, i + 100 * s, server=s)
            cl.backend.write(th, b, i + 1000 * s)
            boxes.append((s, i, b))
    cl.replicator.flush_epoch()
    dirty = cl.backend.alloc(ths[victim], 256, "dirty", server=victim)
    cl.backend.write(ths[victim], dirty, "unflushed")    # will be lost

    # Shrink: crash + probe loop until declared, recovery runs.
    cl.recovery.crash(victim)
    probe_th = ths[0]
    declared: list = []
    while not declared:
        declared = cl.controller.probe_failures(probe_th)
    report = cl.recovery.reports[-1]
    ok = declared == [victim] and report.server == victim
    ok &= report.rehomed_boxes >= 8 and report.lost_writes >= 1

    # Flushed data is readable at its original addresses, served by the
    # promoted backup; the unflushed write reverted to its flushed epoch.
    for s, i, b in boxes:
        ok &= cl.backend.read(ths[0], b) == i + 1000 * s
    try:
        cl.backend.read(ths[0], dirty)
        got_lost = True          # restored from replica map?  It never flushed
    except ServerLostError:
        got_lost = False
    ok &= not got_lost

    # Grow: a fresh server joins and takes allocations + traffic.
    s_new = cl.add_server()
    th_new = cl.main_thread(s_new)
    nb = cl.backend.alloc(th_new, 256, "fresh", server=s_new)
    ok &= cl.backend.read(ths[0], nb) == "fresh"
    ok &= s_new == n_servers and len(cl.sim.alive_servers()) == n_servers

    if verbose:
        print(f"elastic protocol rescale {n_servers}->"
              f"{n_servers - 1}->{n_servers}: {'OK' if ok else 'MISMATCH'} "
              f"(rehomed {report.rehomed_boxes}, orphans "
              f"{report.orphaned_cids}, makespan "
              f"{report.makespan_us:.1f}us)")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--from-mesh", default="2x4")
    ap.add_argument("--to-mesh", default="4x2")
    ap.add_argument("--protocol", action="store_true",
                    help="live protocol-runtime rescale (crash + fail-over "
                         "+ grow) instead of a checkpoint reshard")
    ap.add_argument("--servers", type=int, default=4)
    a = ap.parse_args()
    if a.protocol:
        assert run_protocol(a.servers)
        return
    parse = lambda s: tuple(int(x) for x in s.split("x"))
    assert run(a.arch, parse(a.from_mesh), parse(a.to_mesh))


if __name__ == "__main__":
    main()
