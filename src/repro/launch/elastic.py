"""Elastic scaling: restore a checkpoint onto a different mesh.

    PYTHONPATH=src python -m repro.launch.elastic --arch qwen3-0.6b \
        --from-mesh 2x4 --to-mesh 4x2

Because checkpoints store logical (path -> global shape) leaves — the PGAS
view, not device shards — restoring onto any mesh is just re-partitioning:
``checkpoint.restore(..., mesh=new_mesh, specs=param_specs(new_mesh, ...))``.
This is the DSM promise applied to cluster resizing: the global address
space stays fixed while the partition map changes (DESIGN §2.2).
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import tempfile

import jax
import numpy as np


def run(arch: str = "qwen3-0.6b", from_mesh=(2, 4), to_mesh=(4, 2),
        verbose: bool = True) -> bool:
    from repro import configs
    from repro.checkpoint import restore, save
    from repro.dist.sharding import param_specs, set_mesh
    from repro.launch.mesh import make_mesh
    from repro.models import init_params

    cfg = configs.smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))

    mesh_a = make_mesh(from_mesh, ("data", "model"))
    set_mesh(mesh_a)
    specs_a = param_specs(mesh_a, params)
    sharded_a = jax.tree.map(
        lambda x, s: jax.device_put(x, jax.sharding.NamedSharding(mesh_a, s)),
        params, specs_a)

    with tempfile.TemporaryDirectory() as d:
        save(f"{d}/ck", sharded_a, color=3)

        mesh_b = make_mesh(to_mesh, ("data", "model"))
        set_mesh(mesh_b)
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        specs_b = param_specs(mesh_b, like)
        restored, manifest = restore(f"{d}/ck", like, mesh=mesh_b,
                                     specs=specs_b)

    ok = manifest["color"] == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        ok &= bool(np.allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-6))
    set_mesh(None)
    if verbose:
        print(f"elastic reshard {from_mesh} -> {to_mesh}: "
              f"{'OK' if ok else 'MISMATCH'} (epoch color {manifest['color']})")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--from-mesh", default="2x4")
    ap.add_argument("--to-mesh", default="4x2")
    a = ap.parse_args()
    parse = lambda s: tuple(int(x) for x in s.split("x"))
    assert run(a.arch, parse(a.from_mesh), parse(a.to_mesh))


if __name__ == "__main__":
    main()
