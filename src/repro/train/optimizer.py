"""Optimizers, pure JAX: AdamW (configurable moment dtype) and Adafactor
(factored second moment — the memory-scaling answer for the 480B config).

Moments are "TBox-tied" to their parameters: they share the parameter's
sharding (see dist.sharding.opt_state_specs) so the optimizer update is
fully local — no collective touches optimizer state, ever.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"              # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    moment_dtype: str = "float32"    # bfloat16 halves optimizer memory
    warmup: int = 100
    decay_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup, 1))
    prog = jnp.clip((step - cfg.warmup) / max(cfg.decay_steps - cfg.warmup, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def _factored_dims(shape):
    """Adafactor factors the two largest trailing dims of >=2D leaves."""
    if len(shape) < 2:
        return None
    return len(shape) - 2, len(shape) - 1


def init_opt_state(cfg: OptConfig, params):
    mdt = jnp.dtype(cfg.moment_dtype)
    state = {"count": jnp.zeros((), jnp.int32)}
    if cfg.name == "adamw":
        state["mu"] = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
        state["nu"] = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
        return state

    def vr(p):
        f = _factored_dims(p.shape)
        if f is None:
            return jnp.zeros(p.shape, jnp.float32)
        shape = list(p.shape)
        shape[f[1]] = 1
        return jnp.zeros(tuple(shape), jnp.float32)

    def vc(p):
        f = _factored_dims(p.shape)
        if f is None:
            return jnp.zeros((1,) * p.ndim, jnp.float32)
        shape = list(p.shape)
        shape[f[0]] = 1
        return jnp.zeros(tuple(shape), jnp.float32)

    state["vr"] = jax.tree.map(vr, params)
    state["vc"] = jax.tree.map(vc, params)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), norm


def apply_updates(cfg: OptConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    lr = schedule(cfg, count)
    mdt = jnp.dtype(cfg.moment_dtype)

    if cfg.name == "adamw":
        bc1 = 1 - cfg.b1 ** count.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
            v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
            step = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * step
            return p2.astype(p.dtype), m2.astype(mdt), v2.astype(mdt)

        out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = treedef.unflatten([l[0] for l in leaves])
        new_mu = treedef.unflatten([l[1] for l in leaves])
        new_nu = treedef.unflatten([l[2] for l in leaves])
        new_state = {"count": count, "mu": new_mu, "nu": new_nu}
        return new_p, new_state, {"grad_norm": gnorm, "lr": lr}

    # adafactor (momentum-less, factored second moment)
    def upd(p, g, vr, vc):
        f = _factored_dims(p.shape)
        g2 = g * g + 1e-30
        decay = 1.0 - (count.astype(jnp.float32)) ** -0.8
        if f is None:
            v2 = decay * vr + (1 - decay) * g2
            precond = g * jax.lax.rsqrt(v2 + cfg.eps)
            vr2, vc2 = v2, vc
        else:
            r, c = f
            vr2 = decay * vr + (1 - decay) * jnp.mean(g2, axis=c, keepdims=True)
            vc2 = decay * vc + (1 - decay) * jnp.mean(g2, axis=r, keepdims=True)
            denom = vr2 * vc2 / jnp.maximum(
                jnp.mean(vr2, axis=r, keepdims=True), 1e-30)
            precond = g * jax.lax.rsqrt(denom + cfg.eps)
        # relative step clipping (RMS of update <= 1)
        rms = jnp.sqrt(jnp.mean(jnp.square(precond)) + 1e-30)
        precond = precond / jnp.maximum(1.0, rms)
        p2 = p.astype(jnp.float32) - lr * (precond
                                           + cfg.weight_decay * p.astype(jnp.float32))
        return p2.astype(p.dtype), vr2, vc2

    out = jax.tree.map(upd, params, grads, state["vr"], state["vc"])
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_vr = treedef.unflatten([l[1] for l in leaves])
    new_vc = treedef.unflatten([l[2] for l in leaves])
    new_state = {"count": count, "vr": new_vr, "vc": new_vc}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
