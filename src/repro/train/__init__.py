from .optimizer import OptConfig, init_opt_state, apply_updates
from .train_step import make_train_step, TrainState
from .data import synthetic_batches, shard_batch

__all__ = ["OptConfig", "TrainState", "apply_updates", "init_opt_state",
           "make_train_step", "shard_batch", "synthetic_batches"]
