"""Deterministic synthetic LM data pipeline, shardable across the mesh.

Markov-chain token streams (not uniform noise) so the loss actually falls
during the example runs; batches are placed with the same NamedSharding the
train step expects, so input transfer is one host->device scatter.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding

from repro.dist.sharding import batch_specs


def synthetic_batches(vocab: int, global_batch: int, seq_len: int,
                      seed: int = 0, prefix_len: int = 0, d_model: int = 0,
                      dtype="bfloat16"):
    """Infinite iterator of {"tokens", "labels"[, "prefix_embeds"]} numpy."""
    rng = np.random.default_rng(seed)
    # sparse Markov transition: each symbol prefers ~8 successors
    succ = rng.integers(0, vocab, size=(vocab, 8))
    while True:
        toks = np.empty((global_batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, size=global_batch)
        choice = rng.integers(0, 8, size=(global_batch, seq_len))
        for t in range(seq_len):
            toks[:, t + 1] = succ[toks[:, t], choice[:, t]]
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if prefix_len:
            batch["prefix_embeds"] = rng.standard_normal(
                (global_batch, prefix_len, d_model)).astype(dtype)
        yield batch


def shard_batch(mesh, batch):
    """Place a host batch onto the mesh with the canonical input sharding."""
    if mesh is None:
        return jax.tree.map(jax.numpy.asarray, batch)
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    specs = batch_specs(mesh, abstract)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), batch, specs)
