"""Train step factory: loss + grad + optimizer update, with microbatch
gradient accumulation and the ownership-epoch hook.

The returned function is pure (pjit-friendly); the TrainState wrapper puts
params/opt_state under ``OwnedState`` so each step is a mutable-borrow epoch:
the color bump at drop is what serving replicas / checkpointers key their
zero-communication refresh on (DESIGN §2.2).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.jaxstate import OwnedState, ReplicaSlot
from repro.models import loss_fn
from repro.models.config import ModelConfig
from .optimizer import OptConfig, apply_updates, init_opt_state


def make_train_step(cfg: ModelConfig, opt: OptConfig, mesh=None,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With microbatches > 1, the global batch is split along axis 0 and
    gradients accumulate in f32 across a lax.scan (sequential — the standard
    memory/throughput trade; see EXPERIMENTS §Perf for where it pays off).
    """

    def lf(p, b):
        return loss_fn(cfg, p, b, mesh=mesh)

    def grads_of(params, batch):
        if microbatches <= 1:
            return jax.value_and_grad(lf)(params, batch)

        def micro(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(lf)(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            return (loss_acc + loss, g_acc), None

        split = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, g), _ = jax.lax.scan(micro, (jnp.zeros(()), g0), split)
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda x: x * inv, g)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        new_params, new_opt, metrics = apply_updates(opt, params, grads,
                                                     opt_state)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return train_step


class TrainState:
    """Host-side ownership wrapper around (params, opt_state).

    Each ``step`` is one write epoch: mutable borrow -> donated update ->
    color bump on drop.  ``replicate()`` attaches a §4.2.3 backup slot whose
    write-back is batched per epoch.
    """

    def __init__(self, cfg: ModelConfig, opt: OptConfig, params,
                 mesh=None, microbatches: int = 1, jit: bool = True):
        self.cfg, self.opt = cfg, opt
        opt_state = init_opt_state(opt, params)
        self.state = OwnedState("train_state", (params, opt_state))
        fn = make_train_step(cfg, opt, mesh=mesh, microbatches=microbatches)
        self._step = jax.jit(fn, donate_argnums=(0, 1)) if jit else fn
        self.replicas: list[ReplicaSlot] = []
        self.metrics: dict[str, Any] = {}

    def replicate(self) -> ReplicaSlot:
        slot = ReplicaSlot(self.state)
        self.replicas.append(slot)
        return slot

    @property
    def color(self) -> int:
        return self.state.color

    def step(self, batch):
        with self.state.borrow_mut() as ref:
            params, opt_state = ref.deref_mut()
            params, opt_state, metrics = self._step(params, opt_state, batch)
            ref.set((params, opt_state))
        self.metrics = metrics
        return metrics

    def params(self):
        return self.state.read()[0]

    def restore_from_backup(self):
        """Failure path: promote the newest backup (checkpoint/restart)."""
        if not self.replicas:
            raise RuntimeError("no replica slot attached")
        self.replicas[-1].promote()
        return self.state.color
