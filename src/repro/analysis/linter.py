"""AST borrow lint for the guard-API app surface.

The old CI check was a grep for ``borrow()``/``deref()``/``drop()`` call
pairs — blind to aliasing, strings, comments, and method-name collisions.
This module parses the app-level surface with :mod:`ast` and reports the
violations a grep cannot see.  Rules (codes are stable; names are used in
``# lint: allow(<name>)`` suppressions):

========  ====================  ==================================================
code      name                  what it catches
========  ====================  ==================================================
E101      raw-verb              raw protocol-verb calls (``borrow``/``borrow_mut``/
                                ``deref``/``deref_mut``/``drop_ref``, and bare
                                ``.drop(th[, h])``) outside ``core/`` — the guard
                                API is the app surface
E102      escaping-payload      a guard payload (the ``with ... as v`` name, or an
                                alias derived from it) read after its ``with``
                                block closed — the payload may be stale or remote
E103      guard-live-conflict   ``transfer``/``drop``/``free``/``drop_box`` on a
                                handle while a guard on that same handle is
                                syntactically live (inside its ``with`` body)
E104      guard-no-with         a guard opened without ``with`` — a direct
                                ``ReadGuard``/``WriteGuard``/``Region``
                                construction, an explicit ``.__enter__()``, or a
                                ``h.read(th)``/``h.write(th)`` call whose result
                                is not a ``with`` context (no structural release
                                on exception)
E105      spawn-capture         a DSM handle captured by a ``scheduler.spawn``
                                closure without ``server=`` routing — use
                                ``spawn_near``/``spawn_to`` + ``backend.locate``
                                so the thread runs near the data
========  ====================  ==================================================

A violation is suppressed when its source line carries a
``# lint: allow(<rule-name>)`` comment (e.g. the reader-lease grant in
``core/sync.py`` deliberately holds a pinned guard beyond lexical scope).
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable

RAW_VERBS = {"borrow", "borrow_mut", "deref", "deref_mut", "drop_ref"}
DISPOSE_VERBS = {"transfer", "free", "drop_box", "drop"}
GUARD_CLASSES = {"ReadGuard", "WriteGuard", "Region"}
SPAWN_ROUTED = {"spawn_to", "spawn_near"}

RULES = {
    "E101": "raw-verb",
    "E102": "escaping-payload",
    "E103": "guard-live-conflict",
    "E104": "guard-no-with",
    "E105": "spawn-capture",
}


@dataclass(frozen=True)
class LintViolation:
    file: str
    line: int
    col: int
    code: str
    rule: str
    message: str

    def format(self, style: str = "text") -> str:
        if style == "github":
            # GitHub Actions workflow-command annotation: attaches the
            # message to the offending line in the PR diff view.
            return (
                f"::error file={self.file},line={self.line},col={self.col},"
                f"title={self.code} {self.rule}::{self.message}"
            )
        return f"{self.file}:{self.line}:{self.col}: {self.code} [{self.rule}] {self.message}"


def _attr_call(node: ast.AST) -> str | None:
    """Return the attribute name if ``node`` is an ``x.attr(...)`` call."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _root_name(node: ast.AST) -> str | None:
    """Leftmost ``Name`` of a Name/Attribute/Subscript chain, else None."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


class _FileLinter:
    def __init__(self, path: str, tree: ast.Module, source: str) -> None:
        self.path = path
        self.tree = tree
        self.lines = source.splitlines()
        self.violations: list[LintViolation] = []
        # Expressions that are with-item context expressions (by identity):
        # these are the *legal* positions for guard-constructor calls.
        self.with_contexts: set[int] = set()
        for node in ast.walk(tree):
            if isinstance(node, (ast.With, ast.AsyncWith)):
                for item in node.items:
                    self.with_contexts.add(id(item.context_expr))
        # Names bound (anywhere in the module) from an alloc-family call —
        # approximation of "this name refers to DSM handles".
        # Block topology: for every statement list, which statement owns it,
        # and for every statement, where it sits.  Used to continue the
        # escaping-payload scan *past* the end of a branch — a `with` that is
        # the last statement of an `else:` still leaks its payload into the
        # statements after the enclosing `if`.
        self.block_parent: dict[int, ast.stmt] = {}
        self.stmt_pos: dict[int, tuple[list[ast.stmt], int]] = {}
        for node in ast.walk(tree):
            lists = [
                getattr(node, f, None) for f in ("body", "orelse", "finalbody")
            ]
            if isinstance(node, ast.Try):
                lists.extend(h.body for h in node.handlers)
            for stmts in lists:
                if not (isinstance(stmts, list) and stmts and isinstance(stmts[0], ast.stmt)):
                    continue
                if isinstance(node, ast.stmt):
                    self.block_parent[id(stmts)] = node
                for j, s in enumerate(stmts):
                    self.stmt_pos[id(s)] = (stmts, j)
        self.handle_names: set[str] = set()
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign):
                attrs = {
                    a
                    for sub in ast.walk(node.value)
                    if (a := _attr_call(sub)) is not None
                }
                if attrs & {"alloc", "alloc_tied"}:
                    for tgt in node.targets:
                        for n in ast.walk(tgt):
                            if isinstance(n, ast.Name):
                                self.handle_names.add(n.id)

    # -- reporting ---------------------------------------------------------

    def report(self, node: ast.AST, code: str, message: str) -> None:
        rule = RULES[code]
        line = getattr(node, "lineno", 1)
        src = self.lines[line - 1] if 0 < line <= len(self.lines) else ""
        if f"lint: allow({rule})" in src or "lint: allow(all)" in src:
            return
        self.violations.append(
            LintViolation(
                file=self.path,
                line=line,
                col=getattr(node, "col_offset", 0) + 1,
                code=code,
                rule=rule,
                message=message,
            )
        )

    # -- rules -------------------------------------------------------------

    def run(self) -> list[LintViolation]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call):
                self._check_raw_verb(node)
                self._check_guard_no_with(node)
                self._check_spawn_capture(node)
            elif isinstance(node, (ast.With, ast.AsyncWith)):
                self._check_guard_live_conflict(node)
        # Escaping payloads need statement-list context, not a flat walk.
        for node in ast.walk(self.tree):
            for field in ("body", "orelse", "finalbody"):
                stmts = getattr(node, field, None)
                if isinstance(stmts, list):
                    self._check_escaping_payload(stmts)
        self.violations.sort(key=lambda v: (v.line, v.col, v.code))
        return self.violations

    def _check_raw_verb(self, call: ast.Call) -> None:
        attr = _attr_call(call)
        if attr in RAW_VERBS:
            self.report(
                call,
                "E101",
                f"raw protocol verb .{attr}() — use the guard API "
                f"(box.read/box.write/cluster.region) instead",
            )
        elif attr == "drop" and not call.keywords and 1 <= len(call.args) <= 2:
            # `backend.drop(th, h)` / `h.drop(th)` — the legacy disposal verb.
            # Zero-arg and kwarg forms are assumed to be unrelated APIs.
            self.report(
                call,
                "E101",
                "raw protocol verb .drop() — dispose via guard-scoped "
                "backend.free()/drop_box() outside any live guard",
            )

    def _guard_call_target(self, call: ast.Call) -> ast.AST | None:
        """If ``call`` looks like a guard constructor, return the handle expr.

        Guard constructors on the app surface are ``h.read(th)`` /
        ``h.write(th)`` with exactly one positional argument that is a bare
        name (the thread).  This deliberately excludes ``backend.read(th,
        h)`` 2-arg shims, ``f.read()`` file-style calls, and
        ``state.write(state.read())`` value-plumbing (argument is a call,
        not a name).
        """
        if isinstance(call.func, ast.Attribute) and call.func.attr in ("read", "write"):
            if (
                len(call.args) == 1
                and not call.keywords
                and isinstance(call.args[0], ast.Name)
            ):
                return call.func.value
        return None

    def _check_guard_no_with(self, call: ast.Call) -> None:
        if id(call) in self.with_contexts:
            return
        if isinstance(call.func, ast.Name) and call.func.id in GUARD_CLASSES:
            self.report(
                call,
                "E104",
                f"{call.func.id}(...) constructed outside a with statement — "
                f"no structural release on exception",
            )
            return
        if _attr_call(call) == "__enter__":
            self.report(
                call,
                "E104",
                "explicit .__enter__() — the guard is never released if the "
                "scope unwinds; use `with`",
            )
            return
        tgt = self._guard_call_target(call)
        if tgt is not None:
            name = _root_name(tgt) or ast.unparse(tgt)
            self.report(
                call,
                "E104",
                f"guard opened on {name!r} outside a with statement — "
                f"no structural release on exception",
            )

    def _check_guard_live_conflict(self, w: ast.With | ast.AsyncWith) -> None:
        # Handles with a syntactically live guard inside this with body.
        live: list[str] = []
        for item in w.items:
            if isinstance(item.context_expr, ast.Call):
                fn = item.context_expr.func
                if isinstance(fn, ast.Attribute) and fn.attr in ("read", "write"):
                    live.append(ast.unparse(fn.value))
        if not live:
            return
        for node in ast.walk(w):
            attr = _attr_call(node)
            if attr not in DISPOSE_VERBS:
                continue
            assert isinstance(node, ast.Call)
            exprs = [node.func.value, *node.args]  # type: ignore[attr-defined]
            for e in exprs:
                u = ast.unparse(e)
                for h in live:
                    if u == h or u.startswith(h + "."):
                        self.report(
                            node,
                            "E103",
                            f".{attr}() on {h!r} while a guard on it is "
                            f"syntactically live in this with block",
                        )
                        return

    def _check_spawn_capture(self, call: ast.Call) -> None:
        attr = _attr_call(call)
        if attr != "spawn" or attr in SPAWN_ROUTED:
            return
        if any(kw.arg == "server" for kw in call.keywords):
            return
        captured = set()
        for arg in [*call.args, *[kw.value for kw in call.keywords]]:
            captured |= _names_in(arg) & self.handle_names
        if captured:
            names = ", ".join(sorted(captured))
            self.report(
                call,
                "E105",
                f"handle(s) {names} captured by .spawn() without locality "
                f"routing — use spawn_near/spawn_to or pass "
                f"server=backend.locate(h)",
            )

    def _check_escaping_payload(self, stmts: list[ast.stmt]) -> None:
        """Flag guard-payload names read after their with block closed."""
        for i, stmt in enumerate(stmts):
            if not isinstance(stmt, (ast.With, ast.AsyncWith)):
                continue
            payloads: set[str] = set()
            for item in stmt.items:
                ctx = item.context_expr
                is_guard = isinstance(ctx, ast.Call) and (
                    (isinstance(ctx.func, ast.Attribute) and ctx.func.attr in ("read", "write"))
                    or (isinstance(ctx.func, ast.Name) and ctx.func.id in GUARD_CLASSES)
                )
                if is_guard and isinstance(item.optional_vars, ast.Name):
                    payloads.add(item.optional_vars.id)
            if not payloads:
                continue
            # Aliases derived from the payload inside the with body:
            # `tmp = w.value` / `row = v[i]` make `tmp`/`row` payloads too.
            # Only pure access chains alias the payload — a method call
            # (`result = w.update(fn)`) returns a *new* value, not the
            # guarded snapshot, so it may legitimately outlive the guard.
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Assign) and self._aliases_payload(
                    sub.value, payloads
                ):
                    for tgt in sub.targets:
                        if isinstance(tgt, ast.Name):
                            payloads.add(tgt.id)
            self._scan_after(stmts, i, payloads)

    @staticmethod
    def _aliases_payload(expr: ast.AST, payloads: set[str]) -> bool:
        """True if ``expr`` is a Name/Attribute/Subscript chain rooted at a
        payload name (``w``, ``w.value``, ``v[i]``, ``v[i].field``)."""
        node = expr
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        return isinstance(node, ast.Name) and node.id in payloads

    def _scan_after(
        self, stmts: list[ast.stmt], i: int, payloads: set[str]
    ) -> None:
        """Scan everything that executes after ``stmts[i]`` closes.

        Walks the remainder of the containing block, then climbs the parent
        chain (`if`/`try`/loop bodies) scanning each enclosing remainder —
        a payload escaping the last statement of an ``else:`` branch is
        still dead in the statements after the ``if``.  The climb stops at
        function/class boundaries (escape-by-return is a different rule).
        """
        dead = set(payloads)
        cur_list, idx = stmts, i
        while dead:
            self._scan_block(cur_list[idx + 1 :], dead)
            owner = self.block_parent.get(id(cur_list))
            if owner is None or isinstance(
                owner, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                return
            pos = self.stmt_pos.get(id(owner))
            if pos is None:
                return
            cur_list, idx = pos

    # The scan respects evaluation order: an assignment's RHS is read
    # *before* the target is rebound (so `v = v + 1` after the with is an
    # escape), while a `for v in xs:` rebinds `v` before its body runs (so
    # body uses of `v` are fine).

    def _scan_block(self, stmts: list[ast.stmt], dead: set[str]) -> None:
        for stmt in stmts:
            if not dead:
                return
            self._scan_stmt(stmt, dead)

    def _scan_stmt(self, stmt: ast.stmt, dead: set[str]) -> None:
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            if isinstance(stmt, ast.AugAssign):
                # `v += x` reads the stale payload before rebinding it.
                self._scan_loads([stmt.target, stmt.value], dead, aug=True)
                self._discard_stores([stmt.target], dead)
                return
            if stmt.value is not None:
                self._scan_loads([stmt.value], dead)
            targets = stmt.targets if isinstance(stmt, ast.Assign) else [stmt.target]
            self._discard_stores(targets, dead)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_loads([stmt.iter], dead)
            self._discard_stores([stmt.target], dead)
            self._scan_block(stmt.body, dead)
            self._scan_block(stmt.orelse, dead)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._scan_loads([item.context_expr], dead)
                if item.optional_vars is not None:
                    self._discard_stores([item.optional_vars], dead)
            self._scan_block(stmt.body, dead)
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            # A nested def shadows nothing here reliably; skip its body but
            # treat default-value expressions as loads.
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._scan_loads(
                    [*stmt.args.defaults, *[d for d in stmt.args.kw_defaults if d]],
                    dead,
                )
            dead.discard(stmt.name)
        else:
            exprs: list[ast.AST] = []
            blocks: list[list[ast.stmt]] = []
            for field, value in ast.iter_fields(stmt):
                if field in ("body", "orelse", "finalbody"):
                    blocks.append(value)
                elif field == "handlers":
                    for h in value:
                        if h.type is not None:
                            exprs.append(h.type)
                        blocks.append(h.body)
                elif isinstance(value, ast.AST):
                    exprs.append(value)
                elif isinstance(value, list):
                    exprs.extend(v for v in value if isinstance(v, ast.AST))
            self._scan_loads(exprs, dead)
            for b in blocks:
                self._scan_block(b, dead)

    def _scan_loads(
        self,
        exprs: list[ast.AST],
        dead: set[str],
        aug: bool = False,
        shadow: frozenset[str] = frozenset(),
    ) -> None:
        for e in exprs:
            if isinstance(e, ast.Lambda):
                # Lambda parameters shadow outer names inside the body;
                # default values evaluate in the enclosing scope.
                self._scan_loads(
                    [*e.args.defaults, *[d for d in e.args.kw_defaults if d]],
                    dead,
                    shadow=shadow,
                )
                params = {
                    a.arg
                    for a in (
                        *e.args.posonlyargs,
                        *e.args.args,
                        *e.args.kwonlyargs,
                        *([e.args.vararg] if e.args.vararg else []),
                        *([e.args.kwarg] if e.args.kwarg else []),
                    )
                }
                self._scan_loads([e.body], dead, shadow=shadow | params)
                continue
            if isinstance(e, (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)):
                inner = frozenset(shadow)
                for gen in e.generators:
                    self._scan_loads([gen.iter], dead, shadow=inner)
                    inner = inner | _names_in(gen.target)
                    self._scan_loads(gen.ifs, dead, shadow=inner)
                body = (
                    [e.key, e.value] if isinstance(e, ast.DictComp) else [e.elt]
                )
                self._scan_loads(body, dead, shadow=inner)
                continue
            queue = [e]
            while queue:
                node = queue.pop()
                if isinstance(
                    node,
                    (ast.Lambda, ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp),
                ):
                    self._scan_loads([node], dead, shadow=shadow)
                    continue
                if isinstance(node, ast.Name) and node.id in dead and node.id not in shadow:
                    if isinstance(node.ctx, ast.Load) or aug:
                        self.report(
                            node,
                            "E102",
                            f"guard payload {node.id!r} read after its with "
                            f"block closed — the snapshot may be stale; "
                            f"re-open a guard or copy inside the block",
                        )
                    dead.discard(node.id)
                queue.extend(ast.iter_child_nodes(node))

    def _discard_stores(self, targets: list[ast.AST], dead: set[str]) -> None:
        for t in targets:
            for node in ast.walk(t):
                if isinstance(node, ast.Name) and isinstance(
                    node.ctx, (ast.Store, ast.Del)
                ):
                    dead.discard(node.id)
                elif isinstance(node, ast.Name) and node.id in dead:
                    # Subscript/attribute store on the payload still reads it.
                    self.report(
                        node,
                        "E102",
                        f"guard payload {node.id!r} written through after its "
                        f"with block closed — mutate inside the guard",
                    )
                    dead.discard(node.id)


def lint_file(path: str | Path) -> list[LintViolation]:
    p = Path(path)
    source = p.read_text()
    try:
        tree = ast.parse(source, filename=str(p))
    except SyntaxError as exc:  # pragma: no cover - corpus files must parse
        return [
            LintViolation(
                file=str(p),
                line=exc.lineno or 1,
                col=(exc.offset or 0) + 1,
                code="E100",
                rule="syntax-error",
                message=str(exc.msg),
            )
        ]
    return _FileLinter(str(p), tree, source).run()


def lint_paths(paths: Iterable[str | Path]) -> list[LintViolation]:
    out: list[LintViolation] = []
    for raw in paths:
        p = Path(raw)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            out.extend(lint_file(f))
    return out
