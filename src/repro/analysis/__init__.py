"""Ownership analysis subsystem: static lint + runtime sanitizer + certifier.

DRust's thesis is that language-level ownership constrains access order
enough to make DSM coherence cheap — but the repo can only *lean on* that
discipline if something checks it.  This package is the checker, in three
cooperating layers:

* ``linter`` — an AST borrow lint over the app-level surface
  (``src/repro/apps/``, ``src/repro/serve/``, ``src/repro/core/sync.py``,
  ``examples/``).  It reports the violations the old CI grep could not
  see: raw protocol-verb call pairs, guard payloads escaping their
  ``with`` scope, ``transfer``/``drop``/``free`` under a syntactically
  live guard, guards opened without ``with``, and handles captured by
  ``spawn`` closures without locality routing.  CLI:
  ``PYTHONPATH=src python -m repro.analysis.lint [--format=github]``.

* ``sanitizer`` — a TSan-style runtime checker enabled by
  ``Cluster(sanitize=True)`` (or ``REPRO_SANITIZE=1``).  It hooks guard
  enter/exit, verb posting, lock acquisition, and cid disposition, and
  verifies balanced borrows (per thread, at ``Scheduler.retire`` /
  ``migrate`` / ``fail_over``), tombstoned payload snapshots, exactly-once
  speculative-cid disposition, and deadlock-free lock acquisition order.
  Violations raise structured ``SanitizerError``s carrying the event
  trace that led to them.  Observation-only: no cost-model charges, no
  verbs — sanitize-off runs stay byte-identical.

* ``races`` — a trace-based coherence race certifier.  It replays the
  sanitizer's event trace and proves the paper's core claim as a
  happens-before check: any two conflicting accesses to a box (or its
  TBox tie root) are ordered by an ownership edge — transfer, write-move,
  ``migrate_here``, lease grant/revoke, or lock hand-off — and every read
  observed the epoch of the latest such ordered write (a replica served
  after its epoch bump trips the certifier).

See ``docs/analysis.md`` for the rule catalogue and the event model.
"""

from .linter import LintViolation, lint_file, lint_paths  # noqa: F401
from .races import RaceError, certify  # noqa: F401
from .sanitizer import Event, Sanitizer, SanitizerError  # noqa: F401
