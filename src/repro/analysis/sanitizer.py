"""Runtime borrow/cid sanitizer — ``Cluster(sanitize=True)``.

A TSan-style checker for the DSM runtime.  It installs as
``backend.sanitizer`` (mirroring the placement tracker) and hooks guard
enter/exit, lock acquisition, lease grant/revoke, ownership transfer,
speculative-cid disposition, and the completion plane's verb stream.

Checks enforced (violations raise :class:`SanitizerError` carrying the
tail of the event trace that led to them):

* **Balanced borrows** — every guard a thread opens is closed by the time
  the thread retires or migrates; ``fail_over`` reconciles the dead
  server's threads (their guards were force-released by recovery, not
  leaked).  Reader leases are *detached* from this accounting: they
  outlive scopes by design and are released by revocation or recovery.
* **Tombstoned payloads** — a ``ReadGuard``'s list/dict payload is served
  as an equal snapshot; using the snapshot after the guard closed raises,
  and a snapshot that was *mutated* under an immutable borrow is reported
  at close.  ``WriteGuard`` payloads are never wrapped (in-place mutation
  must land).
* **Exactly-once speculative-cid disposition** — every cid recorded by
  ``DrustRuntime.prefetch`` is disposed exactly once (``fenced`` /
  ``invalidated`` / ``orphaned-*``), cross-checked against ``spec_log``;
  a disposition for a cid that was never created, or a created cid left
  undisposed with no live owner still referencing it, is an error.
* **Lock acquisition order** — a lockdep-style held→acquired edge graph;
  a cycle (the transactional kvstore's sorted-bucket discipline broken)
  raises before the deadlock can happen.

The sanitizer is **observation only**: it never charges the cost model,
posts no verbs, and never mutates protocol state — a sanitized run's
counters and digests are byte-identical to the same run without it.

The recorded event trace doubles as the input to the coherence race
certifier (:mod:`repro.analysis.races`).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

# Event kinds consumed by the race certifier (races.py); the rest are
# provenance for error reports and debugging.
OPEN_KINDS = {"read_open", "pin_open", "write_open"}
CLOSE_KINDS = {"read_close", "pin_close", "write_close"}


@dataclass
class Event:
    """One sanitizer observation.  ``key`` identifies the synchronization
    object: ``id()`` of the box's placement root (drust: the TBox tie
    root, stable across write-moves) or of the handle itself (baselines),
    or of the lock/rwlock primitive.  ``epoch`` is the box version the
    access observed (bumped at every ``write_close``)."""

    seq: int
    kind: str
    tid: int
    key: int = 0
    epoch: int = 0
    t_us: float = 0.0
    src: int | None = None       # spawn parent / join child / migrate src
    detail: str = ""


class SanitizerError(RuntimeError):
    """An ownership-discipline violation, with event provenance."""

    def __init__(self, message: str, events: list[Event] | None = None):
        self.events = list(events or [])
        if self.events:
            tail = "\n".join(
                f"  #{e.seq} {e.kind} tid={e.tid} key={e.key:#x} "
                f"epoch={e.epoch} t={e.t_us:.1f}us {e.detail}".rstrip()
                for e in self.events[-12:]
            )
            message = f"{message}\nrecent events:\n{tail}"
        super().__init__(message)


# --------------------------------------------------------------------------
#  Tombstoned payload snapshots
# --------------------------------------------------------------------------
class _Cell:
    """Shared closed/adopted flags for one snapshot."""

    __slots__ = ("closed", "adopted", "where")

    def __init__(self) -> None:
        self.closed = False
        self.adopted = False
        self.where = ""


def _check_cell(cell: _Cell) -> None:
    if cell.closed and not cell.adopted:
        raise SanitizerError(
            f"guard payload used after its guard closed ({cell.where}) — "
            f"copy inside the with block or re-open a guard"
        )


class _SnapList(list):
    """List snapshot: equal by content, poisoned at guard close."""

    _san_cell: _Cell

    def _chk(self):
        _check_cell(self._san_cell)

    def __getitem__(self, i):
        self._chk()
        return list.__getitem__(self, i)

    def __iter__(self):
        self._chk()
        return list.__iter__(self)

    def __len__(self):
        self._chk()
        return list.__len__(self)

    def __contains__(self, x):
        self._chk()
        return list.__contains__(self, x)

    def __eq__(self, other):
        self._chk()
        return list.__eq__(self, other)

    __hash__ = None  # type: ignore[assignment]  # lists are unhashable


class _SnapDict(dict):
    """Dict snapshot: equal by content, poisoned at guard close."""

    _san_cell: _Cell

    def _chk(self):
        _check_cell(self._san_cell)

    def __getitem__(self, k):
        self._chk()
        return dict.__getitem__(self, k)

    def get(self, k, default=None):
        self._chk()
        return dict.get(self, k, default)

    def __iter__(self):
        self._chk()
        return dict.__iter__(self)

    def __len__(self):
        self._chk()
        return dict.__len__(self)

    def __contains__(self, k):
        self._chk()
        return dict.__contains__(self, k)

    def items(self):
        self._chk()
        return dict.items(self)

    def keys(self):
        self._chk()
        return dict.keys(self)

    def values(self):
        self._chk()
        return dict.values(self)

    def __eq__(self, other):
        self._chk()
        return dict.__eq__(self, other)

    __hash__ = None  # type: ignore[assignment]


def _snapshot(value: Any, cell: _Cell) -> Any:
    """Shallow snapshot of list/dict payloads (anything else is served
    as-is: scalars are immutable, arrays/objects keep identity)."""
    if type(value) is list or isinstance(value, _SnapList):
        s = _SnapList(list.__iter__(value) if isinstance(value, list) else value)
        s._san_cell = cell
        return s
    if type(value) is dict or isinstance(value, _SnapDict):
        s = _SnapDict(dict.items(value) if isinstance(value, dict) else value)
        s._san_cell = cell
        return s
    return None


def _raw_equal(snap: Any, orig: Any) -> bool:
    """Compare bypassing the poison checks."""
    if isinstance(snap, _SnapList):
        return list(list.__iter__(snap)) == orig
    if isinstance(snap, _SnapDict):
        return dict(dict.items(snap)) == orig
    return True


# --------------------------------------------------------------------------
#  The sanitizer
# --------------------------------------------------------------------------
@dataclass
class _OpenGuard:
    key: int
    kind: str                     # read | pin | write
    event: Event
    handle: Any
    snapshot: Any = None          # _SnapList/_SnapDict or None
    original: Any = None          # the heap value the snapshot cloned
    cell: _Cell | None = None


class Sanitizer:
    """One per ``Cluster(sanitize=True)``; installed as
    ``backend.sanitizer`` and ``sim.tracer``."""

    #: the most recently constructed sanitizer — apps build their Cluster
    #: internally, so callers that want the trace of a run they triggered
    #: (the race-certification tests, ``REPRO_SANITIZE=1`` debugging)
    #: reach it here.
    last: "Sanitizer | None" = None

    def __init__(self, cluster=None) -> None:
        Sanitizer.last = self
        self.cluster = cluster
        self.events: list[Event] = []
        self._seq = 0
        # borrow accounting: tid -> {id(guard): _OpenGuard}
        self.open: dict[int, dict[int, _OpenGuard]] = {}
        self._detached: set[int] = set()
        # box versioning
        self.epoch: dict[int, int] = {}
        self._key_refs: dict[int, Any] = {}   # keep roots alive: ids stay unique
        # speculative-cid ledger
        self.spec_created: dict[int, Event] = {}
        self.spec_disposed: dict[int, str] = {}
        # lockdep
        self.held: dict[int, list[int]] = {}            # tid -> [lock keys]
        self.lock_edges: dict[int, set[int]] = {}       # held-key -> then-key
        self.lock_names: dict[int, str] = {}
        # lease release tracking for the certifier lives in the trace
        # test hook: force the next N read_open events to record a stale
        # epoch (simulates a replica served after its epoch bump — the
        # injected coherence bug the race certifier must catch).
        self.inject_stale_reads = 0

    # ---- trace ----------------------------------------------------------
    @property
    def trace(self) -> list[Event]:
        return self.events

    def _emit(self, kind: str, th=None, key: int = 0, epoch: int = 0,
              src: int | None = None, detail: str = "") -> Event:
        tid = getattr(th, "tid", th if isinstance(th, int) else -1)
        t_us = getattr(th, "t_us", 0.0)
        e = Event(self._seq, kind, tid, key, epoch, t_us, src, detail)
        self._seq += 1
        self.events.append(e)
        return e

    def _err(self, message: str) -> SanitizerError:
        return SanitizerError(message, self.events)

    # ---- keys -----------------------------------------------------------
    def key_of(self, h: Any) -> int:
        """Synchronization key for a handle: the placement root's identity
        (drust — a TBox child conflicts through its tie root, and DBox
        identity is stable across write-moves) or the handle's own."""
        backend = getattr(self.cluster, "backend", None)
        root = h
        pr = getattr(backend, "placement_root", None)
        if pr is not None and hasattr(h, "g"):
            try:
                root = pr(h)
            except Exception:
                root = h
        k = id(root)
        self._key_refs[k] = root
        return k

    # ---- guard hooks (called from core/protocol.py) ---------------------
    def on_read_enter(self, guard, value: Any, pin: bool = False) -> Any:
        key = self.key_of(guard.h)
        epoch = self.epoch.get(key, 0)
        if self.inject_stale_reads > 0 and epoch > 0 and not pin:
            self.inject_stale_reads -= 1
            epoch -= 1          # the bug: replica content from before the bump
        evt = self._emit("pin_open" if pin else "read_open", guard.th, key,
                         epoch=epoch)
        cell = _Cell()
        cell.where = f"read guard opened at event #{evt.seq}"
        snap = _snapshot(value, cell)
        og = _OpenGuard(key, "pin" if pin else "read", evt, guard.h,
                        snapshot=snap, original=value, cell=cell)
        self.open.setdefault(evt.tid, {})[id(guard)] = og
        return value if snap is None else snap

    def on_write_enter(self, guard) -> None:
        key = self.key_of(guard.h)
        evt = self._emit("write_open", guard.th, key,
                         epoch=self.epoch.get(key, 0))
        og = _OpenGuard(key, "write", evt, guard.h)
        self.open.setdefault(evt.tid, {})[id(guard)] = og

    def on_guard_close(self, guard, kind: str) -> None:
        tid = getattr(guard.th, "tid", -1)
        og = self.open.get(tid, {}).pop(id(guard), None)
        if og is None and id(guard) in self._detached:
            self._detached.discard(id(guard))
            key = self.key_of(guard.h)
            self._emit("lease_close", guard.th, key,
                       epoch=self.epoch.get(key, 0))
            return
        if og is None:
            raise self._err(
                f"{kind} guard closed that the sanitizer never saw open "
                f"(double close after abandon, or a guard from another run)")
        if og.kind == "write":
            new_epoch = self.epoch.get(og.key, 0) + 1
            self.epoch[og.key] = new_epoch
            self._emit("write_close", guard.th, og.key, epoch=new_epoch)
        else:
            self._emit(f"{og.kind}_close", guard.th, og.key,
                       epoch=self.epoch.get(og.key, 0))
            if og.cell is not None:
                og.cell.closed = True
                og.cell.where = (
                    f"guard opened at event #{og.event.seq}, "
                    f"closed at event #{self._seq - 1}")
            if og.snapshot is not None and not _raw_equal(og.snapshot,
                                                          og.original):
                raise self._err(
                    "payload mutated under an immutable read borrow — "
                    "writes require a write guard")

    def adopt(self, data: Any) -> Any:
        """A guard payload snapshot is being *stored* (``w.set(v)`` /
        ``w.update`` staging): hand the heap a plain equal copy so the
        stored value never carries a poisonable wrapper — storing a
        snapshot is publication, not use-after-close."""
        if isinstance(data, _SnapList):
            return list(list.__iter__(data))
        if isinstance(data, _SnapDict):
            return dict(dict.items(data))
        return data

    def on_guard_abandon(self, guard) -> None:
        """Recovery abandoned the guard: accounting settled by the
        fail-over ledger, not by a close — just drop the tracking."""
        tid = getattr(guard.th, "tid", -1)
        og = self.open.get(tid, {}).pop(id(guard), None)
        self._detached.discard(id(guard))
        if og is not None:
            self._emit("guard_abandon", guard.th, og.key)
            if og.cell is not None:
                og.cell.closed = True
                og.cell.where = "guard abandoned by recovery"

    def detach_guard(self, guard) -> None:
        """A reader lease's pinned guard deliberately outlives lexical
        scope and its granting thread; exempt it from borrow balance."""
        tid = getattr(guard.th, "tid", -1)
        og = self.open.get(tid, {}).pop(id(guard), None)
        self._detached.add(id(guard))
        key = og.key if og is not None else self.key_of(guard.h)
        self._emit("lease_grant", guard.th, key,
                   epoch=self.epoch.get(key, 0))

    # ---- thread lifecycle (called from core/runtime.py) -----------------
    def note_spawn(self, parent, child) -> None:
        self._emit("spawn", child, src=getattr(parent, "tid", None))

    def note_join(self, child, waiter) -> None:
        self._emit("join", waiter, src=getattr(child, "tid", None))

    def check_thread(self, th, where: str, detail: str = "") -> None:
        """Balanced-borrow checkpoint (retire / migrate)."""
        tid = getattr(th, "tid", -1)
        leaked = self.open.get(tid, {})
        if leaked:
            kinds = ", ".join(
                f"{og.kind} guard on key {og.key:#x} "
                f"(opened at event #{og.event.seq})"
                for og in leaked.values())
            raise self._err(
                f"thread {tid} {where}d with {len(leaked)} live guard(s): "
                f"{kinds}")
        self._emit(where, th, detail=detail)

    def on_failover(self, dead_tids) -> None:
        """Recovery force-released the dead threads' borrows; settle their
        accounting here so survivors still balance."""
        for tid in dead_tids:
            for og in self.open.pop(tid, {}).values():
                if og.cell is not None:
                    og.cell.closed = True
                    og.cell.where = "guard's thread died (fail_over)"
            self.held.pop(tid, None)     # broken locks: recovery released
        self._emit("failover", -1, detail=f"dead tids {sorted(dead_tids)}")

    # ---- ownership edges (called from core/ownership.py) ----------------
    def note_transfer(self, th, box, dst: int) -> None:
        key = self.key_of(box)
        self._emit("transfer", th, key, epoch=self.epoch.get(key, 0),
                   detail=f"-> server {dst}")

    def note_migrate_here(self, th, box) -> None:
        key = self.key_of(box)
        self._emit("migrate_here", th, key, epoch=self.epoch.get(key, 0))

    # ---- speculative cids (called from core/ownership.py) ---------------
    def note_spec(self, th, cid: int) -> None:
        self.spec_created[cid] = self._emit("spec_post", th, detail=f"cid {cid}")

    def note_spec_dispose(self, cid: int, how: str, fresh: bool) -> None:
        """``fresh`` is ``_dispose_spec``'s return: False means the cid was
        already disposed and this call was the idempotent no-op path."""
        if not fresh:
            return
        if cid not in self.spec_created:
            raise self._err(
                f"speculative cid {cid} disposed ({how}) but never created "
                f"by prefetch — phantom disposition")
        if cid in self.spec_disposed:
            raise self._err(
                f"speculative cid {cid} disposed twice "
                f"({self.spec_disposed[cid]}, then {how})")
        self.spec_disposed[cid] = how
        self._emit("spec_dispose", -1, detail=f"cid {cid}: {how}")

    def check_spec_ledger(self) -> None:
        """Exactly-once cross-check vs ``DrustRuntime.spec_log``: every
        created cid is disposed, or still pending with a live owner whose
        ``fetch_cid`` references it (a prefetch not yet used)."""
        rt = getattr(self.cluster, "drust", None)
        if rt is not None:
            log = rt.spec_log
            for cid in self.spec_disposed:
                if cid not in log:
                    raise self._err(
                        f"sanitizer saw cid {cid} disposed but spec_log "
                        f"has no record — ledgers diverged")
            for cid, how in log.items():
                if cid in self.spec_created and cid not in self.spec_disposed:
                    raise self._err(
                        f"spec_log disposed cid {cid} ({how}) without the "
                        f"sanitizer hook firing — unhooked disposition path")
        pending = set(self.spec_created) - set(self.spec_disposed)
        if not pending:
            return
        live = set()
        if rt is not None:
            for box in rt.owner_of.values():
                if box.fetch_cid:
                    live.add(box.fetch_cid)
        leaked = pending - live
        if leaked:
            raise self._err(
                f"speculative cid(s) {sorted(leaked)} neither disposed nor "
                f"referenced by any live owner — leaked prefetch")

    # ---- locks (called from core/sync.py) -------------------------------
    def note_lock_acquire(self, th, lock, name: str = "") -> None:
        key = id(lock)
        self._key_refs[key] = lock
        self.lock_names.setdefault(key, name or type(lock).__name__)
        tid = getattr(th, "tid", -1)
        held = self.held.setdefault(tid, [])
        for h in held:
            if h == key:
                raise self._err(
                    f"thread {tid} re-acquired {self.lock_names[key]} "
                    f"{key:#x} it already holds")
            self.lock_edges.setdefault(h, set()).add(key)
        # lockdep: adding h->key for every held h creates a deadlock iff a
        # path key ->* h already exists for some held h.
        for h in held:
            path = self._lock_path(key, h)
            if path:
                names = " -> ".join(
                    self.lock_names.get(k, hex(k)) for k in [h, *path])
                raise self._err(
                    f"lock acquisition order inverted (deadlock): thread "
                    f"{tid} holds {self.lock_names.get(h, hex(h))} and "
                    f"acquires {self.lock_names.get(key, hex(key))}, but the "
                    f"reverse order was also observed ({names}) — acquire "
                    f"in a global (sorted) order")
        held.append(key)
        self._emit("lock_acquire", th, key)

    def note_lock_release(self, th, lock) -> None:
        key = id(lock)
        tid = getattr(th, "tid", -1)
        held = self.held.get(tid, [])
        if key in held:
            held.remove(key)
        self._emit("lock_release", th, key)

    def _lock_path(self, start: int, goal: int) -> list[int] | None:
        """DFS: a path start ->* goal through recorded order edges."""
        stack: list[tuple[int, list[int]]] = [(start, [start])]
        seen = {start}
        while stack:
            node, path = stack.pop()
            if node == goal:
                return path
            for nxt in self.lock_edges.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    # ---- leases (called from core/sync.py DRwLock) ----------------------
    def note_lease_revoke(self, th, rwlock_h) -> None:
        key = self.key_of(rwlock_h)
        self._emit("lease_revoke", th, key, epoch=self.epoch.get(key, 0))

    # ---- completion-plane tracer (installed as Sim.tracer) --------------
    def note_post(self, th, cid: int, dst: int, nbytes: int, kind: str,
                  is_read: bool = False) -> None:
        self._emit("verb_post", th,
                   detail=f"cid {cid} {'READ' if is_read else 'WRITE'} "
                          f"{kind} {nbytes}B -> s{dst}")

    def note_fence(self, th, upto: int) -> None:
        self._emit("fence", th, detail=f"upto cid {upto}")

    def note_forget(self, tid: int) -> None:
        self._emit("forget", tid)

    def note_orphans(self, cids) -> None:
        self._emit("orphan", -1, detail=f"cids {sorted(cids)}")

    # ---- end-of-run -----------------------------------------------------
    def final_check(self) -> None:
        """Quiescence checkpoint (``Cluster.makespan_us``): the spec-cid
        ledger must balance.  Open guards are legal here — the caller may
        measure mid-run — so borrow balance is only enforced at thread
        checkpoints."""
        self.check_spec_ledger()
