"""CLI: ``python -m repro.analysis.lint [paths...] [--format=text|github|json]``.

With no paths, lints the default app-level surface (``src/repro/apps``,
``src/repro/serve``, ``src/repro/core/sync.py``, ``examples``) resolved
relative to the repository root.  Exits 1 if any violation is reported.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .linter import lint_paths


def default_targets() -> list[Path]:
    # src/repro/analysis/lint.py -> repo root is three parents above src/.
    root = Path(__file__).resolve().parents[3]
    targets = [
        root / "src" / "repro" / "apps",
        root / "src" / "repro" / "serve",
        root / "src" / "repro" / "core" / "sync.py",
        root / "examples",
    ]
    return [t for t in targets if t.exists()]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="AST borrow lint for the guard-API app surface.",
    )
    ap.add_argument("paths", nargs="*", help="files or directories (default: app surface)")
    ap.add_argument(
        "--format",
        choices=("text", "github", "json"),
        default="text",
        help="text (default), github (workflow annotations), or json",
    )
    args = ap.parse_args(argv)

    paths = [Path(p) for p in args.paths] or default_targets()
    violations = lint_paths(paths)

    if args.format == "json":
        print(
            json.dumps(
                [v.__dict__ for v in violations], indent=2, sort_keys=True
            )
        )
    else:
        for v in violations:
            print(v.format(args.format))
        n = len(violations)
        tail = f"{n} violation{'s' if n != 1 else ''}"
        print(f"repro.analysis.lint: {tail} in {len(paths)} target(s)", file=sys.stderr)
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
