"""Trace-based coherence race certifier.

Replays a sanitizer event trace (:class:`repro.analysis.sanitizer.Event`)
and proves the paper's core claim as a happens-before check: **any two
conflicting accesses to a box (or its TBox tie root) are ordered by an
ownership edge** — a transfer, a write-move, ``migrate_here``, a lease
grant/revoke, or a lock hand-off — and every access observed the epoch
produced by the latest such ordered write.

Mechanics (vector clocks, release/acquire):

* Each thread carries a vector clock ``vc[tid]``, ticked per event.
* ``spawn``/``join`` join parent/child clocks; ``lock_acquire`` joins the
  lock's release clock (the hand-off edge); ``lease_grant`` joins the
  guarded box's release clock; ``lease_revoke`` joins the accumulated
  lease holders' clocks into the box's release clock.
* Every ``write_close`` bumps the box's epoch and publishes the writer's
  clock as the box's *release* clock; ``transfer`` and ``migrate_here``
  publish the mover's clock the same way (ownership hand-offs are
  release points even without a data write).
* Every ``read_open``/``write_open`` carries the epoch the access
  *observed*.  Observing the current epoch is the recorded form of the
  ownership edge — the protocol synchronized this access with the owner
  of that version — so the opener **acquires** the box's release clock.
  An access that observed an older epoch has no such edge: that is a
  replica served after its epoch bump, and certification fails.
* After acquiring, the opener's clock must dominate the box's last-write
  clock (write opens must also dominate the accumulated read clock), and
  no conflicting guard may be concurrently open — either failure is an
  unordered conflicting access.

``certify`` returns a :class:`Certificate` on success and raises
:class:`RaceError` (with the offending events) on the first violation.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .sanitizer import Event

_READ_OPEN = {"read_open", "pin_open"}
_CLOSE_OF = {"read_open": "read_close", "pin_open": "pin_close",
             "write_open": "write_close"}


class RaceError(RuntimeError):
    """Two conflicting accesses with no ordering ownership edge."""

    def __init__(self, message: str, events: list[Event] | None = None):
        self.events = list(events or [])
        if self.events:
            tail = "\n".join(
                f"  #{e.seq} {e.kind} tid={e.tid} key={e.key:#x} "
                f"epoch={e.epoch} {e.detail}".rstrip()
                for e in self.events
            )
            message = f"{message}\nevidence:\n{tail}"
        super().__init__(message)


@dataclass
class Certificate:
    """Proof summary for a certified trace."""

    events: int = 0
    boxes: int = 0
    reads: int = 0
    writes: int = 0
    edges: int = 0          # ownership edges that ordered conflicts
    threads: int = 0

    def __str__(self) -> str:  # pragma: no cover - debugging nicety
        return (f"certified: {self.events} events, {self.boxes} boxes, "
                f"{self.reads} reads / {self.writes} writes ordered by "
                f"{self.edges} ownership edges across {self.threads} threads")


def _dominates(a: dict[int, int], b: dict[int, int]) -> bool:
    """True iff clock ``a`` >= clock ``b`` componentwise."""
    return all(a.get(t, 0) >= n for t, n in b.items())


def _join(into: dict[int, int], other: dict[int, int]) -> None:
    for t, n in other.items():
        if into.get(t, 0) < n:
            into[t] = n


@dataclass
class _Box:
    epoch: int = 0
    release: dict[int, int] = field(default_factory=dict)  # last hand-off
    wvc: dict[int, int] = field(default_factory=dict)      # last write_close
    rvc: dict[int, int] = field(default_factory=dict)      # joined read_closes
    lease_rel: dict[int, int] = field(default_factory=dict)
    open_read: dict[int, Event] = field(default_factory=dict)   # tid -> open
    open_write: tuple[int, Event] | None = None
    last_write: Event | None = None


def certify(trace: list[Event]) -> Certificate:
    """Replay ``trace``; raise :class:`RaceError` on the first unordered
    conflicting access, else return a :class:`Certificate`."""
    vc: dict[int, dict[int, int]] = {}
    boxes: dict[int, _Box] = {}
    lock_rel: dict[int, dict[int, int]] = {}
    cert = Certificate(events=len(trace))

    def clock(tid: int) -> dict[int, int]:
        c = vc.get(tid)
        if c is None:
            c = vc[tid] = {tid: 0}
        return c

    def tick(tid: int) -> dict[int, int]:
        c = clock(tid)
        c[tid] = c.get(tid, 0) + 1
        return c

    for e in trace:
        kind = e.kind
        if kind in _READ_OPEN or kind == "write_open":
            c = tick(e.tid)
            box = boxes.setdefault(e.key, _Box())
            # -- epoch consistency: the recorded ownership edge ----------
            if e.epoch != box.epoch:
                raise RaceError(
                    f"{'stale replica' if e.epoch < box.epoch else 'phantom epoch'}: "
                    f"tid {e.tid} {kind} on key {e.key:#x} observed epoch "
                    f"{e.epoch} but the last ordered write produced epoch "
                    f"{box.epoch} — no ownership edge orders this access",
                    [x for x in (box.last_write, e) if x is not None])
            _join(c, box.release)        # acquire the hand-off edge
            if box.release:
                cert.edges += 1
            # -- direct conflict: overlapping guards ---------------------
            if box.open_write is not None and box.open_write[0] != e.tid:
                raise RaceError(
                    f"conflicting open guards: tid {e.tid} {kind} while tid "
                    f"{box.open_write[0]}'s write guard is open on key "
                    f"{e.key:#x}", [box.open_write[1], e])
            if kind == "write_open":
                others = [t for t in box.open_read if t != e.tid]
                if others:
                    raise RaceError(
                        f"conflicting open guards: tid {e.tid} write_open "
                        f"while tid {others[0]}'s read guard is open on key "
                        f"{e.key:#x}", [box.open_read[others[0]], e])
            # -- happens-before: the access must see the last write ------
            if not _dominates(c, box.wvc):
                raise RaceError(
                    f"unordered conflicting access: tid {e.tid} {kind} on "
                    f"key {e.key:#x} does not happen-after the last write",
                    [x for x in (box.last_write, e) if x is not None])
            if kind == "write_open":
                if not _dominates(c, box.rvc):
                    raise RaceError(
                        f"unordered write: tid {e.tid} write_open on key "
                        f"{e.key:#x} does not happen-after prior reads",
                        [e])
                box.open_write = (e.tid, e)
                cert.writes += 1
            else:
                box.open_read[e.tid] = e
                cert.reads += 1

        elif kind in ("read_close", "pin_close", "lease_close"):
            c = tick(e.tid)
            box = boxes.setdefault(e.key, _Box())
            box.open_read.pop(e.tid, None)
            _join(box.rvc, c)
            _join(box.release, c)        # a reader release is a hand-off too

        elif kind == "write_close":
            c = tick(e.tid)
            box = boxes.setdefault(e.key, _Box())
            if box.open_write is not None and box.open_write[0] == e.tid:
                box.open_write = None
            box.epoch = e.epoch
            box.wvc = dict(c)
            box.release = dict(c)        # publish: the ownership hand-off
            box.last_write = e

        elif kind in ("transfer", "migrate_here"):
            c = tick(e.tid)
            box = boxes.setdefault(e.key, _Box())
            _join(c, box.release)        # mover synchronizes with the owner
            box.release = dict(c)
            cert.edges += 1

        elif kind == "lease_grant":
            c = tick(e.tid)
            box = boxes.setdefault(e.key, _Box())
            _join(c, box.release)        # grant pays the cold read: acquire
            _join(box.lease_rel, c)
            cert.edges += 1

        elif kind == "lease_revoke":
            c = tick(e.tid)
            box = boxes.setdefault(e.key, _Box())
            _join(c, box.lease_rel)      # writer collects the lease holders
            box.lease_rel = {}
            _join(box.release, c)
            cert.edges += 1

        elif kind == "lock_acquire":
            c = tick(e.tid)
            _join(c, lock_rel.get(e.key, {}))
            if lock_rel.get(e.key):
                cert.edges += 1

        elif kind == "lock_release":
            c = tick(e.tid)
            lock_rel[e.key] = dict(c)

        elif kind == "spawn":
            c = tick(e.tid)
            if e.src is not None:
                _join(c, clock(e.src))

        elif kind == "join":
            c = tick(e.tid)
            if e.src is not None:
                _join(c, clock(e.src))

        elif kind == "guard_abandon":
            box = boxes.setdefault(e.key, _Box())
            box.open_read.pop(e.tid, None)
            if box.open_write is not None and box.open_write[0] == e.tid:
                box.open_write = None

        elif kind == "failover":
            # recovery force-released the dead threads' borrows: any guard
            # still open for a tid we never see again is settled there.
            for box in boxes.values():
                if box.open_write is not None:
                    box.open_write = None
                box.open_read.clear()

        # verb_post / fence / forget / spec_* / retire / migrate events are
        # provenance; they do not move the happens-before frontier.

    cert.boxes = len(boxes)
    cert.threads = len(vc)
    return cert
