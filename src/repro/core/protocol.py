"""The backend-generic protocol surface: ``ProtocolBackend`` + scoped guards.

The paper's thesis is that *exposing ownership semantics to the runtime* is
what makes DSM coherence cheap.  This module is where that exposure happens
at the API level:

* ``ProtocolBackend`` — the single ABC every protocol engine implements
  (``DrustRuntime``, ``GamBackend``, ``GrappaBackend``).  Verbs:
  ``alloc`` / ``read`` / ``write`` / ``update`` / ``transfer`` / ``drop`` /
  ``read_many`` / ``prefetch``.  Applications written against this surface
  (or against the guards below) are backend-generic — the drust-only
  special cases collapse into the ``supports_*`` capability flags.

* ``ReadGuard`` / ``WriteGuard`` — RAII scoped borrows.  ``with
  box.read(th) as v:`` *is* the borrow lifetime: entering takes the borrow
  and dereferences, the body sees the payload, exiting drops the borrow
  (and, for writes, performs the write-back).  Because the scope is
  lexical, the runtime is *told* the settle point instead of having to
  infer it, and an exception inside the body structurally releases the
  borrow — unbalanced-drop leaks are impossible by construction.

* ``Region`` — ``with cluster.region(th) as r:`` — a batching scope whose
  exit is a coalescer settle point: the thread's registered derefs flush
  as ``read_many`` doorbells and its staged channel sends ring, exactly
  the work touched inside the scope.  Entry accepts ``r.prefetch(boxes)``
  (speculative read doorbells) and ``r.pin(boxes)`` (region-lifetime
  immutable borrows that keep cache copies pinned) hints.

Cost discipline: the guards charge **exactly** what the legacy
``borrow()``/``deref()``/``drop_ref()`` call pairs charged — enter defers
every deref cost to first use (``.value`` / ``.set``), so the legacy verbs
reimplemented as thin shims *on top of* the guards stay byte-identical to
the PR-1/PR-4 golden traces.

Python has no borrow checker, so misuse is caught dynamically: a write
guard inside a read guard raises ``BorrowError`` on every backend (the
ownership backend enforces it through real borrows; the directory and
delegation backends through the guard layer's per-handle borrow counts),
and using a guard's payload accessor after exit raises ``BorrowError``.
"""

from __future__ import annotations

import abc
from typing import Any, Callable

from . import addr as A


class BorrowError(RuntimeError):
    """A program the Rust borrow checker would have rejected."""


_MISSING = object()          # sentinel: "not staged / not fetched yet"


def _bump_guard_stat(backend, key: str) -> None:
    """Count guard entries on the backend (``backend.guard_stats``).

    Lazy per-backend dict so every engine gets the counters without any
    subclass opt-in; a ``__slots__`` backend simply goes uncounted.  The
    counters are observability only (serve ``stats()``, debugging) — they
    are never charged to the cost model and never gated.
    """
    stats = getattr(backend, "guard_stats", None)
    if stats is None:
        try:
            stats = backend.guard_stats = {
                "read_guards": 0, "write_guards": 0, "regions": 0, "pins": 0}
        except AttributeError:               # pragma: no cover - __slots__
            return
    stats[key] = stats.get(key, 0) + 1


def _note_open(backend, tid: int, kind: str, delta: int) -> None:
    """Open-guard accounting: a gauge per guard kind in ``guard_stats``
    (``open_read_guards``/``open_write_guards``/``open_pins``/
    ``open_regions``) plus a per-thread count (``backend.open_by_tid``) so
    ``Scheduler.retire`` can warn on a thread leaving with live guards.
    Observability only — never charged, never gated."""
    stats = getattr(backend, "guard_stats", None)
    if stats is not None:
        k = "open_" + kind
        n = stats.get(k, 0) + delta
        stats[k] = n if n > 0 else 0
    m = getattr(backend, "open_by_tid", None)
    if m is None:
        try:
            m = backend.open_by_tid = {}
        except AttributeError:               # pragma: no cover - __slots__
            return
    n = m.get(tid, 0) + delta
    if n > 0:
        m[tid] = n
    else:
        m.pop(tid, None)


def detach_guard(g: "ReadGuard") -> None:
    """Exempt a deliberately scope-escaping guard (a reader lease's pinned
    copy — see ``core/sync.py``) from open-guard accounting: the lease is
    released by writer revocation or recovery, not by the granting
    thread's scope, so it must not count as a leak at retire."""
    g._detached = True
    _note_open(g.backend, getattr(g.th, "tid", -1),
               "pins" if g._pin else "read_guards", -1)
    san = g.backend.sanitizer
    if san is not None:
        san.detach_guard(g)


# --------------------------------------------------------------------------
#  Backend registry (capability lookup without string special-casing)
# --------------------------------------------------------------------------
_REGISTRY: dict[str, type] = {}


def register_backend(cls):
    """Class decorator: make ``cls`` discoverable by its ``name``."""
    _REGISTRY[cls.name] = cls
    return cls


def backend_class(name: str) -> type:
    """The ``ProtocolBackend`` subclass registered under ``name``."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r} "
                         f"(registered: {sorted(_REGISTRY)})") from None


def backend_caps(name: str) -> type:
    """Capability view of a backend (the class itself: ``supports_*`` are
    class attributes, so no instance is needed to consult them)."""
    return backend_class(name)


# --------------------------------------------------------------------------
#  The ABC
# --------------------------------------------------------------------------
class ProtocolBackend(abc.ABC):
    """One DSM protocol engine; every verb charges its own cost model.

    Subclasses override the ``_enter_*``/``_write_*`` guard hooks when the
    protocol has real borrow state (DRust); the defaults here implement
    guard semantics for cache/delegation protocols by tracking per-handle
    borrow counts in the guard layer itself, so borrow-misuse raises
    ``BorrowError`` uniformly across backends.
    """

    name: str = "?"
    # Capability flags — what the apps used to special-case on the backend
    # name string.  Ownership = borrow lifetimes are protocol input.
    supports_ownership = False
    supports_affinity = False      # tie_to / TBox groups
    supports_prefetch = False      # speculative fetch is staleness-safe
    supports_coalescing = False    # runtime deref coalescer can register
    # Access-locality tracker (``core/runtime.py`` PlacementTracker),
    # installed by ``Cluster(placement="auto")``.  None = placement off:
    # the guards skip telemetry entirely, so the default path stays
    # byte-identical to the static-placement golden traces.
    placement = None
    # Runtime borrow/cid sanitizer (``repro.analysis.sanitizer``),
    # installed by ``Cluster(sanitize=True)``.  None = sanitize off: the
    # guards skip the hooks entirely — observation only, byte-identical
    # counters either way.
    sanitizer = None

    # ---- verbs ----------------------------------------------------------
    @abc.abstractmethod
    def alloc(self, th, size: int, data: Any = None, server: int | None = None,
              tie_to=None):
        """Allocate a global object; returns a handle."""

    @abc.abstractmethod
    def read(self, th, h) -> Any:
        """Whole-object immutable read (borrow + deref + drop)."""

    @abc.abstractmethod
    def write(self, th, h, data: Any) -> None:
        """Whole-object write (mutable borrow + deref_mut + drop)."""

    @abc.abstractmethod
    def read_many(self, th, handles) -> list:
        """Batched immutable read: cold misses coalesce per source server."""

    def update(self, th, h, fn: Callable[[Any], Any]) -> Any:
        """Read-modify-write through one write guard."""
        with WriteGuard(self, th, h) as w:
            return w.update(fn)

    def transfer(self, th, h, dst_server: int) -> None:
        """Ownership transfer.  Only meaningful where ownership exists —
        the default is a no-op (directory/delegation protocols have no
        owner to move; placement is fixed by the home node)."""
        return None

    @abc.abstractmethod
    def drop(self, th, h) -> None:
        """Drop the handle out of scope: dealloc + invalidation."""

    def free(self, th, h) -> None:
        """Legacy alias for ``drop``."""
        self.drop(th, h)

    def prefetch(self, th, handles) -> int:
        """Speculative fetch; only staleness-safe with ownership — the
        default posts nothing (apps run unmodified)."""
        return 0

    def locate(self, h) -> int:
        """Server currently hosting ``h``'s payload — the data-affinity
        placement target (``Scheduler.spawn_to`` resolves through this,
        never through the allocation-time home).  The default reads the
        handle's global address, which is exact for fixed-home protocols
        (GAM/Grappa never move data); ownership backends override to track
        write-moves and transfers."""
        return A.server_of(h.g if hasattr(h, "g") else h.raw)

    # ---- guard hooks (default: guard-layer borrow tracking) -------------
    def _enter_read(self, th, h):
        """Take the read borrow and deref; returns (release-token, value)."""
        if getattr(h, "live_mut", False):
            raise BorrowError(
                f"{self.name}: read guard while write guard alive")
        val = self.read(th, h)     # may raise: borrow only counted on success
        h.live_refs = getattr(h, "live_refs", 0) + 1
        return True, val

    def _exit_read(self, th, h, token) -> None:
        if token:
            h.live_refs -= 1

    def _enter_pin(self, th, h):
        """Region-lifetime pin: like a read borrow, but must hold a *real*
        borrow / pinned cache copy for the whole scope — never deferred to
        a coalescer (a registration can be flushed by a conflicting write,
        which would silently drop the pin's exclusion guarantee)."""
        return self._enter_read(th, h)

    def _enter_write(self, th, h):
        """Take the write borrow; returns the write token.  No deref cost
        is charged here — ``.value``/``.set`` charge lazily, so the legacy
        ``write``/``update`` shims cost exactly what they always did."""
        if getattr(h, "live_mut", False) or getattr(h, "live_refs", 0):
            raise BorrowError(
                f"{self.name}: write guard while other guards alive")
        h.live_mut = True
        return {"staged": _MISSING, "seen": _MISSING}

    def _write_value(self, th, h, token) -> Any:
        if token["staged"] is not _MISSING:
            return token["staged"]
        if token["seen"] is _MISSING:
            token["seen"] = self.read(th, h)      # charged like any read
        return token["seen"]

    def _write_set(self, th, h, token, data: Any) -> None:
        token["staged"] = data

    def _exit_write(self, th, h, token) -> None:
        h.live_mut = False
        if token["staged"] is not _MISSING:
            self.write(th, h, token["staged"])    # the write-back
        elif token["seen"] is not _MISSING:
            self.write(th, h, token["seen"])      # in-place mutation lands


# --------------------------------------------------------------------------
#  Scoped guards
# --------------------------------------------------------------------------
class ReadGuard:
    """``with h.read(th) as v:`` — enter = immutable borrow + deref,
    body = payload, exit = drop.  ``guard.value`` re-reads the payload and
    raises ``BorrowError`` once the guard has exited.  ``pin=True`` (used
    by ``Region.pin``) forces a real held borrow even where a plain read
    would defer to the coalescer."""

    __slots__ = ("backend", "th", "h", "_token", "_value", "_state", "_pin",
                 "_detached")

    def __init__(self, backend: ProtocolBackend, th, h, pin: bool = False):
        self.backend, self.th, self.h = backend, th, h
        self._pin = pin
        self._detached = False                 # lease guards: see detach_guard
        self._state = "new"                    # new | open | closed

    def __enter__(self):
        if self._state != "new":
            raise BorrowError("read guard re-entered")
        enter = (self.backend._enter_pin if self._pin
                 else self.backend._enter_read)
        self._token, self._value = enter(self.th, self.h)
        self._state = "open"
        _bump_guard_stat(self.backend, "pins" if self._pin else "read_guards")
        _note_open(self.backend, getattr(self.th, "tid", -1),
                   "pins" if self._pin else "read_guards", +1)
        san = self.backend.sanitizer
        if san is not None:
            self._value = san.on_read_enter(self, self._value, pin=self._pin)
        return self._value

    @property
    def value(self) -> Any:
        if self._state != "open":
            raise BorrowError("payload used outside the guard scope")
        return self._value

    def close(self) -> None:
        if self._state != "open":
            return
        self._state = "closed"
        self._value = None
        self.backend._exit_read(self.th, self.h, self._token)
        if not self._detached:
            _note_open(self.backend, getattr(self.th, "tid", -1),
                       "pins" if self._pin else "read_guards", -1)
        san = self.backend.sanitizer
        if san is not None:
            san.on_guard_close(self, "read")
        pl = self.backend.placement
        if pl is not None:
            # Guard exit is the telemetry point: the borrow just released,
            # so a triggered owner migration can never race a live borrow
            # from this guard.
            pl.note_access(self.th, self.h)

    def _abandon(self) -> None:
        """Recovery-only: retire the guard WITHOUT releasing the borrow.
        Fail-over force-releases every borrow held by a dead server's
        threads while reconstructing lock/lease state; a later ``close()``
        on such a guard would double-decrement a count the recovery ledger
        already settled.  Never call outside ``core/fault.py``-driven
        lease/lock breaking."""
        if self._state != "open":
            return
        self._state = "closed"
        self._value = None
        if not self._detached:
            _note_open(self.backend, getattr(self.th, "tid", -1),
                       "pins" if self._pin else "read_guards", -1)
        san = self.backend.sanitizer
        if san is not None:
            san.on_guard_abandon(self)

    def __exit__(self, *exc):
        self.close()
        return False


class WriteGuard:
    """``with h.write(th) as w:`` — enter = exclusive borrow, exit = drop +
    write-back.  The body mutates through the slot: ``w.value`` derefs the
    payload (mutating it in place works for heap-backed protocols and is
    written back at exit for caching ones), ``w.set(data)`` replaces it,
    ``w.update(fn)`` is read-modify-write.  All three raise ``BorrowError``
    after exit.  An exception inside the body still releases the borrow
    and flushes the write-back exactly once — RAII, not convention."""

    __slots__ = ("backend", "th", "h", "_token", "_state")

    def __init__(self, backend: ProtocolBackend, th, h):
        self.backend, self.th, self.h = backend, th, h
        self._state = "new"

    def __enter__(self) -> "WriteGuard":
        if self._state != "new":
            raise BorrowError("write guard re-entered")
        self._token = self.backend._enter_write(self.th, self.h)
        self._state = "open"
        _bump_guard_stat(self.backend, "write_guards")
        _note_open(self.backend, getattr(self.th, "tid", -1),
                   "write_guards", +1)
        san = self.backend.sanitizer
        if san is not None:
            san.on_write_enter(self)
        return self

    def _check_open(self):
        if self._state != "open":
            raise BorrowError("write slot used outside the guard scope")

    @property
    def value(self) -> Any:
        self._check_open()
        return self.backend._write_value(self.th, self.h, self._token)

    def set(self, data: Any) -> None:
        self._check_open()
        san = self.backend.sanitizer
        if san is not None:
            data = san.adopt(data)
        self.backend._write_set(self.th, self.h, self._token, data)

    def update(self, fn: Callable[[Any], Any]) -> Any:
        self._check_open()
        val = fn(self.value)
        self.set(val)
        return val

    def close(self) -> None:
        if self._state != "open":
            return
        self._state = "closed"
        self.backend._exit_write(self.th, self.h, self._token)
        _note_open(self.backend, getattr(self.th, "tid", -1),
                   "write_guards", -1)
        san = self.backend.sanitizer
        if san is not None:
            san.on_guard_close(self, "write")
        pl = self.backend.placement
        if pl is not None:
            pl.note_access(self.th, self.h, write=True)

    def __exit__(self, *exc):
        self.close()
        return False


class Region:
    """``with cluster.region(th) as r:`` — a batching scope.

    Entry hints:
      * ``r.prefetch(handles)`` — post speculative read doorbells for the
        scope's working set (no-op on backends without safe speculation);
      * ``r.pin(handles)`` — take region-lifetime immutable borrows: the
        payloads stay pinned in the local cache until the region exits;
      * ``lease=(rwlocks...)`` — take reader leases on ``DRwLock``s up
        front (one grant round trip each, amortized over every read this
        server does until a writer revokes).  Unlike pins, leases *outlive*
        the region — revocation is the writer's job, not scope exit's.

    Exit is a *settle point*: the thread's registered (coalesced) derefs
    flush as per-source ``read_many`` doorbells and its staged channel
    sends ring — exactly the work this thread touched inside the scope
    (registration and staging are per-thread, and the previous settle
    point closed the prior quantum).  Pins are released before the flush.
    Exceptions settle too — the scope *is* the lifetime.
    """

    __slots__ = ("cluster", "th", "_pins", "_state", "_prefetch", "_pin",
                 "_lease")

    def __init__(self, cluster, th, prefetch=(), pin=(), lease=()):
        self.cluster, self.th = cluster, th
        self._prefetch, self._pin = tuple(prefetch), tuple(pin)
        self._lease = tuple(lease)
        self._pins: list[ReadGuard] = []
        self._state = "new"

    def __enter__(self) -> "Region":
        if self._state != "new":
            raise BorrowError("region re-entered")
        self._state = "open"
        _bump_guard_stat(self.cluster.backend, "regions")
        _note_open(self.cluster.backend, getattr(self.th, "tid", -1),
                   "regions", +1)
        try:
            if self._prefetch:
                self.prefetch(self._prefetch)
            if self._pin:
                self.pin(self._pin)
            for rw in self._lease:
                rw.acquire_lease(self.th)
        except BaseException:
            # The with-statement never calls __exit__ when __enter__
            # raises — release any pins already taken before propagating,
            # or the hint failure would leak borrows forever.
            self._state = "closed"
            _note_open(self.cluster.backend, getattr(self.th, "tid", -1),
                       "regions", -1)
            for g in reversed(self._pins):
                g.close()
            self._pins.clear()
            raise
        return self

    def prefetch(self, handles) -> int:
        if self._state != "open":
            raise BorrowError("prefetch hint outside the region scope")
        return self.cluster.backend.prefetch(self.th, handles)

    def pin(self, handles) -> None:
        if self._state != "open":
            raise BorrowError("pin hint outside the region scope")
        for h in handles:
            g = ReadGuard(self.cluster.backend, self.th, h, pin=True)
            g.__enter__()
            self._pins.append(g)

    def __exit__(self, *exc):
        if self._state != "open":
            return False
        self._state = "closed"
        _note_open(self.cluster.backend, getattr(self.th, "tid", -1),
                   "regions", -1)
        for g in reversed(self._pins):
            g.close()
        self._pins.clear()
        self.cluster.settle(self.th)
        return False
