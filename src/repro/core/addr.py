"""Colored global addresses and pointer layout (paper Fig. 4 / Fig. 8).

DRust's pointer is two 64-bit words:

  word 0 (global address): [ 16-bit color | 48-bit global heap address ]
  word 1 (extension):      immutable ref / owner read path -> local copy address
                           mutable ref / owner write path  -> [U bit | owner slot address]

The color is a version number: every write epoch bumps it, so cache lookups
(keyed by the *colored* address) miss after any mutation even when the raw
address is unchanged.  The U ("updated") bit dedups color bumps within one
write epoch (Algorithms 6/8); it is reset whenever an immutable reference is
created from the owner or mutable reference (Appendix B.4).
"""

from __future__ import annotations

COLOR_BITS = 16
ADDR_BITS = 48
COLOR_SHIFT = ADDR_BITS
ADDR_MASK = (1 << ADDR_BITS) - 1
COLOR_MASK = ((1 << COLOR_BITS) - 1) << COLOR_SHIFT
MAX_COLOR = (1 << COLOR_BITS) - 1
U_BIT = 1 << 63
NULL = 0

# PGAS layout: each server backs one heap partition of PART_SIZE bytes.
# Stacks live in a disjoint range, aligned identically on every server so a
# migrated thread keeps its stack addresses (paper Fig. 3).
PART_SIZE = 1 << 34          # 16 GiB per-server heap partition
STACK_BASE = 1 << 46         # stack region, shared layout on all servers
STACK_SIZE = 1 << 23         # 8 MiB per thread stack


def clear_color(g: int) -> int:
    """CLEARCOLOR: raw 48-bit global address."""
    return g & ADDR_MASK


def get_color(g: int) -> int:
    """GETCOLOR: the 16-bit version."""
    return (g & COLOR_MASK) >> COLOR_SHIFT


def append_color(g: int, color: int) -> int:
    """APPENDCOLOR: replace the color bits of ``g`` with ``color``."""
    return (g & ADDR_MASK) | ((color & MAX_COLOR) << COLOR_SHIFT)


def bump_color(g: int) -> tuple[int, bool]:
    """Increment the color; returns (new colored addr, overflowed).

    On overflow the caller must apply the move-on-overflow strategy: relocate
    the object and reset the color to zero (paper §4.1.1).
    """
    c = get_color(g) + 1
    if c > MAX_COLOR:
        return append_color(g, 0), True
    return append_color(g, c), False


def color_updated(ext: int) -> bool:
    """COLORUPDATED: U bit of the extension word."""
    return bool(ext & U_BIT)


def set_u_bit(ext: int) -> int:
    return ext | U_BIT


def clear_u_bit(ext: int) -> int:
    """CLEARUBIT: owner slot address without the U bit."""
    return ext & ~U_BIT


def server_of(addr: int) -> int:
    """Which server's partition a raw (uncolored) heap address belongs to."""
    a = clear_color(addr)
    if a >= STACK_BASE:
        raise ValueError(f"stack address {a:#x} has no home partition")
    return a // PART_SIZE


def partition_range(server: int) -> tuple[int, int]:
    base = server * PART_SIZE
    return base, base + PART_SIZE


def is_stack(addr: int) -> bool:
    return clear_color(addr) >= STACK_BASE
