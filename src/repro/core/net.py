"""Deterministic cluster simulation substrate: virtual clocks + RDMA cost model.

The coherence protocols in this package are *control-plane* algorithms; their
message complexity is hardware-independent.  We execute them for real (real
heaps, caches, refcounts, payload bytes) and charge costs on a deterministic
virtual clock, calibrated against the paper's measurements (§3):

  * one-sided RDMA read of a 512 B object  ~ 3.6 us
  * GAM uncached 512 B read (directory)    ~ 16  us  (77% coherence overhead)
  * Table 2: local deref 364 cycles (plain) vs 395 cycles (DRust check)

Latency is charged to the *calling thread's* clock (its critical path); CPU
processing for two-sided messages is additionally charged to the serving
server's busy counter — that is what makes delegation (Grappa) bottleneck on
the home server of hot objects, reproducing the paper's skew results.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class CostModel:
    # Network (InfiniBand 40 Gbps, ConnectX-3-era latencies).
    one_sided_base_us: float = 3.5      # RDMA READ/WRITE verb latency floor
    two_sided_rtt_us: float = 3.0       # SEND/RECV round trip (control msgs)
    atomic_verb_us: float = 3.0         # RDMA FAA / CAS
    bw_bytes_per_us: float = 5000.0     # 40 Gbps ~ 5 GB/s payload bandwidth
    # CPU.
    ghz: float = 2.6                    # Xeon E5-2640 v3
    local_access_us: float = 0.14       # ~364 cycles: local object deref
    deref_check_us: float = 0.012       # ~31 cycles: DRust pointer check
    msg_proc_us: float = 1.0            # handler cost for a two-sided message
    dir_proc_us: float = 3.0            # directory state machine per hop (GAM)
    delegation_proc_us: float = 1.2     # delegated op execution (Grappa)
    alloc_us: float = 0.2               # heap allocator fast path
    hashmap_us: float = 0.05            # cache hashmap lookup/insert

    def xfer_us(self, nbytes: int) -> float:
        return nbytes / self.bw_bytes_per_us

    def cycles_us(self, cycles: float) -> float:
        return cycles / (self.ghz * 1e3)


@dataclass
class ServerStats:
    cpu_busy_us: float = 0.0            # CPU time consumed on this server
    bytes_in: int = 0
    bytes_out: int = 0
    msgs: int = 0


@dataclass
class NetStats:
    one_sided_reads: int = 0
    one_sided_writes: int = 0
    two_sided_msgs: int = 0
    atomics: int = 0
    async_msgs: int = 0
    invalidations: int = 0
    bytes_moved: int = 0
    round_trips: int = 0

    def total_msgs(self) -> int:
        return (self.one_sided_reads + self.one_sided_writes
                + self.two_sided_msgs + self.atomics + self.async_msgs)


class Sim:
    """Virtual-time cluster: per-server stats, per-thread clocks (on Thread)."""

    def __init__(self, n_servers: int, cores_per_server: int = 16,
                 cost: CostModel | None = None):
        self.n = n_servers
        self.cores = cores_per_server
        self.cost = cost or CostModel()
        self.servers = [ServerStats() for _ in range(n_servers)]
        self.net = NetStats()
        # straggler model: per-server compute slowdown (thermal throttling,
        # noisy neighbours, failing DIMMs...).  1.0 = healthy.
        self.slowdown = [1.0] * n_servers

    def degrade(self, server: int, factor: float) -> None:
        self.slowdown[server] = factor

    # ---- thread-charged primitives -------------------------------------
    def compute(self, th, cycles: float) -> None:
        us = self.cost.cycles_us(cycles) * self.slowdown[th.server]
        th.t_us += us
        self.servers[th.server].cpu_busy_us += us

    def busy(self, th, us: float) -> None:
        us *= self.slowdown[th.server]
        th.t_us += us
        self.servers[th.server].cpu_busy_us += us

    def local_access(self, th, nbytes: int = 0) -> None:
        # In-memory object access; bandwidth term only for bulk payloads.
        us = self.cost.local_access_us + (nbytes / 2e4 if nbytes > 4096 else 0.0)
        th.t_us += us
        self.servers[th.server].cpu_busy_us += us

    def deref_check(self, th) -> None:
        self.busy(th, self.cost.deref_check_us)

    def rdma_read(self, th, src_server: int, nbytes: int) -> None:
        """One-sided READ: no CPU on the remote side."""
        us = self.cost.one_sided_base_us + self.cost.xfer_us(nbytes)
        th.t_us += us
        self.net.one_sided_reads += 1
        self.net.bytes_moved += nbytes
        self.net.round_trips += 1
        self.servers[src_server].bytes_out += nbytes
        self.servers[th.server].bytes_in += nbytes

    def rdma_write(self, th, dst_server: int, nbytes: int) -> None:
        us = self.cost.one_sided_base_us + self.cost.xfer_us(nbytes)
        th.t_us += us
        self.net.one_sided_writes += 1
        self.net.bytes_moved += nbytes
        self.net.round_trips += 1
        self.servers[dst_server].bytes_in += nbytes
        self.servers[th.server].bytes_out += nbytes

    def rdma_atomic(self, th, dst_server: int) -> None:
        th.t_us += self.cost.atomic_verb_us
        self.net.atomics += 1
        self.net.round_trips += 1

    def rpc(self, th, dst_server: int, req_bytes: int = 64,
            resp_bytes: int = 64, proc_us: float | None = None) -> None:
        """Two-sided request/response; remote CPU does ``proc_us`` of work."""
        proc = self.cost.msg_proc_us if proc_us is None else proc_us
        us = (self.cost.two_sided_rtt_us + self.cost.xfer_us(req_bytes + resp_bytes)
              + proc)
        th.t_us += us
        self.net.two_sided_msgs += 2
        self.net.round_trips += 1
        self.net.bytes_moved += req_bytes + resp_bytes
        self.servers[dst_server].cpu_busy_us += proc
        self.servers[dst_server].msgs += 1

    def async_msg(self, dst_server: int, nbytes: int = 64) -> None:
        """Off-critical-path message (e.g. async dealloc, lazy invalidation)."""
        self.net.async_msgs += 1
        self.net.bytes_moved += nbytes
        self.servers[dst_server].cpu_busy_us += self.cost.msg_proc_us * 0.5
        self.servers[dst_server].msgs += 1

    # ---- aggregation ----------------------------------------------------
    def makespan_us(self, threads) -> float:
        """App completion time: slowest thread, or a saturated server's CPU."""
        per_server_thread = [0.0] * self.n
        for t in threads:
            per_server_thread[t.server] = max(per_server_thread[t.server], t.t_us)
        span = 0.0
        for s in range(self.n):
            cpu = self.servers[s].cpu_busy_us / self.cores
            span = max(span, per_server_thread[s], cpu)
        return span

    def snapshot(self) -> dict:
        return {
            "net": dataclasses.asdict(self.net),
            "servers": [dataclasses.asdict(s) for s in self.servers],
        }
