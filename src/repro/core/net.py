"""Deterministic cluster simulation substrate: virtual clocks + RDMA cost model.

The coherence protocols in this package are *control-plane* algorithms; their
message complexity is hardware-independent.  We execute them for real (real
heaps, caches, refcounts, payload bytes) and charge costs on a deterministic
virtual clock, calibrated against the paper's measurements (§3):

  * one-sided RDMA read of a 512 B object  ~ 3.6 us
  * GAM uncached 512 B read (directory)    ~ 16  us  (77% coherence overhead)
  * Table 2: local deref 364 cycles (plain) vs 395 cycles (DRust check)

Latency is charged to the *calling thread's* clock (its critical path); CPU
processing for two-sided messages is additionally charged to the serving
server's busy counter — that is what makes delegation (Grappa) bottleneck on
the home server of hot objects, reproducing the paper's skew results.

Batched I/O plane
-----------------
Two mechanisms take verbs off the per-object critical path:

* ``IOBatch`` (``Sim.batch()``) — *doorbell coalescing*: N one-sided verbs
  posted to the same destination in one doorbell ring cost ONE base latency
  plus the summed bandwidth terms plus a small per-verb issue cost
  (``doorbell_us``).  Doorbells to *different* servers overlap in flight, so
  the thread pays the max per-server latency, not the sum.  Counting:
  ``one_sided_reads``/``one_sided_writes`` and ``round_trips`` tick once per
  doorbell (one completion polled), ``batched_verbs`` counts the coalesced
  scatter/gather elements, ``doorbell_batches`` the rings.  This is how TBox
  affinity groups (§4.1.3) are fetched as one transfer.

* ``WritebackQueue`` (``Sim.wb``) — *async write-back pipelining*: posted
  WRITEs (e.g. DropMutRef's 8-byte owner write-back) charge only the issue
  cost (``wb_issue_us``) to the poster; the verb's completion time is
  tracked per destination (bandwidth-serialized) and surfaces either at an
  explicit ``drain()`` (a synchronization point, e.g. ownership transfer)
  or in ``makespan_us`` — the cost is real, just off the critical path.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class CostModel:
    # Network (InfiniBand 40 Gbps, ConnectX-3-era latencies).
    one_sided_base_us: float = 3.5      # RDMA READ/WRITE verb latency floor
    two_sided_rtt_us: float = 3.0       # SEND/RECV round trip (control msgs)
    atomic_verb_us: float = 3.0         # RDMA FAA / CAS
    bw_bytes_per_us: float = 5000.0     # 40 Gbps ~ 5 GB/s payload bandwidth
    # CPU.
    ghz: float = 2.6                    # Xeon E5-2640 v3
    local_access_us: float = 0.14       # ~364 cycles: local object deref
    deref_check_us: float = 0.012       # ~31 cycles: DRust pointer check
    msg_proc_us: float = 1.0            # handler cost for a two-sided message
    dir_proc_us: float = 3.0            # directory state machine per hop (GAM)
    delegation_proc_us: float = 1.2     # delegated op execution (Grappa)
    alloc_us: float = 0.2               # heap allocator fast path
    hashmap_us: float = 0.05            # cache hashmap lookup/insert
    doorbell_us: float = 0.08           # per-verb issue cost inside a doorbell
    wb_issue_us: float = 0.15           # post an async write-back (no wait)

    def xfer_us(self, nbytes: int) -> float:
        return nbytes / self.bw_bytes_per_us

    def cycles_us(self, cycles: float) -> float:
        return cycles / (self.ghz * 1e3)


@dataclass
class ServerStats:
    cpu_busy_us: float = 0.0            # CPU time consumed on this server
    bytes_in: int = 0
    bytes_out: int = 0
    msgs: int = 0


@dataclass
class NetStats:
    one_sided_reads: int = 0            # doorbells (completion events) polled
    one_sided_writes: int = 0
    two_sided_msgs: int = 0
    atomics: int = 0
    async_msgs: int = 0
    async_writebacks: int = 0           # pipelined WRITEs posted off-path
    invalidations: int = 0
    bytes_moved: int = 0
    round_trips: int = 0                # critical-path completions waited on
    doorbell_batches: int = 0           # doorbell rings (>= 1 verb each)
    batched_verbs: int = 0              # scatter/gather elements coalesced
    wb_drains: int = 0                  # write-back queue fences

    def total_msgs(self) -> int:
        return (self.one_sided_reads + self.one_sided_writes
                + self.two_sided_msgs + self.atomics + self.async_msgs)

    def critical_path_msgs(self) -> int:
        """Synchronous messages a thread actually waited on; DRust's
        invalidation/dealloc traffic and pipelined write-backs are
        asynchronous by design and reported separately."""
        return self.total_msgs() - self.async_msgs - self.async_writebacks


class IOBatch:
    """Doorbell-coalesced one-sided verbs (see module docstring).

    Verbs are staged with ``add_read``/``add_write`` and charged at
    ``commit(th)``: one base latency per (server, direction) doorbell plus
    summed bandwidth terms; doorbells to distinct servers overlap (thread
    pays the max), per-verb issue cost is additive.
    """

    __slots__ = ("sim", "reads", "writes")

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self.reads: dict[int, list[int]] = {}    # src server -> [nbytes]
        self.writes: dict[int, list[int]] = {}   # dst server -> [nbytes]

    def add_read(self, src_server: int, nbytes: int) -> None:
        self.reads.setdefault(src_server, []).append(nbytes)

    def add_write(self, dst_server: int, nbytes: int) -> None:
        self.writes.setdefault(dst_server, []).append(nbytes)

    @property
    def empty(self) -> bool:
        return not self.reads and not self.writes

    def n_verbs(self) -> int:
        return (sum(len(v) for v in self.reads.values())
                + sum(len(v) for v in self.writes.values()))

    def commit(self, th) -> float:
        """Ring every doorbell; returns the critical-path latency charged."""
        if self.empty:
            return 0.0
        sim, cost, net = self.sim, self.sim.cost, self.sim.net
        issue = 0.0                      # CPU posts every WQE serially
        inflight = 0.0                   # doorbells to distinct QPs overlap
        for server, sizes in self.reads.items():
            total = sum(sizes)
            issue += cost.doorbell_us * len(sizes)
            inflight = max(inflight, cost.one_sided_base_us + cost.xfer_us(total))
            net.one_sided_reads += 1
            net.doorbell_batches += 1
            net.batched_verbs += len(sizes)
            net.round_trips += 1
            net.bytes_moved += total
            sim.servers[server].bytes_out += total
            sim.servers[th.server].bytes_in += total
        for server, sizes in self.writes.items():
            total = sum(sizes)
            issue += cost.doorbell_us * len(sizes)
            inflight = max(inflight, cost.one_sided_base_us + cost.xfer_us(total))
            net.one_sided_writes += 1
            net.doorbell_batches += 1
            net.batched_verbs += len(sizes)
            net.round_trips += 1
            net.bytes_moved += total
            sim.servers[server].bytes_in += total
            sim.servers[th.server].bytes_out += total
        lat = issue + inflight
        th.t_us += lat
        self.reads.clear()
        self.writes.clear()
        return lat


class WritebackQueue:
    """Pipelined one-sided WRITEs charged off the critical path.

    ``post`` charges only the issue cost to the posting thread; the verb's
    completion is modeled per destination (bandwidth-serialized per QP) and
    must be waited on at synchronization points via ``drain`` — otherwise it
    surfaces as a floor on ``Sim.makespan_us``.
    """

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self._bw_tail: dict[int, float] = {}     # dst -> wire busy-until time
        self._tail: dict[int, float] = {}        # poster tid -> last completion
        self.posted = 0

    def post(self, th, dst_server: int, nbytes: int) -> None:
        sim, cost, net = self.sim, self.sim.cost, self.sim.net
        th.t_us += cost.wb_issue_us
        # In-flight WRITEs overlap their base latencies (deep NIC queue);
        # only the bandwidth term serializes per destination link.
        # Completion is tracked per *posting thread*: a fence orders a
        # thread's own prior write-backs, not other threads' traffic.
        wire = max(th.t_us, self._bw_tail.get(dst_server, 0.0)) + cost.xfer_us(nbytes)
        self._bw_tail[dst_server] = wire
        done = wire + cost.one_sided_base_us
        tid = getattr(th, "tid", 0)
        self._tail[tid] = max(self._tail.get(tid, 0.0), done)
        self.posted += 1
        net.one_sided_writes += 1
        net.async_writebacks += 1
        net.bytes_moved += nbytes
        sim.servers[dst_server].bytes_in += nbytes
        sim.servers[th.server].bytes_out += nbytes

    @property
    def pending_completion_us(self) -> float:
        return max(self._tail.values(), default=0.0)

    def drain(self, th) -> float:
        """Fence: block ``th`` until every write-back *it posted* has
        completed (program-order fence; other threads' traffic is not
        charged to this thread)."""
        t = self._tail.pop(getattr(th, "tid", 0), None)
        if t is None:
            return 0.0
        if t > th.t_us:
            th.t_us = t
        self.sim.net.wb_drains += 1
        if not self._tail:
            self._bw_tail.clear()
        return t


class Sim:
    """Virtual-time cluster: per-server stats, per-thread clocks (on Thread)."""

    def __init__(self, n_servers: int, cores_per_server: int = 16,
                 cost: CostModel | None = None):
        self.n = n_servers
        self.cores = cores_per_server
        self.cost = cost or CostModel()
        self.servers = [ServerStats() for _ in range(n_servers)]
        self.net = NetStats()
        self.wb = WritebackQueue(self)
        # straggler model: per-server compute slowdown (thermal throttling,
        # noisy neighbours, failing DIMMs...).  1.0 = healthy.
        self.slowdown = [1.0] * n_servers

    def batch(self) -> IOBatch:
        return IOBatch(self)

    def degrade(self, server: int, factor: float) -> None:
        self.slowdown[server] = factor

    # ---- thread-charged primitives -------------------------------------
    def compute(self, th, cycles: float) -> None:
        us = self.cost.cycles_us(cycles) * self.slowdown[th.server]
        th.t_us += us
        self.servers[th.server].cpu_busy_us += us

    def busy(self, th, us: float) -> None:
        us *= self.slowdown[th.server]
        th.t_us += us
        self.servers[th.server].cpu_busy_us += us

    def local_access(self, th, nbytes: int = 0) -> None:
        # In-memory object access; bandwidth term only for bulk payloads.
        us = self.cost.local_access_us + (nbytes / 2e4 if nbytes > 4096 else 0.0)
        th.t_us += us
        self.servers[th.server].cpu_busy_us += us

    def deref_check(self, th) -> None:
        self.busy(th, self.cost.deref_check_us)

    def rdma_read(self, th, src_server: int, nbytes: int) -> None:
        """One-sided READ: no CPU on the remote side."""
        us = self.cost.one_sided_base_us + self.cost.xfer_us(nbytes)
        th.t_us += us
        self.net.one_sided_reads += 1
        self.net.bytes_moved += nbytes
        self.net.round_trips += 1
        self.servers[src_server].bytes_out += nbytes
        self.servers[th.server].bytes_in += nbytes

    def rdma_write(self, th, dst_server: int, nbytes: int) -> None:
        us = self.cost.one_sided_base_us + self.cost.xfer_us(nbytes)
        th.t_us += us
        self.net.one_sided_writes += 1
        self.net.bytes_moved += nbytes
        self.net.round_trips += 1
        self.servers[dst_server].bytes_in += nbytes
        self.servers[th.server].bytes_out += nbytes

    def rdma_atomic(self, th, dst_server: int) -> None:
        th.t_us += self.cost.atomic_verb_us
        self.net.atomics += 1
        self.net.round_trips += 1

    def rpc(self, th, dst_server: int, req_bytes: int = 64,
            resp_bytes: int = 64, proc_us: float | None = None) -> None:
        """Two-sided request/response; remote CPU does ``proc_us`` of work."""
        proc = self.cost.msg_proc_us if proc_us is None else proc_us
        us = (self.cost.two_sided_rtt_us + self.cost.xfer_us(req_bytes + resp_bytes)
              + proc)
        th.t_us += us
        self.net.two_sided_msgs += 2
        self.net.round_trips += 1
        self.net.bytes_moved += req_bytes + resp_bytes
        self.servers[dst_server].cpu_busy_us += proc
        self.servers[dst_server].msgs += 1

    def async_msg(self, dst_server: int, nbytes: int = 64) -> None:
        """Off-critical-path message (e.g. async dealloc, lazy invalidation)."""
        self.net.async_msgs += 1
        self.net.bytes_moved += nbytes
        self.servers[dst_server].cpu_busy_us += self.cost.msg_proc_us * 0.5
        self.servers[dst_server].msgs += 1

    # ---- aggregation ----------------------------------------------------
    def makespan_us(self, threads) -> float:
        """App completion time: slowest thread, a saturated server's CPU, or
        the last in-flight async write-back (pipelined cost is still cost)."""
        per_server_thread = [0.0] * self.n
        for t in threads:
            per_server_thread[t.server] = max(per_server_thread[t.server], t.t_us)
        span = self.wb.pending_completion_us
        for s in range(self.n):
            cpu = self.servers[s].cpu_busy_us / self.cores
            span = max(span, per_server_thread[s], cpu)
        return span

    def snapshot(self) -> dict:
        return {
            "net": dataclasses.asdict(self.net),
            "servers": [dataclasses.asdict(s) for s in self.servers],
        }
