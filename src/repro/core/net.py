"""Deterministic cluster simulation substrate: virtual clocks + RDMA cost model.

The coherence protocols in this package are *control-plane* algorithms; their
message complexity is hardware-independent.  We execute them for real (real
heaps, caches, refcounts, payload bytes) and charge costs on a deterministic
virtual clock, calibrated against the paper's measurements (§3):

  * one-sided RDMA read of a 512 B object  ~ 3.6 us
  * GAM uncached 512 B read (directory)    ~ 16  us  (77% coherence overhead)
  * Table 2: local deref 364 cycles (plain) vs 395 cycles (DRust check)

Latency is charged to the *calling thread's* clock (its critical path); CPU
processing for two-sided messages is additionally charged to the serving
server's busy counter — that is what makes delegation (Grappa) bottleneck on
the home server of hot objects, reproducing the paper's skew results.

Batched I/O plane
-----------------
Two mechanisms take verbs off the per-object critical path:

* ``IOBatch`` (``Sim.batch()``) — *doorbell coalescing*: N one-sided verbs
  posted to the same destination in one doorbell ring cost ONE base latency
  plus the summed bandwidth terms plus a small per-verb issue cost
  (``doorbell_us``).  Counting: ``one_sided_reads``/``one_sided_writes`` and
  ``round_trips`` tick once per doorbell (one completion polled),
  ``batched_verbs`` counts the coalesced scatter/gather elements,
  ``doorbell_batches`` the rings.  This is how TBox affinity groups (§4.1.3)
  are fetched as one transfer.

* ``WritebackQueue`` (``Sim.wb``) — *async write-back pipelining*: posted
  WRITEs (e.g. DropMutRef's 8-byte owner write-back) charge only the issue
  cost (``wb_issue_us``) to the poster; the verb's completion is tracked and
  surfaces either at a fence (a synchronization point, e.g. ownership
  transfer) or in ``makespan_us`` — the cost is real, just off the critical
  path.

Multi-QP completion plane
-------------------------
Every posted verb draws a cluster-wide monotone **completion id** from
``Sim.next_cid()``.  How its completion *time* is computed depends on the
completion model:

* ``ooo=False`` (default) — the PR-1 legacy model: write-backs complete in
  post order per destination (one bandwidth-serialized wire per destination
  server), doorbells to distinct servers overlap.  This path reproduces the
  PR-1 plane exactly: byte-identical message/byte counters and virtual
  times equal to float-ulp level (pinned against golden PR-1 values by
  ``tests/test_net_invariants.py``).

* ``ooo=True`` — NIC-grade out-of-order completions.  Each thread owns
  ``qps_per_thread`` queue pairs; verbs/doorbells stripe round-robin across
  them (``qp_switches`` counts rings on a different QP than the last, at
  ``qp_switch_us`` CPU each).  Three deterministic serialization constraints
  shape every completion time:

    1. *per-QP engine*: a QP's WQEs are processed in order — each verb
       occupies the engine for ``max(bandwidth term, qp_msg_us)`` (the
       NIC's per-QP message-rate limit) before the next may start;
    2. *per-QP CQ order*: an RC QP's completions are strictly ordered, so a
       verb's completion time is floored by the QP's previous completion;
    3. *shared-link congestion*: all QPs of all threads share the
       destination server's link (bandwidth ``link_bw_bytes_per_us``) —
       every transfer's occupancy accumulates per server and a saturated
       link floors the makespan exactly like a saturated CPU
       (``Sim.link_xfer`` explains why it is capacity accounting rather
       than a busy-until queue).

  Completions of *different* QPs carry no ordering: a verb may complete
  before an earlier-posted verb on a sibling QP (``ooo_completions`` counts
  these inversions per posting thread).

Speculative prefetch rides the same completion plane: ``post_read`` posts a
one-sided READ doorbell off the critical path (``speculative_fetches``), the
runtime records the cid on the prefetched ``DBox``, and the fence is deferred
to the first *materialized* use (``late_fences``) — or never happens, when
ownership moves or the owner mutates before first use and the speculatively
fetched cache entry is invalidated instead (``wasted_prefetches``).

Fences wait on **completion ids**, not queues: ``fence(th, upto_id)`` blocks
``th`` until every still-pending verb with ``cid <= upto_id`` has completed
(a CQ-order fence may over-wait on unrelated earlier verbs — that is what a
cid fence means); ``fence_all(th)`` fences the entire pending window.  An
ownership transfer fences only the ids it actually depends on (the
write-backs recorded on the transferred box), leaving later verbs in flight.
"""

from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field


class ServerLostError(RuntimeError):
    """A verb, borrow, or payload depended on a server that failed.

    Raised (a) by ``Sim`` when a verb targets a *declared-failed* server or
    exhausts the degraded-mode retry ladder against an unresponsive one, and
    (b) by the ownership layer when a guard touches a box whose payload died
    with its home server (open ``WriteGuard`` broken by fail-over, or a box
    that had no replica to restore from).  Structured — carries the server —
    so applications can re-drive work instead of pattern-matching strings.
    """

    def __init__(self, server: int, msg: str):
        super().__init__(f"server {server}: {msg}")
        self.server = server


@dataclass(frozen=True)
class CostModel:
    # Network (InfiniBand 40 Gbps, ConnectX-3-era latencies).
    one_sided_base_us: float = 3.5      # RDMA READ/WRITE verb latency floor
    two_sided_rtt_us: float = 3.0       # SEND/RECV round trip (control msgs)
    atomic_verb_us: float = 3.0         # RDMA FAA / CAS
    bw_bytes_per_us: float = 5000.0     # 40 Gbps ~ 5 GB/s payload bandwidth
    # CPU.
    ghz: float = 2.6                    # Xeon E5-2640 v3
    local_access_us: float = 0.14       # ~364 cycles: local object deref
    deref_check_us: float = 0.012       # ~31 cycles: DRust pointer check
    msg_proc_us: float = 1.0            # handler cost for a two-sided message
    dir_proc_us: float = 3.0            # directory state machine per hop (GAM)
    delegation_proc_us: float = 1.2     # delegated op execution (Grappa)
    alloc_us: float = 0.2               # heap allocator fast path
    hashmap_us: float = 0.05            # cache hashmap lookup/insert
    doorbell_us: float = 0.08           # per-verb issue cost inside a doorbell
    wb_issue_us: float = 0.15           # post an async write-back (no wait)
    # Multi-QP completion plane (ooo=True only).
    link_bw_bytes_per_us: float = 5000.0  # shared per-server link (NIC port)
    qp_msg_us: float = 0.5              # per-QP WQE engine occupancy per verb
    #   (the NIC's per-QP message-rate limit, ~2 M verbs/s: the reason
    #    multi-QP raises small-verb throughput even when bandwidth is idle)
    qp_switch_us: float = 0.02          # ring a doorbell on a different QP
    # Degraded mode (failure detection).  A verb posted to a server that is
    # failing-but-not-yet-declared times out and retries with exponential
    # backoff; after ``max_retries`` the error surfaces to the caller (and
    # feeds the controller's missed-probe counter).
    retry_timeout_us: float = 40.0      # per-attempt verb/probe timeout
    retry_backoff: float = 2.0          # backoff factor between attempts
    max_retries: int = 3                # attempts before declaring the verb lost

    def retry_penalty_us(self) -> float:
        """Total virtual time burned by a full retry ladder (timeout,
        backoff x2, ...): what a thread pays to discover a dead peer."""
        return sum(self.retry_timeout_us * self.retry_backoff ** i
                   for i in range(self.max_retries))

    def xfer_us(self, nbytes: int) -> float:
        return nbytes / self.bw_bytes_per_us

    def link_xfer_us(self, nbytes: int) -> float:
        return nbytes / self.link_bw_bytes_per_us

    def cycles_us(self, cycles: float) -> float:
        return cycles / (self.ghz * 1e3)


@dataclass
class ServerStats:
    cpu_busy_us: float = 0.0            # CPU time consumed on this server
    link_busy_us: float = 0.0           # shared-link occupancy (ooo model)
    bytes_in: int = 0
    bytes_out: int = 0
    msgs: int = 0


@dataclass
class NetStats:
    one_sided_reads: int = 0            # doorbells (completion events) polled
    one_sided_writes: int = 0
    two_sided_msgs: int = 0
    atomics: int = 0
    async_msgs: int = 0
    async_writebacks: int = 0           # pipelined WRITEs posted off-path
    invalidations: int = 0
    bytes_moved: int = 0
    round_trips: int = 0                # critical-path completions waited on
    doorbell_batches: int = 0           # doorbell rings (>= 1 verb each)
    batched_verbs: int = 0              # scatter/gather elements coalesced
    wb_drains: int = 0                  # fences that retired >= 1 verb
    fences: int = 0                     # fence/fence_all calls issued
    fenced_verbs: int = 0               # verbs retired by a completion fence
    ooo_completions: int = 0            # completions beating an earlier cid
    qp_switches: int = 0                # doorbell rung on a different QP
    speculative_fetches: int = 0        # prefetch doorbells posted off-path
    late_fences: int = 0                # fences deferred to first use
    wasted_prefetches: int = 0          # speculative entries killed unused
    # Telemetry-driven placement (core/runtime.py PlacementTracker; all
    # zero under placement="static", the default).
    owner_migrations: int = 0           # hot-accessor ownership pulls
    migration_round_trips: int = 0      # round trips spent inside those pulls
    quantum_merges: int = 0             # sibling derefs merged into one flush
    # Scalable synchronization (core/sync.py; zero on lock-free paths).
    closure_ships: int = 0              # delegated critical sections shipped
    convoy_completions: int = 0         # convoy-head completions polled
    delegated_sections: int = 0         # critical sections run at the home
    lease_grants: int = 0               # reader leases granted by a home
    lease_revokes: int = 0              # reader leases revoked by a writer
    # Recovery (crash fail-over; all zero on the no-failure path).
    orphaned_cids: int = 0              # pending verbs disposed at fail-over
    rehomed_boxes: int = 0              # objects restored from replica/checkpoint
    broken_locks: int = 0               # DMutex holders broken by fail-over
    lost_writes: int = 0                # dirty-at-crash objects (epoch revert)
    suspect_invalidations: int = 0      # dead-home cache copies scrubbed
    degraded_retries: int = 0           # retry attempts against failing servers
    recovery_makespan_us: float = 0.0   # virtual time of the last fail-over

    def total_msgs(self) -> int:
        return (self.one_sided_reads + self.one_sided_writes
                + self.two_sided_msgs + self.atomics + self.async_msgs)

    def critical_path_msgs(self) -> int:
        """Synchronous messages a thread actually waited on; DRust's
        invalidation/dealloc traffic, pipelined write-backs, and
        speculative prefetch READs are asynchronous by design and
        reported separately."""
        return (self.total_msgs() - self.async_msgs - self.async_writebacks
                - self.speculative_fetches - self.closure_ships)


@dataclass
class _Verb:
    """A posted-but-not-retired one-sided verb on the completion plane."""
    cid: int
    tid: int
    dst: int
    nbytes: int
    done_us: float
    is_read: bool = False     # speculative READ (vs async write-back WRITE)
    kind: str = "write"       # "write" | "closure" | "revoke" (WRITE flavors)


class IOBatch:
    """Doorbell-coalesced one-sided verbs (see module docstring).

    Verbs are staged with ``add_read``/``add_write`` and charged at
    ``commit(th)``: one base latency per (server, direction) doorbell plus
    summed bandwidth terms, per-verb issue cost additive.  Under the legacy
    completion model doorbells to distinct servers overlap (thread pays the
    max); under ``ooo=True`` the doorbells stripe round-robin across the
    thread's QPs — same-QP doorbells serialize on the QP engine, sibling-QP
    doorbells overlap, and every transfer serializes on the destination's
    shared link.
    """

    __slots__ = ("sim", "reads", "writes")

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self.reads: dict[int, list[int]] = {}    # src server -> [nbytes]
        self.writes: dict[int, list[int]] = {}   # dst server -> [nbytes]

    def add_read(self, src_server: int, nbytes: int) -> None:
        self.reads.setdefault(src_server, []).append(nbytes)

    def add_write(self, dst_server: int, nbytes: int) -> None:
        self.writes.setdefault(dst_server, []).append(nbytes)

    @property
    def empty(self) -> bool:
        return not self.reads and not self.writes

    def n_verbs(self) -> int:
        return (sum(len(v) for v in self.reads.values())
                + sum(len(v) for v in self.writes.values()))

    def _count_doorbell(self, th, server: int, sizes: list[int],
                        is_read: bool) -> int:
        """Message/byte accounting for one doorbell (identical under both
        completion models); returns the doorbell's total byte count."""
        net, sim = self.sim.net, self.sim
        total = sum(sizes)
        for _ in sizes:
            sim.next_cid()               # every coalesced verb draws a cid
        if is_read:
            net.one_sided_reads += 1
            sim.servers[sim._serve(server)].bytes_out += total
            sim.servers[th.server].bytes_in += total
        else:
            net.one_sided_writes += 1
            sim.servers[sim._serve(server)].bytes_in += total
            sim.servers[th.server].bytes_out += total
        net.doorbell_batches += 1
        net.batched_verbs += len(sizes)
        net.round_trips += 1
        net.bytes_moved += total
        return total

    def commit(self, th) -> float:
        """Ring every doorbell; returns the critical-path latency charged."""
        if self.empty:
            return 0.0
        sim, cost = self.sim, self.sim.cost
        if sim.failed or sim.failing:     # all-or-nothing: gate before counting
            for server in (*self.reads, *self.writes):
                sim.check_reachable(th, server)
        if not sim.ooo:                  # legacy plane: PR-1 arithmetic
            issue = 0.0                  # CPU posts every WQE serially
            inflight = 0.0               # doorbells to distinct QPs overlap
            for server, sizes in self.reads.items():
                total = self._count_doorbell(th, server, sizes, is_read=True)
                issue += cost.doorbell_us * len(sizes)
                inflight = max(inflight,
                               cost.one_sided_base_us + cost.xfer_us(total))
            for server, sizes in self.writes.items():
                total = self._count_doorbell(th, server, sizes, is_read=False)
                issue += cost.doorbell_us * len(sizes)
                inflight = max(inflight,
                               cost.one_sided_base_us + cost.xfer_us(total))
            lat = issue + inflight
            th.t_us += lat
        else:                            # multi-QP out-of-order plane
            t0 = th.t_us
            dones: list[float] = []
            doorbells = ([(s, sz, True) for s, sz in self.reads.items()]
                         + [(s, sz, False) for s, sz in self.writes.items()])
            for server, sizes, is_read in doorbells:
                total = self._count_doorbell(th, server, sizes, is_read)
                th.t_us += cost.doorbell_us * len(sizes)    # serial WQE posts
                done = sim.qp_complete(th, server, total, n_verbs=len(sizes))
                if dones and done < max(dones):
                    sim.net.ooo_completions += 1
                dones.append(done)
            th.t_us = max(th.t_us, max(dones))   # sync commit: poll all CQs
            lat = th.t_us - t0
        self.reads.clear()
        self.writes.clear()
        return lat


class WritebackQueue:
    """Pipelined one-sided WRITEs charged off the critical path.

    ``post`` charges only the issue cost to the posting thread and returns
    the verb's **completion id**; the completion time comes from the active
    completion model (see module docstring).  Synchronization points wait on
    specific ids via ``fence``/``fence_all`` — anything never fenced
    surfaces as a floor on ``Sim.makespan_us``.
    """

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self._bw_tail: dict[int, float] = {}     # legacy: dst -> wire busy-until
        self._bw_tail_rd: dict[int, float] = {}  # legacy: src -> read-wire tail
        self._pending: dict[int, _Verb] = {}     # cid -> verb, insertion = cid order
        self._retired: dict[int, float] = {}     # fenced cid -> completion time
        self._retired_hi = (0, 0.0)  # (highest retired cid, max retired done)
        self._tid_maxdone: dict[int, float] = {}  # max pending done per tid
        self._retired_floor = 0.0    # makespan floor from forgotten threads
        self._max_cid = 0            # highest cid ever posted on this queue
        self.posted = 0

    # ---- post ----------------------------------------------------------
    def post(self, th, dst_server: int, nbytes: int,
             kind: str = "write") -> int:
        """Post an async WRITE; returns its completion id.

        ``kind`` selects the WRITE flavor for counter purposes — identical
        cost model, different trajectory columns: ``"write"`` is a
        pipelined write-back (``async_writebacks``), ``"closure"`` is a
        delegated critical section shipped to a lock home
        (``closure_ships``, off the critical path — its completion is the
        convoy head's), ``"revoke"`` is a lease-revocation WRITE the
        writer fences immediately (counted on the critical path)."""
        sim, cost, net = self.sim, self.sim.cost, self.sim.net
        sim.check_reachable(th, dst_server, sync=False)
        th.t_us += cost.wb_issue_us
        tid = getattr(th, "tid", 0)
        cid = sim.next_cid()
        if not sim.ooo:
            # Legacy (PR-1) completion model: in-flight WRITEs overlap their
            # base latencies (deep NIC queue); only the bandwidth term
            # serializes per destination link, completions surface in post
            # order per destination.
            wire = (max(th.t_us, self._bw_tail.get(dst_server, 0.0))
                    + cost.xfer_us(nbytes))
            self._bw_tail[dst_server] = wire
            done = wire + cost.one_sided_base_us
        else:
            done = sim.qp_complete(th, dst_server, nbytes)
            # Out-of-order completion: this verb beats an earlier-posted,
            # still-pending verb of the same thread (on a sibling QP).
            prior_max = self._pending_maxdone(tid)
            if prior_max > done:
                net.ooo_completions += 1
            self._tid_maxdone[tid] = max(prior_max, done)
        self._pending[cid] = _Verb(cid, tid, dst_server, nbytes, done,
                                   kind=kind)
        self._max_cid = cid
        self.posted += 1
        net.one_sided_writes += 1
        if kind == "closure":
            net.closure_ships += 1
        elif kind != "revoke":
            net.async_writebacks += 1
        net.bytes_moved += nbytes
        sim.servers[sim._serve(dst_server)].bytes_in += nbytes
        sim.servers[th.server].bytes_out += nbytes
        if sim.tracer is not None:
            sim.tracer.note_post(th, cid, dst_server, nbytes, kind)
        return cid

    def post_read(self, th, src_server: int, nbytes: int,
                  n_verbs: int = 1) -> int:
        """Post a *speculative* one-sided READ doorbell (``n_verbs``
        coalesced WQEs pulling ``nbytes`` total from ``src_server``) and
        return its completion id.  The poster pays only the issue cost —
        the completion surfaces at a fence (the deferred first-use fence,
        an ownership-transfer dependency, or B.4 dealloc) or as a floor on
        ``makespan_us``.  Same completion models as ``post``; the legacy
        plane serializes reads on a per-*source* wire, independent of the
        write-back tails (READs come out of a link, WRITEs go into it)."""
        sim, cost, net = self.sim, self.sim.cost, self.sim.net
        sim.check_reachable(th, src_server, sync=False)
        th.t_us += cost.wb_issue_us + cost.doorbell_us * (n_verbs - 1)
        tid = getattr(th, "tid", 0)
        cid = sim.next_cid()
        if not sim.ooo:
            wire = (max(th.t_us, self._bw_tail_rd.get(src_server, 0.0))
                    + cost.xfer_us(nbytes))
            self._bw_tail_rd[src_server] = wire
            done = wire + cost.one_sided_base_us
        else:
            done = sim.qp_complete(th, src_server, nbytes, n_verbs=n_verbs)
            prior_max = self._pending_maxdone(tid)
            if prior_max > done:
                net.ooo_completions += 1
            self._tid_maxdone[tid] = max(prior_max, done)
        self._pending[cid] = _Verb(cid, tid, src_server, nbytes, done,
                                   is_read=True)
        self._max_cid = cid
        self.posted += 1
        net.one_sided_reads += 1
        net.speculative_fetches += 1
        net.bytes_moved += nbytes
        sim.servers[sim._serve(src_server)].bytes_out += nbytes
        sim.servers[th.server].bytes_in += nbytes
        if sim.tracer is not None:
            sim.tracer.note_post(th, cid, src_server, nbytes, "read",
                                 is_read=True)
        return cid

    # ---- fences --------------------------------------------------------
    @property
    def pending_completion_us(self) -> float:
        t = max((v.done_us for v in self._pending.values()), default=0.0)
        return max(t, self._retired_floor)

    def _pending_maxdone(self, tid: int) -> float:
        """Max completion time among ``tid``'s pending verbs — incrementally
        maintained on post, invalidated when a fence/forget removes the
        thread's verbs, recomputed lazily (keeps the inversion check O(1)
        per post instead of a pending-set scan)."""
        cached = self._tid_maxdone.get(tid)
        if cached is None:
            cached = max((v.done_us for v in self._pending.values()
                          if v.tid == tid), default=0.0)
            self._tid_maxdone[tid] = cached
        return cached

    def _retire(self, cid: int, done_us: float) -> None:
        self._retired[cid] = done_us
        hi_cid, hi_done = self._retired_hi
        self._retired_hi = (max(hi_cid, cid), max(hi_done, done_us))

    def _retired_before(self, upto_id: int) -> float:
        """Max completion time among retired cids <= upto_id.  O(1) when the
        fence covers the whole retirement frontier (the common case — new
        fences use fresh, higher cids); the scan only runs for a fence
        scoped below an already-retired cid."""
        hi_cid, hi_done = self._retired_hi
        if upto_id >= hi_cid:
            return hi_done
        return max((d for c, d in self._retired.items() if c <= upto_id),
                   default=0.0)

    def fence(self, th, upto_id: int) -> float:
        """Completion-id fence: block ``th`` until every verb with
        ``cid <= upto_id`` has completed.  Pending verbs in that range
        retire; verbs another thread's fence already retired still gate
        ``th`` — their completion *times* are kept in ``_retired`` so a
        dependent fence waits even when it is not the first to poll the
        cid (otherwise an ownership transfer could ship before a
        write-back another thread happened to sweep).  Verbs posted after
        ``upto_id`` stay in flight — a transfer waits only on the ids it
        depends on."""
        net = self.sim.net
        net.fences += 1
        if self.sim.tracer is not None:
            self.sim.tracer.note_fence(th, upto_id)
        take = [v for v in self._pending.values() if v.cid <= upto_id]
        t = max((v.done_us for v in take), default=0.0)
        t = max(t, self._retired_before(upto_id))
        if t > th.t_us:
            th.t_us = t
        if not take:
            return t
        for v in take:
            del self._pending[v.cid]
            self._retire(v.cid, v.done_us)
            self._tid_maxdone.pop(v.tid, None)   # recomputed on next post
        net.fenced_verbs += len(take)
        net.wb_drains += 1
        if not self._pending:
            self._bw_tail.clear()
            self._bw_tail_rd.clear()
        return t

    def fence_all(self, th) -> float:
        """Fence the whole cid window ever posted (full barrier)."""
        return self.fence(th, self._max_cid)

    # Backward-compatible name for the PR-1 full drain.
    drain = fence_all

    # ---- epoch / thread lifecycle --------------------------------------
    def forget(self, tid: int) -> int:
        """A thread retired: drop its per-thread completion state (QP rings,
        pending-verb tracking).  The retired verbs' cost is not lost — their
        completion times move to the retired-cid record (cids are globally
        unique, so this cannot pollute a reused thread id; a *dependent*
        fence on those cids still waits) and their latest completion is a
        makespan floor.  A rescale that wants a fully clean slate ends the
        epoch via ``Sim.snapshot()``/``Sim.reset()`` after retiring."""
        if self.sim.tracer is not None:
            self.sim.tracer.note_forget(tid)
        mine = [v for v in self._pending.values() if v.tid == tid]
        for v in mine:
            self._retired_floor = max(self._retired_floor, v.done_us)
            self._retire(v.cid, v.done_us)
            del self._pending[v.cid]
        self._tid_maxdone.pop(tid, None)
        if not self._pending:
            self._bw_tail.clear()
            self._bw_tail_rd.clear()
        self.sim._forget_tid(tid)
        return len(mine)

    def dispose_server(self, dead: int, at_us: float) -> list[_Verb]:
        """Recovery quiesce: every pending verb touching ``dead`` (an async
        WRITE into it, a speculative READ out of it) can never complete —
        the RC connection died with the NIC.  Each such verb is *disposed*
        exactly once: removed from the pending window and retired at
        ``at_us``, the recovery barrier, so a dependent completion-id fence
        neither waits forever on a completion that will never arrive nor
        silently forgets the dependency (it waits until the recovery
        declared the verb dead — the moment its outcome became known).
        Verbs posted *by* threads of the dead server to surviving servers
        are NOT disposed here: their bytes were DMA'd before the crash, so
        ``forget(tid)`` retires them at their real completion times.

        Returns the disposed verbs (the RecoveryManager records their cids
        in its exactly-once ledger and routes the speculative READs through
        the ``spec_log`` invalidation discipline)."""
        victims = [v for v in self._pending.values() if v.dst == dead]
        for v in victims:
            del self._pending[v.cid]
            self._retire(v.cid, at_us)
            self._tid_maxdone.pop(v.tid, None)   # recomputed on next post
        self._bw_tail.pop(dead, None)
        self._bw_tail_rd.pop(dead, None)
        if not self._pending:
            self._bw_tail.clear()
            self._bw_tail_rd.clear()
        if self.sim.tracer is not None and victims:
            self.sim.tracer.note_orphans([v.cid for v in victims])
        return victims

    def end_epoch(self) -> None:
        """End an observation epoch (``Sim.snapshot()``/``Sim.reset()``):
        clear every per-thread tail — pending verbs, legacy per-destination
        wires, QP state, and the retired-thread floor — so reused thread ids
        in a later epoch (elastic rescale) start clean."""
        self._pending.clear()
        self._bw_tail.clear()
        self._bw_tail_rd.clear()
        self._retired.clear()
        self._retired_hi = (0, 0.0)
        self._retired_floor = 0.0
        self._tid_maxdone.clear()
        self.sim._clear_qp_state()


class Sim:
    """Virtual-time cluster: per-server stats, per-thread clocks (on Thread).

    ``qps_per_thread``/``ooo`` select the completion model (module
    docstring): the defaults reproduce the PR-1 plane exactly; ``ooo=True``
    enables per-verb out-of-order completions over ``qps_per_thread`` queue
    pairs per thread with shared-link congestion.
    """

    def __init__(self, n_servers: int, cores_per_server: int = 16,
                 cost: CostModel | None = None, qps_per_thread: int = 1,
                 ooo: bool = False):
        self.n = n_servers
        self.cores = cores_per_server
        self.cost = cost or CostModel()
        # Event tracer (``repro.analysis.sanitizer.Sanitizer``), installed
        # by ``Cluster(sanitize=True)``.  None = off: the completion plane
        # emits nothing — observation only, byte-identical either way.
        self.tracer = None
        self.qps = max(1, int(qps_per_thread))
        self.ooo = bool(ooo)
        self.servers = [ServerStats() for _ in range(n_servers)]
        self.net = NetStats()
        self._cids = itertools.count(1)          # cluster-wide completion ids
        self._qp_rr: dict[int, int] = {}         # tid -> last QP index rung
        self._qp_tail: dict[tuple[int, int], float] = {}  # (tid,qp) -> engine
        self._qp_done: dict[tuple[int, int], float] = {}  # (tid,qp) -> last CQE
        self.wb = WritebackQueue(self)
        # straggler model: per-server compute slowdown (thermal throttling,
        # noisy neighbours, failing DIMMs...).  1.0 = healthy.
        self.slowdown = [1.0] * n_servers
        # Failure state.  ``failing`` = unresponsive but not yet declared:
        # verbs posted to it burn the degraded-mode retry ladder and raise;
        # ``failed`` = declared dead by the controller (recovery ran or is
        # running): verbs raise immediately.  ``degrade`` escalates to
        # ``mark_failing`` escalates to ``declare_failed``.
        self.failing: set[int] = set()
        self.failed: set[int] = set()
        # ``lost`` = machines whose *compute* is gone forever (the scheduler
        # and controller never place threads there again).  ``rehosted``
        # maps a lost server's partition index to the surviving server now
        # physically serving it (backup promotion): traffic to the index
        # keeps its addresses but lands on the backup's NIC/CPU.
        self.lost: set[int] = set()
        self.rehosted: dict[int, int] = {}

    def batch(self) -> IOBatch:
        return IOBatch(self)

    def degrade(self, server: int, factor: float) -> None:
        """Slow-but-alive straggler (verbs still complete).  A server that
        stops answering entirely escalates to ``mark_failing`` (verbs burn
        the retry ladder) and finally ``declare_failed`` (fail-over ran)."""
        self.slowdown[server] = factor

    # ---- failure / elasticity -----------------------------------------
    def mark_failing(self, server: int) -> None:
        """The server stopped responding (crash suspected, not declared):
        synchronous verbs to it now time out through the retry/backoff
        ladder; async posts still enqueue (the NIC accepts the WQE — the
        verb becomes an orphan the recovery quiesce disposes)."""
        if server in self.failed:
            return
        self.failing.add(server)

    def declare_failed(self, server: int) -> None:
        """Controller declared the failure: every subsequent verb to the
        server raises ``ServerLostError`` immediately (no retry ladder) —
        until ``rehost`` remaps the partition onto its promoted backup."""
        self.failing.discard(server)
        self.failed.add(server)
        self.lost.add(server)

    def rehost(self, dead: int, backup: int) -> None:
        """Backup promotion completed: the dead server's partition index is
        served by ``backup`` from now on — verbs to it succeed again, and
        their NIC/CPU occupancy is charged to the backup's stats (that is
        the promoted replica absorbing the dead server's traffic).  The
        dead machine's *compute* stays lost."""
        self.rehosted[dead] = backup
        self.failed.discard(dead)

    def _serve(self, server: int) -> int:
        """Physical server currently serving a partition index (follows
        rehost chains — a promoted backup may itself have died later)."""
        while server in self.rehosted:
            server = self.rehosted[server]
        return server

    def alive_servers(self) -> list[int]:
        return [s for s in range(self.n) if s not in self.lost]

    def check_reachable(self, th, server: int, sync: bool = True) -> None:
        """Reachability gate charged before a verb to ``server``.  Declared
        failures raise immediately.  For a failing-but-undeclared server, a
        *synchronous* verb burns the full retry ladder on the caller's
        clock before raising (that latency is how the caller — and through
        it the controller's probe loop — learns the peer is gone); an
        *async* post (``sync=False``) is accepted by the local NIC and
        raises nothing — the verb simply never completes and is disposed
        as an orphan by the recovery quiesce."""
        if not (self.failed or self.failing):
            return
        if server in self.failed:
            raise ServerLostError(server, "declared failed; verb rejected")
        if sync and server in self.failing:
            pen = self.cost.retry_penalty_us()
            th.t_us += pen
            self.servers[th.server].cpu_busy_us += pen
            self.net.degraded_retries += self.cost.max_retries
            raise ServerLostError(
                server, f"unresponsive after {self.cost.max_retries} retries")

    def add_server(self) -> int:
        """Elastic grow: append a fresh server to the cluster (stats,
        slowdown, link accounting) and restripe the QP plane.  Returns the
        new server index.  The heap partition / cache / replica extension
        is the cluster layer's job (``Cluster.add_server``)."""
        s = self.n
        self.n += 1
        self.servers.append(ServerStats())
        self.slowdown.append(1.0)
        self.restripe()
        return s

    def restripe(self) -> None:
        """The server set changed (shrink or grow): every thread's RC
        connections are re-established against the new membership, so the
        per-thread QP rings/tails/CQ state are dropped.  Accumulated
        link/cpu occupancy is kept — it is history that already happened
        and still floors the makespan."""
        self._clear_qp_state()

    # ---- completion plane primitives -----------------------------------
    def next_cid(self) -> int:
        return next(self._cids)

    def select_qp(self, th) -> tuple[int, int]:
        """Round-robin QP pick for ``th``'s next doorbell; charges the QP
        switch cost when the ring differs from the thread's previous one."""
        tid = getattr(th, "tid", 0)
        prev = self._qp_rr.get(tid)
        qp = 0 if prev is None else (prev + 1) % self.qps
        self._qp_rr[tid] = qp
        if prev is not None and qp != prev:
            self.net.qp_switches += 1
            th.t_us += self.cost.qp_switch_us
        return (tid, qp)

    def qp_complete(self, th, server: int, nbytes: int,
                    n_verbs: int = 1) -> float:
        """Run one doorbell (``n_verbs`` coalesced WQEs, ``nbytes`` total)
        through the out-of-order completion model: pick the thread's next
        QP, serialize on its engine (bandwidth- or message-rate-limited),
        charge the shared link, add base latency, and floor by the QP's
        in-order CQ.  Returns the completion time; only the ``ooo=True``
        paths call this."""
        cost = self.cost
        key = self.select_qp(th)
        start = max(th.t_us, self._qp_tail.get(key, 0.0))
        occupancy = max(cost.xfer_us(nbytes), cost.qp_msg_us * n_verbs)
        engine_done = start + occupancy
        link_done = self.link_xfer(start, server, nbytes)
        self._qp_tail[key] = engine_done
        done = max(engine_done, link_done) + cost.one_sided_base_us
        done = max(done, self._qp_done.get(key, 0.0))        # CQ in order
        self._qp_done[key] = done
        return done

    def wire_done(self, start_us: float, server: int, nbytes: int) -> float:
        """Wire completion for a synchronous transfer starting at
        ``start_us``: the shared-link congestion model under ``ooo=True``,
        plain bandwidth otherwise — one dispatch point so the legacy and
        congested models cannot drift apart per call site."""
        if self.ooo:
            return self.link_xfer(start_us, server, nbytes)
        return start_us + self.cost.xfer_us(nbytes)

    def link_xfer(self, start_us: float, server: int, nbytes: int) -> float:
        """Charge an ``nbytes`` transfer to ``server``'s shared link: the
        transfer itself runs at link bandwidth from ``start_us`` (returned
        completion time), and the occupancy accumulates in
        ``ServerStats.link_busy_us`` — a saturated link is a *makespan*
        floor, exactly like a saturated CPU.  (A busy-until scalar would
        time-warp here: threads execute in program order with unsynchronized
        virtual clocks, so a thread ahead in time would spuriously delay a
        thread still in the link's idle past.)  Only the ``ooo=True``
        congestion model calls this; the caller guards."""
        us = self.cost.link_xfer_us(nbytes)
        self.servers[self._serve(server)].link_busy_us += us
        return start_us + us

    def _forget_tid(self, tid: int) -> None:
        self._qp_rr.pop(tid, None)
        for qp in range(self.qps):
            self._qp_tail.pop((tid, qp), None)
            self._qp_done.pop((tid, qp), None)

    def _clear_qp_state(self) -> None:
        self._qp_rr.clear()
        self._qp_tail.clear()
        self._qp_done.clear()

    # ---- thread-charged primitives -------------------------------------
    def compute(self, th, cycles: float) -> None:
        us = self.cost.cycles_us(cycles) * self.slowdown[th.server]
        th.t_us += us
        self.servers[th.server].cpu_busy_us += us

    def busy(self, th, us: float) -> None:
        us *= self.slowdown[th.server]
        th.t_us += us
        self.servers[th.server].cpu_busy_us += us

    def local_access(self, th, nbytes: int = 0) -> None:
        # In-memory object access; bandwidth term only for bulk payloads.
        us = self.cost.local_access_us + (nbytes / 2e4 if nbytes > 4096 else 0.0)
        th.t_us += us
        self.servers[th.server].cpu_busy_us += us

    def deref_check(self, th) -> None:
        self.busy(th, self.cost.deref_check_us)

    def rdma_read(self, th, src_server: int, nbytes: int) -> None:
        """One-sided READ: no CPU on the remote side."""
        self.check_reachable(th, src_server)
        self.next_cid()
        th.t_us = (self.wire_done(th.t_us, src_server, nbytes)
                   + self.cost.one_sided_base_us)
        self.net.one_sided_reads += 1
        self.net.bytes_moved += nbytes
        self.net.round_trips += 1
        self.servers[self._serve(src_server)].bytes_out += nbytes
        self.servers[th.server].bytes_in += nbytes

    def rdma_write(self, th, dst_server: int, nbytes: int) -> None:
        self.check_reachable(th, dst_server)
        self.next_cid()
        th.t_us = (self.wire_done(th.t_us, dst_server, nbytes)
                   + self.cost.one_sided_base_us)
        self.net.one_sided_writes += 1
        self.net.bytes_moved += nbytes
        self.net.round_trips += 1
        self.servers[self._serve(dst_server)].bytes_in += nbytes
        self.servers[th.server].bytes_out += nbytes

    def rdma_atomic(self, th, dst_server: int) -> None:
        self.check_reachable(th, dst_server)
        self.next_cid()
        th.t_us += self.cost.atomic_verb_us
        self.net.atomics += 1
        self.net.round_trips += 1

    def rpc(self, th, dst_server: int, req_bytes: int = 64,
            resp_bytes: int = 64, proc_us: float | None = None) -> None:
        """Two-sided request/response; remote CPU does ``proc_us`` of work."""
        self.check_reachable(th, dst_server)
        proc = self.cost.msg_proc_us if proc_us is None else proc_us
        us = (self.cost.two_sided_rtt_us + self.cost.xfer_us(req_bytes + resp_bytes)
              + proc)
        th.t_us += us
        self.net.two_sided_msgs += 2
        self.net.round_trips += 1
        self.net.bytes_moved += req_bytes + resp_bytes
        serve = self._serve(dst_server)
        self.servers[serve].cpu_busy_us += proc
        self.servers[serve].msgs += 1

    def ship_closure(self, th, dst_server: int, nbytes: int = 64) -> int:
        """Ship a delegated critical-section closure (captured arguments +
        code pointer, ~64 B) to a lock home as a doorbell-batched one-sided
        WRITE on the completion plane.  The poster pays only the issue
        cost — the closure's *completion* is observed when its convoy head
        polls (``convoy_complete``), and an orphaned closure (home died
        before running it) is disposed exactly once by the recovery
        quiesce like any other pending verb.  Returns the completion id."""
        return self.wb.post(th, dst_server, nbytes, kind="closure")

    def convoy_complete(self, th, home_server: int, new_convoy: bool,
                        one_sided: bool = True) -> None:
        """Completion accounting for one delegated critical section.  The
        *convoy head* (first waiter to arrive after the previous batch
        drained) pays one completion poll — a one-sided READ of the result
        slot under drust, the response half of the two-sided exchange under
        GAM/Grappa — and one round trip; joiners ride the head's poll
        (that is the N-waiters-one-round-trip amortization).  Latency is
        the caller's job (``sync.py`` owns the convoy serialization
        clock); this charges only the deterministic counters."""
        net = self.net
        net.delegated_sections += 1
        if new_convoy:
            net.convoy_completions += 1
            net.round_trips += 1
            if one_sided:
                net.one_sided_reads += 1
            else:
                net.two_sided_msgs += 1

    def async_msg(self, dst_server: int, nbytes: int = 64) -> None:
        """Off-critical-path message (e.g. async dealloc, lazy invalidation)."""
        if dst_server in self.failed:
            return                       # dropped on the floor: nobody listens
        self.net.async_msgs += 1
        self.net.bytes_moved += nbytes
        serve = self._serve(dst_server)
        self.servers[serve].cpu_busy_us += self.cost.msg_proc_us * 0.5
        self.servers[serve].msgs += 1

    # ---- aggregation ----------------------------------------------------
    def makespan_us(self, threads) -> float:
        """App completion time: slowest thread, a saturated server's CPU, or
        the last in-flight async write-back (pipelined cost is still cost)."""
        per_server_thread = [0.0] * self.n
        for t in threads:
            per_server_thread[t.server] = max(per_server_thread[t.server], t.t_us)
        span = self.wb.pending_completion_us
        for s in range(self.n):
            cpu = self.servers[s].cpu_busy_us / self.cores
            span = max(span, per_server_thread[s], cpu,
                       self.servers[s].link_busy_us)
        return span

    def snapshot(self) -> dict:
        """Stats snapshot; also ends the observation epoch — per-thread
        completion-plane state (write-back tails, QP rings) is cleared so a
        later epoch reusing thread ids (elastic rescale) starts clean.
        Compute ``makespan_us`` *before* snapshotting."""
        out = {
            "net": dataclasses.asdict(self.net),
            "servers": [dataclasses.asdict(s) for s in self.servers],
        }
        self.wb.end_epoch()
        return out

    def reset(self) -> None:
        """Zero every stat and clear the completion plane (fresh trace on
        the same cluster)."""
        self.net = NetStats()
        self.servers = [ServerStats() for _ in range(self.n)]
        self.wb.end_epoch()
        self.wb.posted = 0
