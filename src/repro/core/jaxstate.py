"""Ownership-guided distributed state for JAX (the paper's technique as a
first-class framework feature).

A training/serving stack is a DSM problem: parameters, optimizer state and
KV pages are mutable objects with one writer (the optimizer step / the
decoding request) and many readers (forward replicas, eval, serving weight
refresh, async checkpoint).  ``OwnedState`` applies DRust's protocol to a
JAX pytree:

  * the pytree has a **colored logical address** (name, color);
  * the writer takes a *mutable borrow* — exclusive, buffers donated into the
    step function — and the color is bumped when the borrow drops (one bump
    per write epoch, the U-bit rule);
  * readers take *immutable borrows* keyed by the colored address.  A reader
    whose cache matches the color does **zero communication**; a stale reader
    refetches.  No invalidation traffic exists anywhere.

``StateCache`` is the per-replica read cache (hashmap H).  ``ReplicaSlot``
is the fault-tolerance hook: write-backs are batched per epoch and flushed
at the borrow drop (ownership-transfer point), exactly §4.2.3.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from .ownership import BorrowError


@dataclass(frozen=True)
class ColoredAddr:
    """Logical colored address of a distributed pytree."""
    name: str
    color: int

    def bumped(self) -> "ColoredAddr":
        return ColoredAddr(self.name, self.color + 1)


class OwnedState:
    """A distributed pytree under the ownership protocol."""

    _uid = itertools.count()

    def __init__(self, name: str, tree: Any, sharding: Any = None):
        self.addr = ColoredAddr(f"{name}#{next(self._uid)}", 0)
        self._tree = tree
        self.sharding = sharding
        self._live_refs = 0
        self._live_mut = False
        self._u = False                       # U bit: bumped this epoch?
        self.write_epochs = 0
        self.on_epoch: list[Callable[[ColoredAddr, Any], None]] = []

    # ---- immutable borrow -------------------------------------------------
    def borrow(self) -> "StateRef":
        if self._live_mut:
            raise BorrowError(f"{self.addr.name}: read during write epoch")
        self._live_refs += 1
        self._u = False                       # B.4: new & resets U
        return StateRef(self, self.addr)

    # ---- mutable borrow -----------------------------------------------------
    def borrow_mut(self) -> "StateMutRef":
        if self._live_mut or self._live_refs:
            raise BorrowError(f"{self.addr.name}: write while borrows alive")
        self._live_mut = True
        return StateMutRef(self)

    # ---- owner access (Algorithm 7/8 analogue) ------------------------------
    def read(self) -> Any:
        if self._live_mut:
            raise BorrowError(f"{self.addr.name}: owner read in write epoch")
        self._u = False
        return self._tree

    def write(self, tree: Any) -> None:
        with self.borrow_mut() as ref:
            ref.set(tree)

    @property
    def color(self) -> int:
        return self.addr.color


class StateRef:
    """Immutable borrow: a colored read-only view.  Use as a scoped guard
    (``with state.borrow() as tree:``) — same RAII discipline as the DSM
    layer's ``ReadGuard``; use after drop raises ``BorrowError``."""

    def __init__(self, owner: OwnedState, addr: ColoredAddr):
        self.owner = owner
        self.addr = addr
        self._dropped = False

    def deref(self) -> Any:
        if self._dropped:
            raise BorrowError(
                f"{self.addr.name}: payload used outside the guard scope")
        return self.owner._tree

    @property
    def value(self) -> Any:
        return self.deref()

    def drop(self) -> None:
        if not self._dropped:
            self._dropped = True
            self.owner._live_refs -= 1

    def __enter__(self):
        return self.deref()

    def __exit__(self, *exc):
        self.drop()
        return False


class StateMutRef:
    """Exclusive write epoch; color bump + epoch hooks fire on drop.  Use
    as a scoped guard (``with state.borrow_mut() as m:``) — the same
    ``value``/``set``/``update`` slot surface as the DSM ``WriteGuard``;
    an exception inside the scope still drops the borrow, and use after
    drop raises ``BorrowError``."""

    def __init__(self, owner: OwnedState):
        self.owner = owner
        self._dropped = False
        self._accessed = False

    def _check_open(self) -> None:
        if self._dropped:
            raise BorrowError(f"{self.owner.addr.name}: write slot used "
                              "outside the guard scope")

    def deref_mut(self) -> Any:
        self._check_open()
        self._accessed = True
        return self.owner._tree

    @property
    def value(self) -> Any:
        return self.deref_mut()

    def set(self, tree: Any) -> None:
        self._check_open()
        self._accessed = True
        self.owner._tree = tree

    def update(self, fn: Callable[[Any], Any]) -> Any:
        val = fn(self.deref_mut())
        self.set(val)
        return val

    def drop(self) -> None:
        if self._dropped:
            return
        self._dropped = True
        o = self.owner
        o._live_mut = False
        if self._accessed:
            # Every write epoch bumps the color.  (The DSM layer additionally
            # implements the paper's U-bit dedup — see core.ownership — but a
            # train step IS the epoch boundary here: checkpoints and replica
            # refresh key off it.)
            o.addr = o.addr.bumped()          # the color bump = invalidation
            o._u = True
            o.write_epochs += 1
            for hook in o.on_epoch:           # batched write-back flush point
                hook(o.addr, o._tree)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.drop()
        return False


class StateCache:
    """Per-replica read cache (hashmap H): colored addr -> cached tree.

    ``fetch`` returns the cached tree when the color matches (zero comms);
    otherwise calls ``transfer`` (e.g. a device_put / collective pull),
    replaces the entry, and counts the refresh.  There is no invalidation
    path — stale entries simply become unreachable, like the paper's cache.
    """

    def __init__(self, transfer: Callable[[Any], Any] | None = None):
        self.entries: dict[str, tuple[int, Any]] = {}
        self.transfer = transfer or (lambda t: t)
        self.hits = 0
        self.refreshes = 0
        self.bytes_transferred = 0

    def fetch(self, state: OwnedState) -> Any:
        with state.borrow() as tree:
            name, color = state.addr.name, state.addr.color
            hit = self.entries.get(name)
            if hit is not None and hit[0] == color:
                self.hits += 1
                return hit[1]
            copied = self.transfer(tree)
            self.entries[name] = (color, copied)
            self.refreshes += 1
            self.bytes_transferred += _tree_bytes(copied)
            return copied

    def evict_stale(self, live: dict[str, int]) -> int:
        victims = [k for k, (c, _) in self.entries.items()
                   if k not in live or live[k] != c]
        for k in victims:
            del self.entries[k]
        return len(victims)


class ReplicaSlot:
    """§4.2.3 for pytrees: a backup copy refreshed once per write epoch."""

    def __init__(self, state: OwnedState):
        self.state = state
        self.backup: tuple[int, Any] | None = None
        self.flushes = 0
        state.on_epoch.append(self._flush)

    def _flush(self, addr: ColoredAddr, tree: Any) -> None:
        # Batched write-back: one snapshot per epoch, at the visibility
        # point.  Must be a real copy: the live buffers are donated into the
        # next step (aliasing them would hand the backup to the optimizer).
        import jax.numpy as jnp
        self.backup = (addr.color, jax.tree.map(jnp.copy, tree))
        self.flushes += 1

    def promote(self) -> Any:
        """Failure of the primary: the backup becomes the state."""
        if self.backup is None:
            raise RuntimeError("no backup to promote")
        color, tree = self.backup
        self.state._tree = tree
        self.state.addr = ColoredAddr(self.state.addr.name, color)
        return tree


def _tree_bytes(tree: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "nbytes"):
            total += int(leaf.nbytes)
        elif hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += int(leaf.size) * leaf.dtype.itemsize
    return total
