"""repro.core — DRust's ownership-guided DSM, protocol-exact, plus the
JAX-facing ownership state store (``jaxstate``).

Entry points:
  * ``Cluster(n, backend=...)`` — simulated deployment (drust | gam | grappa)
  * ``ProtocolBackend`` — the backend-generic verb ABC all three implement
  * scoped guards — ``with box.read(th) as v:`` / ``with box.write(th) as
    w:`` / ``with cluster.region(th) as r:`` (see ``protocol``)
  * ``DrustRuntime`` — the coherence protocol engine (Algorithms 1-8)
  * ``OwnedState`` — colored, borrow-checked distributed pytrees for JAX
"""

from . import addr
from .baselines import GamBackend, GrappaBackend, GHandle
from .cache import LocalCache
from .channel import Channel
from .fault import RecoveryManager, RecoveryReport, Replicator
from .heap import GlobalHeap, Obj, Partition
from .jaxstate import (ColoredAddr, OwnedState, ReplicaSlot, StateCache,
                       StateMutRef, StateRef)
from .net import (CostModel, IOBatch, NetStats, ServerLostError, Sim,
                  WritebackQueue)
from .ownership import (BorrowError, DBox, DrustBackend, DrustRuntime, MutRef,
                        Ref, StackRef)
from .protocol import (ProtocolBackend, ReadGuard, Region, WriteGuard,
                       backend_caps, backend_class)
from .runtime import (Cluster, CoalescePolicy, DerefCoalescer,
                      GlobalController, Scheduler, Thread)
from .sync import DAtomic, DMutex, DRwLock

__all__ = [
    "addr", "backend_caps", "backend_class", "BorrowError", "Channel",
    "Cluster", "CoalescePolicy", "ColoredAddr", "CostModel",
    "DAtomic", "DBox", "DerefCoalescer", "DMutex", "DRwLock",
    "DrustBackend", "DrustRuntime", "GamBackend",
    "GHandle", "GlobalController", "GlobalHeap", "GrappaBackend", "IOBatch",
    "LocalCache", "MutRef", "NetStats", "Obj", "OwnedState", "Partition",
    "ProtocolBackend", "ReadGuard", "RecoveryManager", "RecoveryReport",
    "Ref", "Region", "ReplicaSlot",
    "Replicator", "Scheduler", "ServerLostError", "Sim", "StackRef",
    "StateCache", "StateMutRef", "StateRef", "Thread", "WritebackQueue",
    "WriteGuard",
]
