"""PGAS global heap: per-server partitions, first-fit free-list allocator.

Every server backs one partition of the shared address space (paper Fig. 3).
Objects are real Python payloads (bytes / numpy arrays) tracked with explicit
sizes; allocation returns raw 48-bit global addresses whose partition index
identifies the backing server (``addr.server_of``).

``Obj.ties`` holds the raw addresses of TBox-tied children (affinity groups,
§4.1.3): moving/copying an object transfers its transitive tie-closure in one
batched message.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

from . import addr as A


@dataclass
class Obj:
    data: Any
    size: int
    ties: list[int] = field(default_factory=list)   # raw addrs of tied children


class Partition:
    """One server's slice of the global heap."""

    QUARANTINE = 16      # freed blocks sit out this many frees before reuse

    def __init__(self, server: int):
        self.server = server
        self.base, self.limit = A.partition_range(server)
        self._cursor = self.base + 64        # keep 0 offset unused (NULL-safe)
        self._free: list[tuple[int, int]] = []  # (addr, size) reuse list
        self._quarantine: list[tuple[int, int]] = []
        self.objects: dict[int, Obj] = {}
        self.used = 0

    # -- allocation ------------------------------------------------------
    def alloc(self, size: int, data: Any) -> int:
        size = max(1, int(size))
        for i, (a, sz) in enumerate(self._free):
            if sz >= size:
                self._free.pop(i)
                if sz > size:
                    self._free.append((a + size, sz - size))
                self.objects[a] = Obj(data, size)
                self.used += size
                return a
        a = self._cursor
        if a + size > self.limit:
            raise MemoryError(f"server {self.server} heap partition exhausted")
        self._cursor += size
        self.objects[a] = Obj(data, size)
        self.used += size
        return a

    def free(self, raw: int) -> Obj:
        obj = self.objects.pop(raw)
        self.used -= obj.size
        # Deferred reuse: a freed address sits out QUARANTINE frees so a
        # recycled address cannot alias a colored pointer still in flight
        # (the ABA window that B.4's async invalidation also covers).
        self._quarantine.append((raw, obj.size))
        if len(self._quarantine) > self.QUARANTINE:
            self._free.append(self._quarantine.pop(0))
        return obj

    # -- access ----------------------------------------------------------
    def get(self, raw: int) -> Obj:
        return self.objects[raw]

    def contains(self, raw: int) -> bool:
        return raw in self.objects

    @property
    def capacity(self) -> int:
        return self.limit - self.base

    @property
    def frac_used(self) -> float:
        return self.used / self.capacity


class GlobalHeap:
    """The PGAS: one partition per server + a shared stack region map."""

    def __init__(self, n_servers: int, partition_bytes: int | None = None):
        self.n = n_servers
        self.partitions = [Partition(s) for s in range(n_servers)]
        if partition_bytes is not None:
            for p in self.partitions:
                p.limit = p.base + partition_bytes

    def add_partition(self, partition_bytes: int | None = None) -> Partition:
        """Elastic grow: back a new server with a fresh partition.  The
        global address space already reserves the range (addresses encode
        the partition index), so growing is just mapping it."""
        p = Partition(self.n)
        if partition_bytes is not None:
            p.limit = p.base + partition_bytes
        self.partitions.append(p)
        self.n += 1
        return p

    def partition_of(self, raw: int) -> Partition:
        return self.partitions[A.server_of(raw)]

    def alloc_on(self, server: int, size: int, data: Any) -> int:
        return self.partitions[server].alloc(size, data)

    def get(self, raw: int) -> Obj:
        return self.partition_of(raw).get(raw)

    def free(self, raw: int) -> Obj:
        return self.partition_of(raw).free(raw)

    def contains(self, raw: int) -> bool:
        return self.partition_of(raw).contains(raw)

    def tie_closure(self, raw: int) -> list[int]:
        """Transitive TBox group rooted at ``raw`` (including the root)."""
        out, stack, seen = [], [raw], set()
        while stack:
            a = stack.pop()
            if a in seen:
                continue
            seen.add(a)
            out.append(a)
            stack.extend(self.get(a).ties)
        return out

    def group_bytes(self, raw: int) -> int:
        return sum(self.get(a).size for a in self.tie_closure(raw))
