"""Per-server read-only cache: the hashmap H of Algorithms 2/4/7.

Maps a *colored* global address to (local copy address, live-ref count).
Copies live in the server's regular heap partition (the cache is a "virtual"
aggregation, §4.1.1); entries with refcount 0 are reclaimed lazily under
memory pressure.  Because keys are colored, any write (which bumps the color
or moves the object) makes stale entries unreachable — they age out without
any invalidation message.
"""

from __future__ import annotations

from dataclasses import dataclass

from . import addr as A
from .heap import Partition


@dataclass
class CacheEntry:
    local: int          # raw address of the copy in the local partition
    refcount: int


class LocalCache:
    def __init__(self, server: int, partition: Partition):
        self.server = server
        self.partition = partition
        self.entries: dict[int, CacheEntry] = {}   # colored g -> entry
        self.hits = 0
        self.misses = 0

    def lookup(self, colored_g: int) -> CacheEntry | None:
        e = self.entries.get(colored_g)
        if e is not None:
            self.hits += 1
        else:
            self.misses += 1
        return e

    def insert(self, colored_g: int, local_raw: int, refcount: int = 1) -> CacheEntry:
        e = CacheEntry(local_raw, refcount)
        self.entries[colored_g] = e
        return e

    def inc(self, colored_g: int) -> CacheEntry:
        e = self.entries[colored_g]
        e.refcount += 1
        return e

    def dec(self, colored_g: int) -> None:
        e = self.entries.get(colored_g)
        if e is not None and e.refcount > 0:
            e.refcount -= 1

    def remove(self, colored_g: int) -> CacheEntry | None:
        return self.entries.pop(colored_g, None)

    def invalidate_raw(self, raw: int) -> int:
        """Async invalidation on dealloc/move (Appendix B.4): drop every entry
        whose underlying raw address matches, freeing the local copies."""
        victims = [g for g in self.entries if A.clear_color(g) == raw]
        for g in victims:
            e = self.entries.pop(g)
            if self.partition.contains(e.local):
                self.partition.free(e.local)
        return len(victims)

    def evict_unreferenced(self) -> int:
        """Lazy reclamation under memory pressure (§4.2.1)."""
        victims = [g for g, e in self.entries.items() if e.refcount <= 0]
        freed = 0
        for g in victims:
            e = self.entries.pop(g)
            if self.partition.contains(e.local):
                freed += self.partition.get(e.local).size
                self.partition.free(e.local)
        return freed

    @property
    def bytes_cached(self) -> int:
        return sum(self.partition.get(e.local).size
                   for e in self.entries.values()
                   if self.partition.contains(e.local))
