"""Per-server read-only cache: the hashmap H of Algorithms 2/4/7.

Maps a *colored* global address to (local copy address, live-ref count).
Copies live in the server's regular heap partition (the cache is a "virtual"
aggregation, §4.1.1); entries with refcount 0 are reclaimed lazily under
memory pressure.  Because keys are colored, any write (which bumps the color
or moves the object) makes stale entries unreachable — they age out without
any invalidation message.

Indexing
--------
Two structures keep every hot-path operation O(1) amortized:

* ``_by_raw`` — secondary index from the *uncolored* raw address to the set
  of colored keys currently caching it, so dealloc-time invalidation
  (``invalidate_raw``, Appendix B.4) touches only matching entries instead
  of scanning the whole map.
* ``bytes_cached`` — a counter maintained on insert/remove/invalidate/evict
  (it used to be a full scan summing partition sizes).
* ``_by_cid`` — speculative-prefetch index from a prefetch doorbell's
  completion id to the colored keys it fetched, so a transfer/mutation
  before first use invalidates exactly that doorbell's entries
  (``invalidate_cid``) in O(1).  A speculative entry that leaves the cache
  any other way (eviction, B.4 invalidation, insert-replace) fires the
  ``on_spec_drop`` hook so the runtime can record the cid as wasted.

Eviction under memory pressure is CLOCK-style second chance: ``lookup`` sets
a reference bit, ``evict_clock`` sweeps a persistent hand, giving recently
hit entries one more pass before their copies are freed.  Pinned entries
(refcount > 0) are never evicted.
"""

from __future__ import annotations

from dataclasses import dataclass

from . import addr as A
from .heap import Partition


@dataclass
class CacheEntry:
    local: int          # raw address of the copy in the local partition
    refcount: int
    size: int = 0       # copy size, captured at insert (for bytes_cached)
    ref_bit: bool = True  # CLOCK second-chance bit
    speculative: bool = False  # prefetched, completion fence still deferred
    cid: int = 0        # completion id of the speculative fetch doorbell
    suspect: bool = False  # home server failed while this copy was pinned:
    #   the frozen snapshot keeps serving its open ReadGuards, but new
    #   lookups MISS (they must re-fetch the restored epoch value) and the
    #   copy is freed the moment the last pin drops


class LocalCache:
    def __init__(self, server: int, partition: Partition):
        self.server = server
        self.partition = partition
        self.entries: dict[int, CacheEntry] = {}   # colored g -> entry
        self._by_raw: dict[int, set[int]] = {}     # raw -> colored keys
        self._by_cid: dict[int, set[int]] = {}     # spec cid -> colored keys
        self._bytes = 0
        self._hand = 0                             # CLOCK hand (key index)
        # colored g -> [local, pins]: suspect entries displaced by a
        # re-fetch while still pinned (see ``insert``); freed at pins==0
        self._limbo: dict[int, list[int]] = {}
        self.hits = 0
        self.misses = 0
        # Runtime hook: a *speculative* entry left the cache without being
        # materialized (evicted / B.4-invalidated) — the runtime records the
        # cid's disposition so every speculative fetch is fenced or
        # invalidated exactly once.
        self.on_spec_drop = lambda cid: None

    def lookup(self, colored_g: int) -> CacheEntry | None:
        e = self.entries.get(colored_g)
        if e is not None and not e.suspect:
            self.hits += 1
            e.ref_bit = True
            return e
        # A suspect entry is invisible to new readers: its bytes may hold a
        # write that died unflushed with the home server, and serving them
        # would resurrect it.  Open pins keep their frozen snapshot through
        # the direct local address; everyone else misses and re-fetches.
        self.misses += 1
        return None

    def insert(self, colored_g: int, local_raw: int, refcount: int = 1,
               speculative: bool = False, cid: int = 0) -> CacheEntry:
        size = (self.partition.get(local_raw).size
                if self.partition.contains(local_raw) else 0)
        old = self.entries.get(colored_g)
        if old is not None:
            self._drop_index(colored_g, old)
            if old.suspect and old.refcount > 0:
                # A new reader re-fetched past a still-pinned suspect copy:
                # the frozen snapshot must outlive the key collision, so it
                # parks in limbo until its pins drain (``dec`` drains limbo
                # first — the pre-crash pins are the ones that drop next).
                lim = self._limbo.get(colored_g)
                if lim is None:
                    self._limbo[colored_g] = [old.local, old.refcount]
                else:
                    lim[1] += old.refcount
            elif old.suspect:
                self._free_copy(old)
        e = CacheEntry(local_raw, refcount, size=size,
                       speculative=speculative, cid=cid)
        self.entries[colored_g] = e
        self._by_raw.setdefault(A.clear_color(colored_g), set()).add(colored_g)
        if speculative:
            self._by_cid.setdefault(cid, set()).add(colored_g)
        self._bytes += size
        return e

    def materialize(self, colored_g: int) -> None:
        """First materialized use of a speculative entry: the completion
        fence ran — the entry becomes a regular warm copy."""
        e = self.entries.get(colored_g)
        if e is None or not e.speculative:
            return
        e.speculative = False
        keys = self._by_cid.get(e.cid)
        if keys is not None:
            keys.discard(colored_g)
            if not keys:
                del self._by_cid[e.cid]

    def invalidate_cid(self, cid: int) -> int:
        """Kill every still-speculative entry of a prefetch doorbell (the
        source moved ownership / mutated before first use).  Returns the
        number of entries dropped.  Does NOT fire ``on_spec_drop`` — the
        caller is the runtime, already recording the disposition."""
        victims = self._by_cid.pop(cid, None)
        if not victims:
            return 0
        n = 0
        for g in victims:
            e = self.entries.pop(g, None)
            if e is None:
                continue
            raw_keys = self._by_raw.get(A.clear_color(g))
            if raw_keys is not None:
                raw_keys.discard(g)
                if not raw_keys:
                    del self._by_raw[A.clear_color(g)]
            self._bytes -= e.size
            self._free_copy(e)
            n += 1
        return n

    def inc(self, colored_g: int) -> CacheEntry:
        e = self.entries[colored_g]
        e.refcount += 1
        return e

    def dec(self, colored_g: int) -> None:
        lim = self._limbo.get(colored_g)
        if lim is not None:              # a displaced frozen snapshot drains
            lim[1] -= 1
            if lim[1] <= 0:
                del self._limbo[colored_g]
                if self.partition.contains(lim[0]):
                    self.partition.free(lim[0])
            return
        e = self.entries.get(colored_g)
        if e is not None and e.refcount > 0:
            e.refcount -= 1
            if e.refcount == 0 and e.suspect:
                # last pin of a crash-frozen snapshot dropped: the copy is
                # both stale (pre-crash bytes) and unreachable (lookup
                # misses) — free it now instead of waiting for pressure
                self.entries.pop(colored_g, None)
                self._drop_index(colored_g, e)
                self._free_copy(e)

    def remove(self, colored_g: int) -> CacheEntry | None:
        e = self.entries.pop(colored_g, None)
        if e is not None:
            self._drop_index(colored_g, e)
        return e

    def _drop_index(self, colored_g: int, e: CacheEntry) -> None:
        raw = A.clear_color(colored_g)
        keys = self._by_raw.get(raw)
        if keys is not None:
            keys.discard(colored_g)
            if not keys:
                del self._by_raw[raw]
        self._bytes -= e.size
        if e.speculative:
            cids = self._by_cid.get(e.cid)
            if cids is not None:
                cids.discard(colored_g)
                if not cids:
                    del self._by_cid[e.cid]
            self.on_spec_drop(e.cid)

    def _free_copy(self, e: CacheEntry) -> int:
        if self.partition.contains(e.local):
            freed = self.partition.get(e.local).size
            self.partition.free(e.local)
            return freed
        return 0

    def invalidate_raw(self, raw: int) -> int:
        """Async invalidation on dealloc/move (Appendix B.4): drop every entry
        whose underlying raw address matches, freeing the local copies.
        O(1) amortized via the raw index (was a full-map scan)."""
        victims = self._by_raw.pop(raw, None)
        if not victims:
            return 0
        n = 0
        for g in victims:
            e = self.entries.pop(g, None)
            if e is None:
                continue
            self._bytes -= e.size
            if e.speculative:
                cids = self._by_cid.get(e.cid)
                if cids is not None:
                    cids.discard(g)
                    if not cids:
                        del self._by_cid[e.cid]
                self.on_spec_drop(e.cid)
            self._free_copy(e)
            n += 1
        return n

    def quarantine_home(self, home: int) -> tuple[int, int]:
        """The home server of some cached objects failed: copies of its
        boxes may hold writes that died unflushed (the restored replica
        reverts to the last flushed epoch), so serving them would silently
        resurrect lost writes.  Unpinned copies are invalidated on the
        spot; pinned copies (open ``ReadGuard``s — frozen snapshots by
        contract) are marked *suspect*: they keep serving their holders but
        are invisible to new lookups and are freed when the last pin drops.
        Returns ``(invalidated, suspected)`` entry counts."""
        victims = [(g, e) for g, e in self.entries.items()
                   if A.server_of(A.clear_color(g)) == home]
        invalidated = suspected = 0
        for g, e in victims:
            if e.refcount > 0:
                e.suspect = True
                suspected += 1
            else:
                self.entries.pop(g, None)
                self._drop_index(g, e)       # spec entries fire on_spec_drop
                self._free_copy(e)
                invalidated += 1
        return invalidated, suspected

    def drop_all(self) -> int:
        """The cache's own server died: every entry is gone with it.  Fires
        ``on_spec_drop`` for still-speculative entries (their prefetch cids
        get an ``invalidated`` disposition) but does not touch the backing
        partition — the crash already cleared it."""
        n = len(self.entries)
        for g, e in list(self.entries.items()):
            self._drop_index(g, e)
        self.entries.clear()
        self._by_raw.clear()
        self._by_cid.clear()
        self._limbo.clear()
        self._bytes = 0
        return n

    def evict_unreferenced(self) -> int:
        """Lazy reclamation under memory pressure (§4.2.1): free every
        unpinned copy.  Returns bytes freed."""
        victims = [g for g, e in self.entries.items() if e.refcount <= 0]
        freed = 0
        for g in victims:
            e = self.entries.pop(g)
            self._drop_index(g, e)
            freed += self._free_copy(e)
        return freed

    def evict_clock(self, target_bytes: int) -> int:
        """CLOCK second-chance eviction: free unpinned copies until at least
        ``target_bytes`` are reclaimed (or every candidate had its chance).
        Entries hit since the last sweep survive one extra pass."""
        freed = 0
        keys = list(self.entries)
        if not keys:
            return 0
        scans = 0
        limit = 2 * len(keys)
        while freed < target_bytes and scans < limit:
            self._hand %= len(keys)
            g = keys[self._hand]
            scans += 1
            e = self.entries.get(g)
            if e is None or e.refcount > 0:
                self._hand += 1
                continue
            if e.ref_bit:
                e.ref_bit = False
                self._hand += 1
                continue
            self.entries.pop(g)
            self._drop_index(g, e)
            freed += self._free_copy(e)
            self._hand += 1
        return freed

    @property
    def bytes_cached(self) -> int:
        return self._bytes
