"""DRust's ownership-guided coherence protocol (paper §4.1.1, Appendix B).

The user-facing surface is the **scoped-guard API** (``core/protocol.py``):

    with box.read(th) as v:        # enter = immutable borrow + deref
        use(v)                     # body  = the deref'd payload
                                   # exit  = DropRef (release the pin)

    with box.write(th) as w:       # enter = exclusive mutable borrow
        w.set(new_value)           # deref_mut + store
                                   # exit  = DropMutRef (the write-back)

    with cluster.region(th) as r:  # batching scope (see core/runtime.py)
        r.prefetch(boxes)          # speculative read doorbells
        ...                        # exit = coalescer settle point

The guard scope *is* the borrow lifetime, so the runtime is told the settle
points (quantum close, write-back, release) instead of inferring them, and
an exception inside a guard body structurally releases the borrow — no
unbalanced-drop leaks.  The legacy call-pair surface
(``backend.read/write/update/free``) is kept as a thin shim implemented on
top of the guards, charging byte-identical costs.

Legacy call pairs → guard surface migration:

    ====================================  ===================================
    legacy (manual call pairs)            scoped guards
    ====================================  ===================================
    ``r = box.borrow(th)``                ``with box.read(th) as v: ...``
    ``v = r.deref(th); r.drop(th)``
    ``m = box.borrow_mut(th)``            ``with box.write(th) as w:``
    ``m.deref_mut(th); ...; m.drop(th)``  ``    w.set(x)  # or w.value / w.update(fn)``
    ``val = backend.read(th, box)``       unchanged (shim over the read guard)
    ``backend.write(th, box, x)``         unchanged (shim over the write guard)
    ``backend.prefetch(th, boxes)``       ``with cluster.region(th) as r: r.prefetch(boxes)``
    ``val, ref = read_cached(th, box)``   ``r.pin(boxes)`` inside a region
    ====================================  ===================================

Underneath, the protocol is implemented operation-for-operation:

  * Algorithm 4  — immutable-reference Deref / DropRef (cache hashmap H)
  * Algorithm 6  — mutable-reference DerefMut (move-on-remote-write, pointer
                   coloring + U bit on local write) / DropMutRef (owner
                   write-back of the colored address)
  * Algorithm 7  — owner immutable access (borrow+return pair)
  * Algorithm 8  — owner mutable access (incl. adopting an existing local
                   cache copy instead of re-copying)
  * Algorithm 3/5 — color utilities (see ``addr``), move-on-overflow
  * Appendix D.1 — stack values / partial borrows (copy + write-back)
  * Appendix D.2 — reference creation & ownership transfer (cache eviction)
  * §4.1.3       — TBox affinity groups (batched group fetch/move, check-free
                   deref) and spawn_to support hooks

``DrustRuntime`` implements the backend-generic ``ProtocolBackend`` ABC —
the same verb surface as the GAM/Grappa baselines — so the applications are
backend-generic; only DRust's implementation threads real ownership state
through the verbs.

Python has no borrow checker, so Rust's *static* guarantees are enforced
dynamically: every DBox tracks live borrows and raises ``BorrowError`` on
violations — the tests drive only programs a Rust compiler would accept, and
the hypothesis suite checks the protocol's coherence lemmas (Appendix C).

Colors are authoritative in *pointers* (exactly as in the paper); the heap
keeps a mirror (``obj_color``) only so batched TBox group fetches can key
children cache entries without threading every handle through the runtime —
the mirror is bookkeeping, not protocol state.
"""

from __future__ import annotations

import copy as _copy
from typing import Any, Callable

from . import addr as A
from .cache import LocalCache
from .heap import GlobalHeap, Obj
from .net import ServerLostError, Sim
from .protocol import (BorrowError, ProtocolBackend, ReadGuard, WriteGuard,
                       register_backend)


try:
    import numpy as _np
except Exception:      # pragma: no cover
    _np = None

_SCALARS = (bytes, int, float, str, bool, complex, type(None))


def _clone(data: Any) -> Any:
    """Payload snapshot.  Scalars pass through; flat lists/tuples/dicts of
    scalars and numpy arrays take a shallow-copy fast path (no memo dict, no
    recursion); everything else falls back to ``deepcopy``."""
    if isinstance(data, _SCALARS):
        return data
    if _np is not None and isinstance(data, _np.ndarray):
        return data.copy()
    if isinstance(data, list):
        if all(isinstance(x, _SCALARS) for x in data):
            return list(data)
    elif isinstance(data, tuple):
        if all(isinstance(x, _SCALARS) for x in data):
            return data
    elif isinstance(data, dict):
        if all(isinstance(k, _SCALARS) and isinstance(v, _SCALARS)
               for k, v in data.items()):
            return dict(data)
    return _copy.deepcopy(data)


class DBox:
    """Owner pointer (DRust's ``DBox<T>``, re-implemented ``Box``)."""

    __slots__ = ("g", "l", "u", "home", "rt", "live_refs", "live_mut",
                 "dropped", "tied", "wb_cids", "fetch_cid", "fetch_server",
                 "lost", "mut_broken", "mut_tid", "ref_tids", "site")

    def __init__(self, rt: "DrustRuntime", g: int, home: int, tied: bool = False):
        self.rt = rt
        self.g = g          # colored global address (word 0)
        self.l = A.NULL     # ext word, read path: local cache copy address
        self.u = False      # ext word, write path: U bit
        self.home = home    # server hosting the *pointer* (for write-back cost)
        self.live_refs = 0
        self.live_mut = False
        self.dropped = False
        self.tied = tied    # this owner is a TBox (affinity-tied to a parent)
        self.wb_cids: list[int] = []   # in-flight write-back completion ids
        self.fetch_cid = 0             # in-flight speculative prefetch cid
        self.fetch_server: int | None = None   # server that prefetched
        # Recovery state (all no-ops on the no-failure path).
        self.lost = False        # payload died unrecoverably with its server
        self.mut_broken = False  # open WriteGuard's home died: the pending
        #   mutation can never be written back — the guard surfaces
        #   ServerLostError and releases without write-back
        self.mut_tid: int | None = None   # tid holding the mutable borrow
        self.ref_tids: dict[int, int] = {}  # tid -> live read borrows held
        # Placement override: the server a ``transfer`` shipped the owner
        # to.  None = the payload location (``server_of(g)``) is the
        # placement target; any payload relocation clears the override —
        # the data caught up with (or overtook) the pointer.
        self.site: int | None = None

    def __repr__(self):
        return (f"DBox(g={A.clear_color(self.g):#x}c{A.get_color(self.g)}, "
                f"l={self.l:#x}, u={self.u})")

    # Scoped-guard surface -------------------------------------------------
    def read(self, th) -> ReadGuard:
        """``with box.read(th) as v:`` — scoped immutable borrow."""
        return ReadGuard(self.rt, th, self)

    def write(self, th) -> WriteGuard:
        """``with box.write(th) as w:`` — scoped mutable borrow; exit is
        the DropMutRef write-back."""
        return WriteGuard(self.rt, th, self)

    # Rust surface: borrows ------------------------------------------------
    def borrow(self, th) -> "Ref":
        self._check_live()
        if self.live_mut:
            raise BorrowError("immutable borrow while mutable borrow alive")
        self.live_refs += 1
        tid = getattr(th, "tid", 0)
        self.ref_tids[tid] = self.ref_tids.get(tid, 0) + 1
        self.u = False                      # B.4: creating & ref resets U
        return Ref(self.rt, self.g, owner=self, tid=tid)

    def borrow_mut(self, th) -> "MutRef":
        self._check_live()
        self.rt._coalesce_conflict(self)    # flush pending registered derefs
        if self.live_mut or self.live_refs:
            raise BorrowError("mutable borrow while other borrows alive")
        self.rt._invalidate_prefetch(self)  # speculative bytes go stale
        self._release_pin()                 # owner's cached copy unpinned
        self.live_mut = True
        self.mut_tid = getattr(th, "tid", 0)
        return MutRef(self.rt, self.g, owner=self, u=self.u)

    def _check_live(self):
        if self.dropped:
            raise BorrowError("use after drop")
        if self.lost:
            raise ServerLostError(
                A.server_of(self.g),
                "object lost with its home server (no replica to restore)")

    def _release_pin(self):
        if self.l != A.NULL:
            self.rt.caches[A.server_of(self.l)].dec(self.g)
            self.l = A.NULL


class Ref:
    """Shared immutable reference (``&T``)."""

    __slots__ = ("rt", "g", "l", "owner", "dropped", "tid")

    def __init__(self, rt: "DrustRuntime", g: int, owner: DBox | None,
                 tid: int = 0):
        self.rt = rt
        self.g = g          # colored global address, copied at creation (D.2)
        self.l = A.NULL     # local copy address (filled on first deref)
        self.owner = owner
        self.dropped = False
        self.tid = tid      # borrower thread (recovery releases dead holders)

    def clone(self) -> "Ref":
        """New ref from a ref: copies only the global address (D.2)."""
        if self.owner is not None:
            self.owner.live_refs += 1
            tids = self.owner.ref_tids
            tids[self.tid] = tids.get(self.tid, 0) + 1
        return Ref(self.rt, self.g, self.owner, tid=self.tid)

    def deref(self, th) -> Any:
        """Algorithm 4."""
        assert not self.dropped
        rt, sim = self.rt, self.rt.sim
        sim.deref_check(th)
        if A.server_of(self.g) == th.server:                 # IsLocal
            sim.local_access(th)
            return rt.heap.get(A.clear_color(self.g)).data
        if self.l == A.NULL:
            H = rt.caches[th.server]
            sim.busy(th, sim.cost.hashmap_us)
            e = H.lookup(self.g)
            if e is not None:                                # lines 7-10
                rt._touch_spec(th, H, self.g, e, self.owner)
                self.l = e.local
                e.refcount += 1
            else:                                            # lines 11-13
                self.l = rt._copy_in(th, self.g)
                H.insert(self.g, self.l, refcount=1)
        sim.local_access(th)
        return rt.heap.get(self.l).data

    def drop(self, th) -> None:
        """DropRef: release the cache pin."""
        if self.dropped:
            return
        self.dropped = True
        if self.l != A.NULL:
            self.rt.caches[th.server].dec(self.g)
            self.l = A.NULL
        if self.owner is not None:
            self.owner.live_refs -= 1
            tids = self.owner.ref_tids
            left = tids.get(self.tid, 0) - 1
            if left > 0:
                tids[self.tid] = left
            else:
                tids.pop(self.tid, None)


class MutRef:
    """Exclusive mutable reference (``&mut T``)."""

    __slots__ = ("rt", "g", "u", "owner", "dropped", "accessed")

    def __init__(self, rt: "DrustRuntime", g: int, owner: DBox, u: bool):
        self.rt = rt
        self.g = g
        self.u = u          # U bit of the extension word (owner addr | U)
        self.owner = owner
        self.dropped = False
        self.accessed = False

    def deref_mut(self, th) -> Any:
        """Algorithm 6: returns the payload at a local, writable address."""
        assert not self.dropped
        if self.owner.mut_broken:
            raise ServerLostError(
                A.server_of(self.owner.g),
                "mutable borrow broken: the object's home server failed")
        rt, sim = self.rt, self.rt.sim
        sim.deref_check(th)
        self.accessed = True
        if A.server_of(self.g) == th.server:                 # local write
            if not self.u:                                   # lines 3-6
                self.u = True
                g2, overflow = A.bump_color(self.g)
                if overflow:                                 # move-on-overflow
                    g2 = A.append_color(rt._move_local(th, self.g), 0)
                self.g = g2
                rt._mirror_color(self.g)
        else:                                                # lines 7-9
            self.u = True
            self.g = A.append_color(rt._move_in(th, self.g), A.get_color(self.g))
            rt._mirror_color(self.g)
        sim.local_access(th)
        return rt.heap.get(A.clear_color(self.g)).data

    def set(self, th, data: Any) -> None:
        obj = self.rt.heap.get(A.clear_color(self.deref_and_addr(th)))
        obj.data = data

    def deref_and_addr(self, th) -> int:
        self.deref_mut(th)
        return A.clear_color(self.g)

    def drop(self, th) -> None:
        """DropMutRef: WRITE the colored address back into the owner slot.

        The 8-byte pointer write-back is posted on the async write-back
        queue: the dropping thread pays only the issue cost; the verb's
        completion id is recorded on the owner so synchronization points
        (ownership transfer, drop-time dealloc, makespan) fence exactly the
        ids they depend on — the next owner access goes through the new
        address regardless, so coherence (Appendix C) is unaffected."""
        if self.dropped:
            return
        self.dropped = True
        rt, owner = self.rt, self.owner
        if owner.mut_broken:
            # Guard-aware fail-over: the object's home died while this
            # mutable borrow was open.  The pending mutation can never be
            # written back (the restored replica reverts to the last flushed
            # epoch) — release the borrow WITHOUT posting a write-back and
            # without committing the speculative colored address, then
            # surface the loss structurally.
            owner.mut_broken = False
            owner.live_mut = False
            owner.mut_tid = None
            raise ServerLostError(
                A.server_of(owner.g),
                "write-back impossible: home server failed mid-mutation "
                "(un-flushed write lost, reverted to last flushed epoch)")
        if owner.home != th.server:
            if rt.batch_io:
                owner.wb_cids.append(
                    rt.sim.wb.post(th, owner.home, 8))       # pipelined WRITE
            else:
                rt.sim.rdma_write(th, owner.home, 8)         # sync WRITE
        else:
            rt.sim.local_access(th)
        owner.g = self.g
        owner.u = self.u
        owner.l = A.NULL       # stale read-path ext cannot survive a new g
        owner.live_mut = False
        owner.mut_tid = None
        if self.accessed:
            rt.on_write_visible(A.clear_color(self.g))       # FT write-back hook


class StackRef:
    """Appendix D.1: mutable borrow of a stack value / struct part.

    The borrowed bytes are *copied* to the borrowing server and written back
    on drop (the address cannot change); the parent owner's color is bumped
    atomically so remote caches of the parent miss afterwards.
    """

    __slots__ = ("rt", "parent", "data", "size", "src_server", "dropped")

    def __init__(self, rt: "DrustRuntime", parent: DBox, data: Any, size: int,
                 src_server: int):
        self.rt, self.parent = rt, parent
        self.data, self.size, self.src_server = data, size, src_server
        self.dropped = False

    def deref_mut(self, th) -> Any:
        self.rt.sim.deref_check(th)
        self.rt.sim.local_access(th)
        return self.data

    def drop(self, th) -> None:
        if self.dropped:
            return
        self.dropped = True
        rt = self.rt
        if th.server != self.src_server:
            if rt.batch_io:
                cid = rt.sim.wb.post(th, self.src_server, self.size)
                if self.parent is not None:   # transfer of the parent fences it
                    self.parent.wb_cids.append(cid)
            else:
                rt.sim.rdma_write(th, self.src_server, self.size)
        else:
            rt.sim.local_access(th, self.size)
        if self.parent is not None:
            g2, overflow = A.bump_color(self.parent.g)
            if overflow:
                g2 = A.append_color(rt._move_local(th, self.parent.g), 0)
            self.parent.g = g2
            rt._mirror_color(self.parent.g)
            self.parent.live_mut = False
            rt.on_write_visible(A.clear_color(self.parent.g))


@register_backend
class DrustRuntime(ProtocolBackend):
    """Per-cluster protocol engine: heap + caches + the op implementations.

    Implements the backend-generic ``ProtocolBackend`` verb surface (it IS
    the drust backend — the old ``DrustBackend`` facade survives as a thin
    deprecated shim), plus the owner/borrow primitives the guards build on.

    ``batch_io`` selects the communication plane: ``True`` (default) uses
    doorbell coalescing for group fetches and the async pipeline for
    write-backs; ``False`` reproduces the naive plane — one verb per object,
    synchronous write-backs — for A/B cost ablations.  Protocol *state* is
    identical under both planes; only the cost accounting differs.
    """

    name = "drust"
    supports_ownership = True
    supports_affinity = True
    supports_prefetch = True
    supports_coalescing = True

    def __init__(self, sim: Sim, heap: GlobalHeap | None = None,
                 batch_io: bool = True):
        self.sim = sim
        self.batch_io = batch_io
        self.heap = heap or GlobalHeap(sim.n)
        self.caches = [LocalCache(s, self.heap.partitions[s])
                       for s in range(sim.n)]
        self.owner_of: dict[int, DBox] = {}    # raw addr -> unique owner handle
        self.obj_color: dict[int, int] = {}    # bookkeeping mirror (see module doc)
        self.tie_parent: dict[int, int] = {}   # raw child -> raw tie parent
        # fault-tolerance hook; replaced by repro.core.fault.Replicator
        self.on_write_visible: Callable[[int], None] = lambda raw: None
        self.on_alloc: Callable[[int], None] = lambda raw: None
        self.on_free: Callable[[int], None] = lambda raw: None
        self.on_transfer: Callable[[int], None] = lambda raw: None
        self.on_move: Callable[[int, int], None] = lambda old, new: None
        # Deref coalescer (installed by Cluster under ``coalesce="auto"``);
        # None = every deref fetches eagerly (the manual plane).
        self.coalescer = None
        # Speculative-prefetch ledger: every posted prefetch cid, and its
        # disposition ("fenced" at first use | "invalidated" before use).
        # The staleness-safety property suite checks every cid is disposed
        # exactly once.
        self.spec_cids: list[int] = []
        self.spec_log: dict[int, str] = {}
        for H in self.caches:
            H.on_spec_drop = (
                lambda cid: self._dispose_spec(cid, "invalidated"))

    # ---- guard hooks (the scoped-borrow surface) -------------------------
    def _enter_read(self, th, box: DBox):
        """Read-guard entry: register with the coalescer when it wants the
        deref (the registration borrow is owned by the coalescer and drops
        at the flush), else take the borrow eagerly (Algorithm 4)."""
        co = self.coalescer
        if co is not None and co.wants(th, box):
            return None, co.register(th, box)
        r = box.borrow(th)
        return r, r.deref(th)

    def _exit_read(self, th, box: DBox, token) -> None:
        if token is not None:
            token.drop(th)

    def _enter_pin(self, th, box: DBox):
        """Region pin: always the eager held borrow (never a coalescer
        registration — a registration flushes on a conflicting write
        instead of excluding it, which is the opposite of a pin)."""
        r = box.borrow(th)
        return r, r.deref(th)

    def _enter_write(self, th, box: DBox):
        return box.borrow_mut(th)

    def _write_value(self, th, box: DBox, m: "MutRef") -> Any:
        return m.deref_mut(th)

    def _write_set(self, th, box: DBox, m: "MutRef", data: Any) -> None:
        if not m.accessed:
            m.deref_mut(th)                  # first touch: Algorithm 6
        self.heap.get(A.clear_color(m.g)).data = data

    def _exit_write(self, th, box: DBox, m: "MutRef") -> None:
        m.drop(th)                           # DropMutRef: the write-back

    # ---- whole-object verbs (thin shims over the guards) -----------------
    def read(self, th, box: DBox) -> Any:
        with ReadGuard(self, th, box) as v:
            # Whole-object read is copy-out: the caller keeps the value
            # past the internal guard, so hand out a plain copy, never the
            # guard-scoped (sanitize: tombstoned) snapshot.
            return v if self.sanitizer is None else self.sanitizer.adopt(v)

    def write(self, th, box: DBox, data: Any) -> None:
        with WriteGuard(self, th, box) as w:
            w.set(data)

    def read_cached(self, th, box: DBox) -> tuple[Any, "Ref"]:
        """Long-lived immutable borrow (caller drops); prefer
        ``Region.pin`` on the guard surface."""
        r = box.borrow(th)
        return r.deref(th), r

    def drop(self, th, box: DBox) -> None:
        self.drop_box(th, box)

    # ---- allocation ------------------------------------------------------
    def alloc(self, th, size: int, data: Any = None, server: int | None = None,
              tie_to: DBox | None = None) -> DBox:
        """Global allocation (§4.2.1): local-first, controller may redirect.

        ``tie_to`` makes this a TBox allocation: the object is co-located
        with (and tied to) its owner object's partition.
        """
        if tie_to is not None:
            server = A.server_of(tie_to.g)
        elif server is None:
            server = th.server
        self.sim.busy(th, self.sim.cost.alloc_us)
        if server != th.server:
            self.sim.rpc(th, server, req_bytes=64 + (size if data is not None else 0))
        raw = self.heap.alloc_on(server, size, data)
        box = DBox(self, A.append_color(raw, 0), home=th.server,
                   tied=tie_to is not None)
        self.owner_of[raw] = box
        self.obj_color[raw] = 0
        if tie_to is not None:
            parent_raw = A.clear_color(tie_to.g)
            self.heap.get(parent_raw).ties.append(raw)
            self.tie_parent[raw] = parent_raw
        self.on_alloc(raw)
        th.local_heap_bytes += size if server == th.server else 0
        return box

    def stack_val(self, th, size: int, data: Any) -> DBox:
        """A stack value exposed for borrowing (D.1): modeled as an object in
        the thread's partition that is never moved (address pinned)."""
        raw = self.heap.alloc_on(th.server, size, data)
        box = DBox(self, A.append_color(raw, 0), home=th.server)
        self.owner_of[raw] = box
        self.obj_color[raw] = 0
        return box

    # ---- owner direct access (Algorithms 7/8) ----------------------------
    def owner_read(self, th, box: DBox) -> Any:
        """Algorithm 7 (a borrow+return pair; resets U per B.4)."""
        box._check_live()
        if box.live_mut:
            raise BorrowError("owner read while mutable borrow alive")
        sim = self.sim
        sim.deref_check(th)
        box.u = False
        if A.server_of(box.g) == th.server:
            sim.local_access(th)
            return self.heap.get(A.clear_color(box.g)).data
        if box.l == A.NULL:
            H = self.caches[th.server]
            sim.busy(th, sim.cost.hashmap_us)
            e = H.lookup(box.g)
            if e is not None:
                self._touch_spec(th, H, box.g, e, box)
                box.l = e.local
                e.refcount += 1
            else:
                box.l = self._copy_in(th, box.g)
                H.insert(box.g, box.l, refcount=1)
        sim.local_access(th)
        return self.heap.get(box.l).data

    def owner_write(self, th, box: DBox, fn: Callable[[Any], Any] | None = None,
                    data: Any = None) -> Any:
        """Algorithm 8 (incl. adopting an existing local cache copy)."""
        box._check_live()
        self._coalesce_conflict(box)
        if box.live_mut or box.live_refs:
            raise BorrowError("owner write while borrows alive")
        self._invalidate_prefetch(box)
        box._release_pin()
        sim = self.sim
        sim.deref_check(th)
        if A.server_of(box.g) == th.server:
            if not box.u:                                    # lines 3-6
                box.u = True
                g2, overflow = A.bump_color(box.g)
                if overflow:
                    g2 = A.append_color(self._move_local(th, box.g), 0)
                box.g = g2
                self._mirror_color(box.g)
        else:
            H = self.caches[th.server]
            sim.busy(th, sim.cost.hashmap_us)
            e = H.lookup(box.g)
            if e is None:                                    # lines 8-10
                box.u = True
                box.g = A.append_color(self._move_in(th, box.g),
                                       A.get_color(box.g))
            else:                                            # lines 11-16: adopt
                H.remove(box.g)
                old_raw = A.clear_color(box.g)
                new_raw = e.local
                # the adopted copy inherits the tie edges of the original
                self.heap.get(new_raw).ties = list(self.heap.get(old_raw).ties)
                self._relocate_tie_links(old_raw, new_raw)
                self._dealloc_remote(th, old_raw)
                self.owner_of.pop(old_raw, None)
                self.owner_of[new_raw] = box
                self.obj_color[new_raw] = A.get_color(box.g)
                box.g = A.append_color(new_raw, A.get_color(box.g))
                box.u = True
                box.site = None        # adopted copy: payload relocated
            box.l = A.NULL
            self._mirror_color(box.g)
        sim.local_access(th)
        obj = self.heap.get(A.clear_color(box.g))
        if fn is not None:
            obj.data = fn(obj.data)
        elif data is not None:
            obj.data = data
        self.on_write_visible(A.clear_color(box.g))
        return obj.data

    # ---- drop / transfer ---------------------------------------------------
    def drop_box(self, th, box: DBox) -> None:
        """Owner out of scope: drop of the whole tied closure, dealloc, and
        async invalidation of cached copies on every server (B.4).

        Dealloc requests and invalidations for the closure are *coalesced*:
        one async message per remote server carrying every freed address
        (instead of one per object), and one invalidation scrub per cache."""
        if box.dropped:
            return
        self._coalesce_conflict(box)
        if box.live_mut or box.live_refs:
            raise BorrowError("drop while borrows alive")
        stack, group = [box], []
        wb_upto = 0
        while stack:
            b = stack.pop()
            if b.dropped:
                continue
            self._coalesce_conflict(b)
            if b.live_mut or b.live_refs:
                raise BorrowError("drop while borrows alive")
            b._release_pin()
            b.dropped = True
            if b.wb_cids:
                wb_upto = max(wb_upto, max(b.wb_cids))
                b.wb_cids.clear()
            if b.fetch_cid:
                # B.4 dealloc: an in-flight speculative READ of the dropped
                # slots must complete before they are freed — fence its cid
                # like a write-back; the unused entries are invalidated.
                wb_upto = max(wb_upto, b.fetch_cid)
                self._invalidate_prefetch(b)
            raw = A.clear_color(b.g)
            if not self.heap.contains(raw):
                continue
            group.append(raw)
            for child in list(self.heap.get(raw).ties):
                child_box = self.owner_of.get(child)
                if child_box is not None and not child_box.dropped:
                    stack.append(child_box)
        if wb_upto:
            # B.4 dealloc: in-flight owner-slot write-backs into the dropped
            # closure must complete before the slots are freed (the NIC may
            # not WRITE into recycled memory) — fence only those ids.
            self.sim.wb.fence(th, wb_upto)
        if not group:
            return
        remote: dict[int, int] = {}              # server -> freed addr count
        freed = set(group)
        for raw in group:
            s = A.server_of(raw)
            if s != th.server:
                remote[s] = remote.get(s, 0) + 1
            self.heap.free(raw)
            self.on_free(raw)
            self.owner_of.pop(raw, None)
            self.obj_color.pop(raw, None)
            self._unlink_tie(raw, freed)
        if self.batch_io:
            for s, n in remote.items():
                self.sim.async_msg(s, 16 * n)    # one coalesced dealloc req
        else:
            for s, n in remote.items():
                for _ in range(n):
                    self.sim.async_msg(s, 16)    # naive: one req per object
        self._async_invalidate_many(group)

    def transfer(self, th_src, box: DBox, dst_server: int) -> None:
        """Ownership transfer between threads/servers (D.2): only the pointer
        moves; the source server's cache copy is deallocated."""
        self._coalesce_conflict(box)
        if box.live_mut or box.live_refs:
            raise BorrowError("transfer while borrows alive")
        if box.l != A.NULL:
            H = self.caches[A.server_of(box.l)]
            H.dec(box.g)
            e = H.entries.get(box.g)
            if e is not None and e.refcount <= 0:
                H.remove(box.g)
                part = self.heap.partitions[A.server_of(box.l)]
                if part.contains(box.l):
                    part.free(box.l)
            box.l = A.NULL
        # §4.2.3: ownership transfer is the visibility point — fence exactly
        # the write-back completion ids this pointer depends on (the box's
        # own and its tied children's), plus any in-flight speculative
        # prefetch of the moving closure (the NIC's READ must complete
        # before the object can move; the unused speculative entries are
        # invalidated — ownership moved before first use).  Later verbs
        # stay in flight.
        upto = max(self._take_wb_deps(box), self._take_spec_deps(box))
        if upto:
            self.sim.wb.fence(th_src, upto)
        self.sim.rpc(th_src, dst_server, req_bytes=16)   # ship the pointer
        box.home = dst_server
        box.site = dst_server          # data-affinity now follows the owner
        # ... and flush batched write-backs to the backup partition now.
        self.on_transfer(A.clear_color(box.g))
        if self.sanitizer is not None:
            self.sanitizer.note_transfer(th_src, box, dst_server)

    # ---- placement (telemetry-driven; see core/runtime.py) ---------------
    def locate(self, box: DBox) -> int:
        """Current data-affinity target: a ``transfer``'s destination while
        the payload has not caught up (``site``), else the payload's server
        — ``g`` is rewritten on every write-move, so this tracks live
        relocations that the allocation-time home does not."""
        if box.site is not None:
            return box.site
        return A.server_of(box.g)

    def placement_root(self, box: DBox) -> DBox:
        """The owner a placement decision actually moves: a TBox child
        migrates with (and its accesses count toward) its tie root, so the
        affinity group always moves as one closure."""
        raw = A.clear_color(box.g)
        seen: set[int] = set()
        while raw in self.tie_parent and raw not in seen:
            seen.add(raw)
            raw = self.tie_parent[raw]
        root = self.owner_of.get(raw)
        return root if root is not None else box

    def migrate_here(self, th, box: DBox) -> bool:
        """Live owner migration (placement subsystem): relocate ``box``'s
        payload — with its whole TBox closure, batched as one move — into
        ``th.server``'s partition and re-home the owner pointer there.

        Same synchronization discipline as ``transfer``: registered derefs
        flush first, the move is refused while any borrow in the closure
        is live, and exactly the write-back / speculative completion ids
        the closure depends on are fenced.  The *accessing* thread pays
        the move (hot-accessor pull).  Returns False when the migration is
        suppressed or unnecessary."""
        if box.dropped or box.lost or box.mut_broken:
            return False
        box = self.placement_root(box)   # a TBox child moves with its root
        if box.dropped or box.lost or box.mut_broken:
            return False
        raw = A.clear_color(box.g)
        if not self.heap.contains(raw):
            return False
        if A.server_of(box.g) == th.server:
            box.site = None            # payload already local: drop override
            return False
        self._coalesce_conflict(box)
        owners = [box]
        for a in self._group(raw):
            child = self.owner_of.get(a)
            if child is not None and child is not box:
                owners.append(child)
        if any(b.live_mut or b.live_refs for b in owners):
            return False               # suppressed: a borrow is live
        net = self.sim.net
        rt0 = net.round_trips
        upto = max(self._take_wb_deps(box), self._take_spec_deps(box))
        if upto:
            self.sim.wb.fence(th, upto)
        box._release_pin()
        new_raw = self._move_in(th, box.g)
        box.g = A.append_color(new_raw, A.get_color(box.g))
        self._mirror_color(box.g)
        box.l = A.NULL
        if box.home != th.server:      # pointer re-home control message
            self.sim.rpc(th, box.home, req_bytes=16)
        box.home = th.server
        box.site = None
        self.on_transfer(new_raw)      # replica epoch follows the owner
        if self.sanitizer is not None:
            self.sanitizer.note_migrate_here(th, box)
        net.owner_migrations += 1
        net.migration_round_trips += net.round_trips - rt0
        return True

    # ---- internals ---------------------------------------------------------
    def _take_wb_deps(self, box: DBox) -> int:
        """Collect (and clear) the in-flight write-back completion ids a
        synchronization point on ``box`` depends on: the box's own pending
        owner-slot write-backs plus its TBox closure's (a group move ships
        the whole closure).  Returns the highest dependent cid (0 = none) —
        the ``upto_id`` for a completion-id fence."""
        upto = max(box.wb_cids, default=0)
        box.wb_cids.clear()
        raw = A.clear_color(box.g)
        if self.heap.contains(raw):
            for a in self._group(raw):
                child = self.owner_of.get(a)
                if child is not None and child is not box and child.wb_cids:
                    upto = max(upto, max(child.wb_cids))
                    child.wb_cids.clear()
        return upto

    def _take_spec_deps(self, box: DBox) -> int:
        """Speculative-fetch analogue of ``_take_wb_deps``: collect (and
        clear) the in-flight prefetch cids of ``box`` and its TBox closure,
        invalidating their unused speculative cache entries.  Returns the
        highest dependent cid (0 = none)."""
        boxes = [box]
        raw = A.clear_color(box.g)
        if self.heap.contains(raw):
            for a in self._group(raw):
                child = self.owner_of.get(a)
                if child is not None and child is not box:
                    boxes.append(child)
        upto = 0
        for b in boxes:
            if b.fetch_cid:
                upto = max(upto, b.fetch_cid)
                self._invalidate_prefetch(b)
        return upto

    # ---- speculative prefetch ------------------------------------------
    def _dispose_spec(self, cid: int, how: str) -> bool:
        """Record a speculative cid's disposition exactly once: ``fenced``
        (materialized at first use) or ``invalidated`` (killed before use).
        Returns False when the cid was already disposed."""
        if cid == 0 or cid in self.spec_log:
            return False
        self.spec_log[cid] = how
        if how == "fenced":
            self.sim.net.late_fences += 1
        else:
            self.sim.net.wasted_prefetches += 1
        if self.sanitizer is not None:
            self.sanitizer.note_spec_dispose(cid, how, True)
        return True

    def _spec_outstanding(self, box: DBox) -> bool:
        """True while ``box``'s recorded prefetch cid is still undisposed.
        A cid disposed elsewhere (a sibling's materialization, eviction,
        B.4 invalidation — paths that cannot reach the box handle) is
        cleared lazily here, so a dead cid never blocks future prefetches
        of the box."""
        if box.fetch_cid and box.fetch_cid not in self.spec_log:
            return True
        box.fetch_cid = 0
        box.fetch_server = None
        return False

    def _invalidate_prefetch(self, box: DBox) -> None:
        """The source is about to mutate / move ownership / dealloc while a
        speculative fetch of it is outstanding and unused: kill the whole
        doorbell's speculative entries (the bytes may go stale) and record
        the cid as wasted.  No-op when nothing undisposed is in flight."""
        cid = box.fetch_cid
        srv = box.fetch_server
        box.fetch_cid = 0
        box.fetch_server = None
        if not cid or cid in self.spec_log:
            return
        if srv is not None:
            self.caches[srv].invalidate_cid(cid)
        self._dispose_spec(cid, "invalidated")

    def _touch_spec(self, th, H: LocalCache, colored_g: int, e,
                    owner: DBox | None) -> None:
        """First materialized use of a cache entry: if it is speculative,
        run the deferred completion-id fence (a *late* fence — the latency
        the prefetch hid) and promote it to a regular warm copy.  The
        fence is unconditional: a sibling entry of an already-disposed
        doorbell must still wait for the READ's completion time (the
        retired-cid record keeps it); only the disposition/counter is
        once-per-cid."""
        if not e.speculative:
            return
        self._dispose_spec(e.cid, "fenced")
        self.sim.wb.fence(th, e.cid)
        H.materialize(colored_g)
        if owner is not None and owner.fetch_cid == e.cid:
            owner.fetch_cid = 0
            owner.fetch_server = None

    def prefetch(self, th, boxes) -> int:
        """Speculative fetch (§4.2 follow-on): post one read doorbell per
        cold remote box (its whole TBox group coalesced, like ``_copy_in``)
        *without* waiting — the poster pays only the issue cost.  The
        completion id is recorded on the box and on the speculative cache
        entries; the fence is deferred to the first materialized use
        (``Ref.deref`` / ``owner_read`` / ``read_many`` hit).  Ownership
        transfer, ``drop_box``, B.4 dealloc, and owner mutation fence or
        invalidate in-flight prefetches exactly like write-backs.  No
        borrow is taken — that is what makes the fetch speculative, and
        why a pre-use mutation wastes it instead of blocking.

        Returns the number of doorbells posted.  Boxes that are local,
        already cached, already in flight, mutably borrowed, or dropped
        are skipped."""
        if not self.batch_io:
            return 0                     # naive plane: no speculation
        H = self.caches[th.server]
        posted = 0
        for b in boxes:
            if (b.dropped or b.live_mut or self._spec_outstanding(b)
                    or A.server_of(b.g) == th.server
                    or b.g in H.entries):
                continue
            raw = A.clear_color(b.g)
            if not self.heap.contains(raw):
                continue
            src = A.server_of(raw)
            members = []
            for a in self._group(raw):
                if A.server_of(a) != src:
                    continue     # member moved off the root's server (its
                    #              own deref/prefetch fetches it from there)
                key = (b.g if a == raw
                       else A.append_color(a, self.obj_color.get(a, 0)))
                if key not in H.entries:
                    members.append((a, key))
            if not members:
                continue
            total = sum(self.heap.get(a).size for a, _ in members)
            cid = self.sim.wb.post_read(th, src, total,
                                        n_verbs=len(members))
            part = self.heap.partitions[th.server]
            for a, key in members:
                obj = self.heap.get(a)
                local = part.alloc(obj.size, _clone(obj.data))
                self.sim.busy(th, self.sim.cost.alloc_us)
                H.insert(key, local, refcount=0, speculative=True, cid=cid)
                # Every fetched member records the cid — a mutation of a
                # tied *child* before first use must waste the whole
                # doorbell, not just a root-recorded one.
                owner = self.owner_of.get(a)
                if owner is not None:
                    owner.fetch_cid = cid
                    owner.fetch_server = th.server
            self.spec_cids.append(cid)
            if self.sanitizer is not None:
                self.sanitizer.note_spec(th, cid)
            posted += 1
        return posted

    def _coalesce_conflict(self, box: DBox) -> None:
        """A mutable op / transfer / drop is about to touch ``box``: any
        *registered-but-unflushed* derefs hold immutable borrows on it —
        close those threads' quanta first (flush their coalesced fetch)."""
        if self.coalescer is not None:
            self.coalescer.flush_box(box)

    def _group(self, raw: int) -> list[int]:
        return self.heap.tie_closure(raw)

    def _relocate_tie_links(self, old_raw: int, new_raw: int,
                            moved: dict[int, int] | None = None) -> None:
        """An object changed address: keep the tie graph consistent — the
        parent's ``ties`` entry, the reverse ``tie_parent`` index, and the
        children's back-links.  ``moved`` maps every old→new address of a
        group move (in-group parents are rewritten by their own call)."""
        parent = self.tie_parent.pop(old_raw, None)
        if parent is not None:
            in_group = moved is not None and parent in moved
            parent_now = moved[parent] if in_group else parent
            self.tie_parent[new_raw] = parent_now
            if not in_group and self.heap.contains(parent_now):
                ties = self.heap.get(parent_now).ties
                for i, t in enumerate(ties):
                    if t == old_raw:
                        ties[i] = new_raw
        if self.heap.contains(new_raw):
            for child in self.heap.get(new_raw).ties:
                if child in self.tie_parent:
                    self.tie_parent[child] = new_raw

    def _unlink_tie(self, raw: int, freed: set[int] | None = None) -> None:
        """An object was freed: drop its reverse link and, if its tie parent
        survives, remove the dangling forward edge."""
        parent = self.tie_parent.pop(raw, None)
        if parent is not None and (freed is None or parent not in freed) \
                and self.heap.contains(parent):
            ties = self.heap.get(parent).ties
            if raw in ties:
                ties.remove(raw)

    def _copy_in(self, th, colored_g: int, batch=None) -> int:
        """COPY: fetch object (+ TBox group) into the local cache; returns the
        local copy address of the root.  The group's N members are N coalesced
        verbs behind ONE doorbell (§4.1.3); with a caller-provided ``batch``
        the verbs join a larger doorbell committed by the caller."""
        raw = A.clear_color(colored_g)
        group = self._group(raw)
        own = batch is None
        if own and self.batch_io:
            batch = self.sim.batch()
        if batch is not None:
            for a in group:
                batch.add_read(A.server_of(a), self.heap.get(a).size)
        else:                            # naive plane: one READ verb per object
            for a in group:
                self.sim.rdma_read(th, A.server_of(a), self.heap.get(a).size)
        H = self.caches[th.server]
        part = self.heap.partitions[th.server]
        root_local = A.NULL
        for a in group:
            obj = self.heap.get(a)
            local = part.alloc(obj.size, _clone(obj.data))
            self.sim.busy(th, self.sim.cost.alloc_us)
            if a == raw:
                root_local = local
            else:
                H.insert(A.append_color(a, self.obj_color.get(a, 0)), local,
                         refcount=0)
        if own and batch is not None:
            batch.commit(th)
        return root_local

    def _move_in(self, th, colored_g: int) -> int:
        """MOVE: relocate object (+ group) into the caller's partition.
        Copy over the wire, then *async* dealloc at the source; the address
        change implicitly invalidates every cached copy."""
        raw = A.clear_color(colored_g)
        src = A.server_of(raw)
        group = self._group(raw)
        total = sum(self.heap.get(a).size for a in group)
        if self.batch_io:
            batch = self.sim.batch()
            for a in group:
                batch.add_read(A.server_of(a), self.heap.get(a).size)
            batch.commit(th)
        else:                            # naive plane: one READ verb per object
            for a in group:
                self.sim.rdma_read(th, A.server_of(a), self.heap.get(a).size)
        part = self.heap.partitions[th.server]
        remap: dict[int, int] = {}
        for a in group:
            obj = self.heap.get(a)
            remap[a] = part.alloc(obj.size, obj.data)
            self.sim.busy(th, self.sim.cost.alloc_us)
        for a in group:
            old = self.heap.get(a)
            new_obj = self.heap.get(remap[a])
            new_obj.ties = [remap.get(t, t) for t in old.ties]
        for a in group:
            self.heap.free(a)
            # the data no longer lives at `a`: FT state keyed by the old
            # address must follow the object, or a later crash of the source
            # server would "restore" a stale replica at a freed (possibly
            # reused) address
            self.on_move(a, remap[a])
            owner = self.owner_of.pop(a, None)
            color = self.obj_color.pop(a, 0)
            self.owner_of[remap[a]] = owner
            self.obj_color[remap[a]] = color
            self._relocate_tie_links(a, remap[a], moved=remap)
            if owner is not None:
                owner.site = None      # the payload relocated: no override
            if owner is not None and a != raw:
                owner.g = A.append_color(remap[a], A.get_color(owner.g))
                # The move's B.4 invalidation frees every cached copy of
                # the old address — a child owner's read-path pin (set by
                # owner_read) would dangle; the root's l is reset by the
                # caller (owner_write / DropMutRef).
                owner.l = A.NULL
        if self.batch_io:
            self.sim.async_msg(src, 16 * len(group))     # coalesced dealloc req
        else:
            for _ in group:
                self.sim.async_msg(src, 16)              # naive: one per object
        self._async_invalidate_many(group)
        th.local_heap_bytes += total
        return remap[raw]

    def _move_local(self, th, colored_g: int) -> int:
        """Move-on-overflow: relocate within the local partition, color→0."""
        raw = A.clear_color(colored_g)
        part = self.heap.partitions[th.server]
        obj = part.get(raw)
        new_raw = part.alloc(obj.size, obj.data)
        new_obj = part.get(new_raw)
        new_obj.ties = list(obj.ties)
        part.free(raw)
        self.on_move(raw, new_raw)  # FT state must not outlive the address
        owner = self.owner_of.pop(raw, None)
        if owner is not None:
            owner.site = None
        self.owner_of[new_raw] = owner
        self.obj_color.pop(raw, None)
        self.obj_color[new_raw] = 0
        self._relocate_tie_links(raw, new_raw)
        self._async_invalidate(raw)
        self.sim.busy(th, self.sim.cost.alloc_us)
        return new_raw

    def _dealloc_remote(self, th, raw: int) -> None:
        src = A.server_of(raw)
        if self.heap.contains(raw):
            self.heap.free(raw)
            self.on_free(raw)
        self.sim.async_msg(src)
        self._async_invalidate(raw)

    def _async_invalidate(self, raw: int) -> None:
        """Dealloc-time cache scrub (B.4) — async, off the critical path."""
        self._async_invalidate_many((raw,))

    def _async_invalidate_many(self, raws) -> None:
        """Coalesced B.4 scrub: ONE async message per cache server covers
        every dropped address (O(1) per address via the cache's raw index).
        The naive plane sends one scrub message per (address, server) hit."""
        for s, H in enumerate(self.caches):
            n = 0
            msgs = 0
            for raw in raws:
                hit = H.invalidate_raw(raw)
                n += hit
                msgs += 1 if hit else 0
            if n:
                self.sim.net.invalidations += n
                if self.batch_io:
                    self.sim.async_msg(s, 16 * msgs)     # one msg, all addrs
                else:
                    for _ in range(msgs):
                        self.sim.async_msg(s, 16)

    def _mirror_color(self, colored_g: int) -> None:
        self.obj_color[A.clear_color(colored_g)] = A.get_color(colored_g)

    # ---- batched reads ------------------------------------------------------
    def read_many(self, th, boxes) -> list:
        """Batched immutable read of N owners: every cold miss (and its TBox
        group) joins ONE IOBatch — one doorbell per source server — instead
        of N independent READ verbs.  The cache/heap end state is identical
        to N sequential ``read`` calls (same entries, refcounts, payloads),
        so the coherence lemmas (Appendix C) are untouched; only the cost
        accounting coalesces."""
        sim = self.sim
        refs = [b.borrow(th) for b in boxes]
        try:                             # refs drop even if a deref raises
            if not self.batch_io:        # naive plane: N independent derefs
                return [r.deref(th) for r in refs]
            H = self.caches[th.server]
            batch = sim.batch()
            vals = []
            for r in refs:
                sim.deref_check(th)
                if A.server_of(r.g) == th.server:
                    sim.local_access(th)
                    vals.append(self.heap.get(A.clear_color(r.g)).data)
                    continue
                if r.l == A.NULL:
                    sim.busy(th, sim.cost.hashmap_us)
                    e = H.lookup(r.g)
                    if e is not None:
                        self._touch_spec(th, H, r.g, e, r.owner)
                        r.l = e.local
                        e.refcount += 1
                    else:
                        r.l = self._copy_in(th, r.g, batch)
                        H.insert(r.g, r.l, refcount=1)
                sim.local_access(th)
                vals.append(self.heap.get(r.l).data)
            batch.commit(th)
            return vals
        finally:
            for r in refs:
                r.drop(th)

    # ---- memory pressure (§4.2.1) -------------------------------------------
    def evict_caches(self, server: int, target_bytes: int | None = None) -> int:
        """Reclaim unpinned cache copies: full sweep by default, CLOCK
        second-chance partial eviction when ``target_bytes`` is given."""
        if target_bytes is None:
            return self.caches[server].evict_unreferenced()
        return self.caches[server].evict_clock(target_bytes)

    def frac_used(self, server: int) -> float:
        return self.heap.partitions[server].frac_used


class DrustBackend:
    """Deprecated alias kept for import compatibility: ``DrustRuntime``
    itself implements the ``ProtocolBackend`` verb surface now.  This shim
    just forwards every attribute to the runtime."""

    name = "drust"

    def __init__(self, rt: DrustRuntime):
        self.rt = rt

    def __getattr__(self, attr: str) -> Any:
        return getattr(self.rt, attr)
