"""Distributed shared-state primitives (§4.1.2): DAtomic and DMutex.

Shared state cannot be type-checked by the ownership model, so DRust stores
the actual value on the global heap (only a Box pointer inside the Arc'd
struct) and serializes every operation at the value's home server:

* DRust uses **one-sided RDMA atomics** (FAA/CAS) — no remote CPU.
* GAM's mutexes ride its two-sided message path (the paper's explanation of
  the KV-store gap).
* Grappa delegates, as always.

Contention is modeled through the home server's CPU/verb accounting plus a
per-primitive serialization clock: an acquire cannot complete before the
previous critical section on the same mutex has released (virtual time).
"""

from __future__ import annotations

from typing import Any, Callable

from . import addr as A


class DAtomic:
    """Atomic cell; value lives at its home partition."""

    def __init__(self, cluster, th, init: Any = 0):
        self.cluster = cluster
        self.backend = cluster.backend
        self.h = self.backend.alloc(th, 8, init)
        self.home = A.server_of(self.h.g if hasattr(self.h, "g") else self.h.raw)

    def _verb(self, th) -> None:
        sim = self.cluster.sim
        if th.server == self.home:
            sim.local_access(th)
            return
        name = self.cluster.backend_name
        if name == "drust":
            sim.rdma_atomic(th, self.home)               # one-sided FAA/CAS
        elif name == "gam":
            sim.rpc(th, self.home, proc_us=sim.cost.msg_proc_us)
        else:
            sim.rpc(th, self.home, proc_us=sim.cost.delegation_proc_us)

    def _obj(self):
        raw = A.clear_color(self.h.g) if hasattr(self.h, "g") else self.h.raw
        return self.cluster.heap.get(raw)

    def fetch_add(self, th, delta: Any = 1) -> Any:
        self._verb(th)
        obj = self._obj()
        old = obj.data
        obj.data = old + delta
        return old

    def load(self, th) -> Any:
        self._verb(th)
        return self._obj().data

    def store(self, th, value: Any) -> None:
        self._verb(th)
        self._obj().data = value

    def cas(self, th, expect: Any, new: Any) -> bool:
        self._verb(th)
        obj = self._obj()
        if obj.data == expect:
            obj.data = new
            return True
        return False


class DMutex:
    """Mutex whose metadata + owned object live on the global heap."""

    def __init__(self, cluster, th, value: Any = None, size: int = 64):
        self.cluster = cluster
        self.backend = cluster.backend
        self.h = self.backend.alloc(th, size, value)
        self.home = A.server_of(self.h.g if hasattr(self.h, "g") else self.h.raw)
        self._release_t = 0.0          # serialization clock (virtual time)
        self._holder = None            # thread inside the critical section
        self.acquisitions = 0
        self.contended = 0
        self.broken = 0                # times recovery broke this lock
        # Recovery needs to find every live mutex to reconstruct lock state
        # after a crash (break locks whose holder or home died).
        registry = getattr(cluster, "mutexes", None)
        if registry is not None:
            registry.append(self)

    def _lock_verb(self, th) -> None:
        sim = self.cluster.sim
        name = self.cluster.backend_name
        if th.server == self.home:
            sim.local_access(th)
        elif name == "drust":
            sim.rdma_atomic(th, self.home)               # CAS acquire
        elif name == "gam":
            sim.rpc(th, self.home, proc_us=sim.cost.msg_proc_us)
        else:
            sim.rpc(th, self.home, proc_us=sim.cost.delegation_proc_us)

    def break_lock(self, at_us: float) -> None:
        """Recovery lock-state reconstruction: the holder (or the home
        server's lock word) died.  Force-release so later acquirers
        serialize behind the recovery barrier instead of a dead holder —
        the critical section's un-flushed effects follow the epoch-revert
        contract (lost, reported, never resurrected)."""
        self._holder = None
        self._release_t = max(self._release_t, at_us)
        self.broken += 1

    def with_lock(self, th, fn: Callable[[Any], Any]) -> Any:
        """Acquire, run the critical section at the caller, release.

        Only the critical section itself serializes; the acquire/release
        verbs overlap with other holders' sections (lock hand-off latency is
        hidden by the queue, as with MCS-style RDMA locks)."""
        self._lock_verb(th)
        self.acquisitions += 1
        if th.t_us < self._release_t:                    # wait for holder
            self.contended += 1
            th.t_us = self._release_t
        self._holder = th
        raw = A.clear_color(self.h.g) if hasattr(self.h, "g") else self.h.raw
        obj = self.cluster.heap.get(raw)
        try:
            return fn(obj)
        finally:
            # A raising critical section still unlocks — otherwise every
            # later acquirer would serialize behind a lock nobody holds
            # (the unbalanced-release analogue of an unbalanced drop).
            # If recovery broke the lock mid-section (holder declared dead),
            # the release already happened during lock-state reconstruction.
            if self._holder is th:
                self._holder = None
            self._release_t = max(self._release_t, th.t_us)  # section end
            # Release: DRust posts a one-sided WRITE (fire-and-forget
            # unlock); GAM posts its release message without waiting for
            # the ack; Grappa's delegated unlock is a blocking global-
            # memory op.
            name = self.cluster.backend_name
            if th.server == self.home:
                self.cluster.sim.local_access(th)
            elif name == "drust":
                self.cluster.sim.net.one_sided_writes += 1
            elif name == "gam":
                self.cluster.sim.async_msg(self.home)
            else:
                self._lock_verb(th)
