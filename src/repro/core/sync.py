"""Distributed shared-state primitives (§4.1.2): DAtomic, DMutex, DRwLock.

Shared state cannot be type-checked by the ownership model, so DRust stores
the actual value on the global heap (only a Box pointer inside the Arc'd
struct) and serializes every operation at the value's home server.  The
paper's KV-store gap (§7.1, Fig. 5d) is exactly this single-home cliff —
every backend convoys on the lock home — so this module offers three
escalating designs:

* **Spin locks** (``DMutex(mode="spin")``, the original design): acquire is
  a home-server verb (DRust one-sided CAS, GAM two-sided message, Grappa
  delegation), the critical section runs *at the caller* — any data it
  touches on the home costs a remote verb per access while the lock is
  held, so lock hold time spans round trips and contention compounds.

* **Delegation / combining locks** (``DMutex(mode="delegate")``): a remote
  acquirer ships its critical-section *closure* to the lock home on the
  async completion plane (one posted WRITE, issue cost only) and the home
  runs the whole convoy back-to-back — data accesses are local there, and
  only the convoy *head* pays a completion round trip; joiners ride it.
  N contended waiters pay one amortized round trip instead of N serialized
  home round trips.  Per-backend transport: drust doorbell-batched closure
  ship + one-sided result poll, GAM two-sided send/response, Grappa native
  delegation (its normal access mode — delegation is free scalability
  there, at home-CPU cost).

* **Reader leases** (``DRwLock``): a read-mostly acquirer takes a
  home-granted lease — a region-lifetime *pinned immutable borrow*, the
  same freeze the deref coalescer exploits — and every subsequent read on
  that server is a pure local pointer chase: zero verbs until a writer
  revokes.  A write first revokes every outstanding lease (one async
  WRITE per leased server, fenced through the completion-id plane — the
  revocation fence), then mutates under an exclusive guard; the next read
  re-grants against the fresh value, so a reader can never observe
  pre-revocation state after the write (staleness safety is structural).

Contention is modeled through the home server's CPU/verb accounting plus a
per-primitive serialization clock (an acquire or delegated section cannot
start before the previous critical section on the same primitive has
released, in virtual time).  Recovery treats all three uniformly:
``core/fault.py`` calls ``on_server_failed`` on every registered primitive
— spin locks break when their holder died, delegated convoys drop their
references to closure cids the quiesce already disposed (exactly once),
and leases break when the leased cache or the lease table's home died.

This module is critical-section *plumbing over the guard surface*: data
access goes through ``ReadGuard``/``WriteGuard``/heap handles, never raw
``borrow()``/``deref()`` pairs (the CI guard lint covers this file).
"""

from __future__ import annotations

from typing import Any, Callable

from . import addr as A
from .protocol import ReadGuard, WriteGuard, detach_guard


def _raw(h) -> int:
    return A.clear_color(h.g) if hasattr(h, "g") else h.raw


class DAtomic:
    """Atomic cell; value lives at its home partition.

    * DRust uses **one-sided RDMA atomics** (FAA/CAS) — no remote CPU.
    * GAM's atomics ride its two-sided message path.
    * Grappa delegates, as always.
    """

    def __init__(self, cluster, th, init: Any = 0):
        self.cluster = cluster
        self.backend = cluster.backend
        self.h = self.backend.alloc(th, 8, init)
        self.home = A.server_of(_raw(self.h))

    def _verb(self, th) -> None:
        sim = self.cluster.sim
        if th.server == self.home:
            sim.local_access(th)
            return
        name = self.cluster.backend_name
        if name == "drust":
            sim.rdma_atomic(th, self.home)               # one-sided FAA/CAS
        elif name == "gam":
            sim.rpc(th, self.home, proc_us=sim.cost.msg_proc_us)
        else:
            sim.rpc(th, self.home, proc_us=sim.cost.delegation_proc_us)

    def _obj(self):
        return self.cluster.heap.get(_raw(self.h))

    def fetch_add(self, th, delta: Any = 1) -> Any:
        self._verb(th)
        obj = self._obj()
        old = obj.data
        obj.data = old + delta
        return old

    def load(self, th) -> Any:
        self._verb(th)
        return self._obj().data

    def store(self, th, value: Any) -> None:
        self._verb(th)
        self._obj().data = value

    def cas(self, th, expect: Any, new: Any) -> bool:
        self._verb(th)
        obj = self._obj()
        if obj.data == expect:
            obj.data = new
            return True
        return False


class DMutex:
    """Mutex whose metadata + owned object live on the global heap.

    ``mode="spin"`` runs critical sections at the caller (remote data
    accesses while holding the lock); ``mode="delegate"`` ships them to
    the lock home as combining-lock convoys.  ``server`` places the lock
    (and its protected object) on a specific partition — co-locate it
    with the data it guards.
    """

    def __init__(self, cluster, th, value: Any = None, size: int = 64,
                 mode: str = "spin", server: int | None = None):
        if mode not in ("spin", "delegate"):
            raise ValueError(f"unknown DMutex mode {mode!r}")
        self.cluster = cluster
        self.backend = cluster.backend
        self.mode = mode
        self.h = self.backend.alloc(th, size, value, server=server)
        self.home = A.server_of(_raw(self.h))
        self._release_t = 0.0          # serialization clock (virtual time)
        self._holder = None            # thread inside the critical section
        self._inflight: list[int] = []  # shipped-closure cids not yet retired
        self.acquisitions = 0
        self.contended = 0
        self.delegated = 0             # sections run at the home (delegate)
        self.convoys = 0               # convoy heads (completion round trips)
        self.broken = 0                # times recovery broke this lock
        # Recovery needs to find every live primitive to reconstruct
        # lock/lease state after a crash (see ``on_server_failed``).
        registry = getattr(cluster, "mutexes", None)
        if registry is not None:
            registry.append(self)

    # ---- verbs ----------------------------------------------------------
    def _lock_verb(self, th) -> None:
        sim = self.cluster.sim
        name = self.cluster.backend_name
        if th.server == self.home:
            sim.local_access(th)
        elif name == "drust":
            sim.rdma_atomic(th, self.home)               # CAS acquire
        elif name == "gam":
            sim.rpc(th, self.home, proc_us=sim.cost.msg_proc_us)
        else:
            sim.rpc(th, self.home, proc_us=sim.cost.delegation_proc_us)

    def _release_verb(self, th) -> None:
        """Release: DRust posts the unlock as a real async verb on the
        completion plane — fire-and-forget latency (issue cost only), but
        it draws a cid, runs ``check_reachable``, and is disposed exactly
        once by the recovery quiesce if the home dies with it in flight
        (a bare counter bump here was the satellite-2 bug: unlocking a
        crashed home silently "succeeded" and the cid ledger never saw
        in-flight unlocks).  GAM posts its release message without
        waiting for the ack; Grappa's delegated unlock is a blocking
        global-memory op."""
        sim = self.cluster.sim
        name = self.cluster.backend_name
        if th.server == self.home:
            sim.local_access(th)
        elif name == "drust":
            if self.cluster.batch_io:
                sim.wb.post(th, self.home, 8)
            else:
                sim.rdma_write(th, self.home, 8)
        elif name == "gam":
            sim.async_msg(self.home)
        else:
            self._lock_verb(th)

    def charge_section(self, th, reads: int = 0, read_bytes: int = 64,
                       compute_us: float = 0.0) -> None:
        """Charge a critical section's data accesses at the *caller* (spin
        mode): each of ``reads`` accesses to lock-home data costs a remote
        verb when the caller is remote — this is why spin-lock hold time
        spans round trips.  Explicit so the transactional kvstore path
        (``lock``/``unlock`` pairs) charges the same model ``with_lock``
        does."""
        sim = self.cluster.sim
        name = self.cluster.backend_name
        if compute_us:
            sim.busy(th, compute_us)
        if th.server == self.home:
            for _ in range(reads):
                sim.local_access(th)
        elif name == "drust":
            for _ in range(reads):
                sim.rdma_read(th, self.home, read_bytes)
        elif name == "gam":
            for _ in range(reads):
                sim.rpc(th, self.home, resp_bytes=read_bytes,
                        proc_us=sim.cost.msg_proc_us)
        else:
            for _ in range(reads):
                sim.rpc(th, self.home, resp_bytes=read_bytes,
                        proc_us=sim.cost.delegation_proc_us)

    # ---- recovery -------------------------------------------------------
    def break_lock(self, at_us: float) -> None:
        """Recovery lock-state reconstruction: the holder (or the home
        server's lock word) died.  Force-release so later acquirers
        serialize behind the recovery barrier instead of a dead holder —
        the critical section's un-flushed effects follow the epoch-revert
        contract (lost, reported, never resurrected)."""
        self._holder = None
        self._release_t = max(self._release_t, at_us)
        self.broken += 1

    def on_server_failed(self, dead: int, dead_tids, at_us: float):
        """Uniform recovery hook (``fault.py`` fail-over): returns
        ``(locks_broken, leases_broken)``.  Breaks the lock when its
        holder died; when the *home* died with shipped closures in
        flight, drops the convoy's cid references — the completion-plane
        quiesce already disposed those cids exactly once, the sections
        never ran (epoch-revert contract), and later acquirers serialize
        behind the recovery barrier against the restored lock word."""
        broken = 0
        h = self._holder
        if h is not None and (getattr(h, "tid", None) in dead_tids
                              or h.server == dead):
            self.break_lock(at_us)
            broken = 1
        if self.home == dead and self._inflight:
            self._inflight.clear()
            if not broken:
                self.break_lock(at_us)
                broken = 1
        return broken, 0

    # ---- critical sections ----------------------------------------------
    def lock(self, th) -> Any:
        """Explicit acquire (pairs with ``unlock``; sorted multi-lock
        acquisition in the transactional kvstore).  Returns the protected
        heap object.  Spin semantics regardless of mode — an explicit
        multi-lock hold cannot be shipped as one closure."""
        san = self.backend.sanitizer
        if san is not None:
            # Lockdep: record held->acquired order edges; an inverted
            # order (the sorted-bucket discipline broken) raises before
            # the deadlock can happen on real hardware.
            san.note_lock_acquire(th, self, name=f"DMutex@s{self.home}")
        self._lock_verb(th)
        self.acquisitions += 1
        if th.t_us < self._release_t:                    # wait for holder
            self.contended += 1
            th.t_us = self._release_t
        self._holder = th
        return self.cluster.heap.get(_raw(self.h))

    def unlock(self, th) -> None:
        """Explicit release.  If recovery broke the lock mid-section (the
        holder was declared dead), the release already happened during
        lock-state reconstruction — skip the verb."""
        if self._holder is not th:
            return
        self._holder = None
        self._release_t = max(self._release_t, th.t_us)  # section end
        self._release_verb(th)
        san = self.backend.sanitizer
        if san is not None:
            san.note_lock_release(th, self)

    def with_lock(self, th, fn: Callable[[Any], Any], reads: int = 0,
                  read_bytes: int = 64, compute_us: float = 0.0) -> Any:
        """Run one critical section; dispatches on the lock mode.

        ``reads``/``read_bytes``/``compute_us`` describe the section's
        data footprint on the lock home — remote verbs at the caller
        under spin, local accesses on the home's CPU under delegation
        (the entire point of shipping the closure to the data).  ``fn``
        must be the pure mutation (costs come from the knobs, or from
        ``fn`` charging the caller itself in legacy zero-knob sections).
        """
        if self.mode == "delegate" and th.server != self.home:
            return self._delegate(th, fn, reads, read_bytes, compute_us)
        obj = self.lock(th)
        try:
            self.charge_section(th, reads, read_bytes, compute_us)
            return fn(obj)
        finally:
            # A raising critical section still unlocks — otherwise every
            # later acquirer would serialize behind a lock nobody holds
            # (the unbalanced-release analogue of an unbalanced drop).
            self.unlock(th)

    def _delegate(self, th, fn: Callable[[Any], Any], reads: int,
                  read_bytes: int, compute_us: float) -> Any:
        """Combining-lock convoy: ship the closure, the home runs it.

        The closure arrives one-way-latency after the ship; the home
        executes arrivals back-to-back in arrival order (the convoy) —
        ``_release_t`` is the convoy tail.  A waiter arriving at a
        drained lock starts a new convoy and pays the completion round
        trip (result-slot poll); a waiter arriving while the convoy is
        busy joins it and rides the head's poll.  An unreachable home is
        discovered *before* the section runs: the shipped closure stays
        pending on the completion plane and the recovery quiesce disposes
        it exactly once (the section never executes — no partial state).
        """
        cluster = self.cluster
        sim, cost = cluster.sim, cluster.sim.cost
        name = cluster.backend_name
        home = self.home
        if name == "drust":
            cid = sim.ship_closure(th, home, 64)
            self._inflight.append(cid)
            one_way = cost.one_sided_base_us
        else:
            # Two-sided ship: the request half of a SEND/RECV exchange,
            # posted without waiting (issue cost only); the response half
            # is the convoy head's completion below.
            sim.check_reachable(th, home, sync=False)
            th.t_us += cost.wb_issue_us
            sim.net.two_sided_msgs += 1
            sim.net.closure_ships += 1
            sim.net.bytes_moved += 64
            sim.servers[sim._serve(home)].msgs += 1
            one_way = cost.two_sided_rtt_us / 2
        # An unresponsive-but-undeclared home surfaces here, on the
        # caller's retry ladder — before the section runs.
        sim.check_reachable(th, home)
        arrive = th.t_us + one_way
        new_convoy = arrive >= self._release_t
        start = max(arrive, self._release_t)
        proc = cost.msg_proc_us if name == "gam" else cost.delegation_proc_us
        exec_us = (proc + reads * cost.local_access_us + compute_us)
        exec_us *= sim.slowdown[sim._serve(home)]
        sim.servers[sim._serve(home)].cpu_busy_us += exec_us
        end = start + exec_us
        self._release_t = end
        self.acquisitions += 1
        self.delegated += 1
        if new_convoy:
            self.convoys += 1
        else:
            self.contended += 1
        result = fn(self.cluster.heap.get(_raw(self.h)))
        sim.convoy_complete(th, home, new_convoy,
                            one_sided=(name == "drust"))
        th.t_us = max(th.t_us, end + one_way)
        if name == "drust":
            self._inflight.clear()       # convoy drained: ships completed
        return result


class _LeaseRead:
    """``with rw.read(th) as v:`` — scoped *view* of a leased value.  The
    underlying lease persists past the scope (revocation is the writer's
    job); the scope only bounds the borrow-style access idiom."""

    __slots__ = ("rw", "th", "_value")

    def __init__(self, rw: "DRwLock", th):
        self.rw, self.th = rw, th

    def __enter__(self):
        self._value = self.rw.get(self.th)
        return self._value

    def __exit__(self, *exc):
        self._value = None
        return False


class DRwLock:
    """Read-mostly shared value with home-granted reader leases.

    The first read from a server takes a lease: a *pinned immutable
    borrow* (``ReadGuard(pin=True)``) — the same region-lifetime freeze
    the deref coalescer exploits — paying the one cold fetch.  Every
    subsequent read on that server is a local pointer chase: zero verbs.
    A write revokes all outstanding leases first (async WRITE per leased
    server + a completion-id fence — the revocation fence), then mutates
    under an exclusive ``WriteGuard``; readers re-grant afterwards and can
    never observe pre-revocation state (the guard cannot be entered while
    any lease's borrow is live, and the mutate happens only after every
    lease closed).  Recovery breaks leases exactly like locks
    (``on_server_failed``)."""

    def __init__(self, cluster, th, value: Any = None, size: int = 64,
                 server: int | None = None):
        self.cluster = cluster
        self.backend = cluster.backend
        self.h = self.backend.alloc(th, size, value, server=server)
        self._leases: dict[int, ReadGuard] = {}   # server -> held pin guard
        self._release_t = 0.0          # writer serialization clock
        self.lease_grants = 0
        self.lease_revokes = 0
        self.writes = 0
        self.broken = 0                # recovery broke the lease table
        self.broken_leases = 0         # individual leases recovery broke
        registry = getattr(cluster, "mutexes", None)
        if registry is not None:
            registry.append(self)

    @property
    def home(self) -> int:
        """Computed per access: a remote writer's ``WriteGuard`` *moves*
        the value under the ownership backend, so the home follows the
        handle instead of being cached at construction."""
        return A.server_of(_raw(self.h))

    # ---- leases ---------------------------------------------------------
    def _grant(self, th) -> ReadGuard:
        """Grant (or find) this server's lease.  The grant itself pays the
        cold read — one round trip for a remote home — and pins the copy;
        a granted server's reads are free until a writer revokes."""
        g = self._leases.get(th.server)
        if g is not None:
            return g
        if th.t_us < self._release_t:  # a write is mid-flight: wait it out
            th.t_us = self._release_t
        # A lease outlives lexical scope by design: the pinned guard stays
        # open until a writer revokes it, so no `with` is possible here.
        # Recovery (`on_server_failed`) and `_revoke` are the release paths.
        g = ReadGuard(self.backend, th, self.h, pin=True)  # lint: allow(guard-no-with)
        g.__enter__()  # lint: allow(guard-no-with)
        detach_guard(g)     # lease lifetime ends at revocation, not scope
        self._leases[th.server] = g
        self.lease_grants += 1
        self.cluster.sim.net.lease_grants += 1
        return g

    def acquire_lease(self, th) -> None:
        """Take this server's lease eagerly (the ``region(lease=...)``
        hint): pay the grant up front, before the read-heavy section."""
        self._grant(th)

    def get(self, th) -> Any:
        """Read the value.  Leased: DRust-check + local chase, zero verbs.
        Unleased: the grant's cold fetch."""
        sim = self.cluster.sim
        g = self._leases.get(th.server)
        if g is None:
            return self._grant(th).value
        sim.deref_check(th)
        sim.local_access(th)
        return g.value

    def read(self, th) -> _LeaseRead:
        """``with rw.read(th) as v:`` — scoped leased read."""
        return _LeaseRead(self, th)

    # ---- writes ---------------------------------------------------------
    def _revoke(self, th) -> int:
        """Revoke every outstanding lease before a write: close the pinned
        borrows, notify each leased server (async WRITE under drust, RPC
        under the message backends), and fence the notifications through
        the completion-id plane — the mutate below must not start until
        every reader's freeze is provably broken."""
        if not self._leases:
            return 0
        san = self.backend.sanitizer
        if san is not None:
            san.note_lease_revoke(th, self.h)
        cluster = self.cluster
        sim, net = cluster.sim, cluster.sim.net
        name = cluster.backend_name
        cids: list[int] = []
        n = 0
        for s in sorted(self._leases):
            g = self._leases.pop(s)
            g.close()
            n += 1
            if s == th.server:
                continue               # local lease-table entry: no verb
            if name == "drust":
                cids.append(sim.wb.post(th, s, 8, kind="revoke"))
            elif name == "gam":
                sim.rpc(th, s, proc_us=sim.cost.msg_proc_us)
            else:
                sim.rpc(th, s, proc_us=sim.cost.delegation_proc_us)
        if cids:
            sim.wb.fence(th, max(cids))          # the revocation fence
            net.round_trips += 1                 # completion poll
        self.lease_revokes += n
        net.lease_revokes += n
        return n

    def write(self, th, data: Any) -> None:
        """Replace the value: revoke leases, fence, mutate exclusively."""
        self.update(th, lambda _v: data)

    def update(self, th, fn: Callable[[Any], Any]) -> Any:
        self._revoke(th)
        if th.t_us < self._release_t:            # serialize vs prior writer
            th.t_us = self._release_t
        with WriteGuard(self.backend, th, self.h) as w:
            result = w.update(fn)
        self._release_t = max(self._release_t, th.t_us)
        self.writes += 1
        return result

    # ---- recovery -------------------------------------------------------
    def on_server_failed(self, dead: int, dead_tids, at_us: float):
        """Uniform recovery hook: break the dead server's lease (its cache
        died) and, when the *home* died, the whole lease table (the grant
        records died with it — conservative, like breaking a lock).
        Guards granted by dead threads are abandoned (fail-over already
        force-released their borrows); survivors' guards close normally
        (a drust drop is local-only, safe even when the home is gone).
        Returns ``(locks_broken, leases_broken)``."""
        home_dead = self.home == dead
        broken = 0
        for s in list(self._leases):
            if not (home_dead or s == dead):
                continue
            g = self._leases.pop(s)
            if s == dead or getattr(g.th, "tid", None) in dead_tids:
                g._abandon()
            else:
                g.close()
            broken += 1
        self.broken_leases += broken
        if home_dead:
            self.broken += 1
            self._release_t = max(self._release_t, at_us)
        return 0, broken
