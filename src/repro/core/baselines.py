"""Baseline DSM protocols the paper evaluates against (§7).

* ``GamBackend`` — a GAM-style **directory-based** protocol [Cai et al.,
  VLDB'18]: every 512 B cache block has a home node that tracks its state
  (Shared / Modified / Invalid) and its sharer set.  Reads miss to the home
  (and possibly bounce to the current owner); writes must invalidate every
  sharer before the requester is granted Modified.  Calibrated to the paper's
  §3 breakdown: a cold 512 B read costs ~16 us of which only ~3.6 us is data
  movement (77% coherence overhead).

* ``GrappaBackend`` — a Grappa-style **delegation** protocol [Nelson et al.,
  ATC'15]: there are no caches at all; every access is an RPC executed by the
  home core of the object.  Cheap to reason about, but every op pays a round
  trip and hot objects saturate their home server (the paper's KV-store skew
  collapse).

Both implement the same ``ProtocolBackend`` ABC as ``DrustRuntime``
(verbs: alloc / read / write / update / transfer / drop / read_many /
prefetch), and their handles carry the same scoped-guard surface
(``with h.read(th) as v:`` / ``with h.write(th) as w:``), so the four
applications run unmodified on all three.  Borrow misuse raises
``BorrowError`` here too — tracked by the guard layer, since neither
protocol has ownership state of its own.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from . import addr as A
from .heap import GlobalHeap
from .net import Sim
from .ownership import _clone
from .protocol import (ProtocolBackend, ReadGuard, WriteGuard,
                       register_backend)

BLOCK = 512                      # GAM default cache block size (bytes)


@dataclass
class GHandle:
    """A plain global pointer: raw address + object size."""
    raw: int
    size: int
    backend: Any = field(default=None, repr=False, compare=False)
    live_refs: int = field(default=0, repr=False, compare=False)
    live_mut: bool = field(default=False, repr=False, compare=False)

    @property
    def home(self) -> int:
        return A.server_of(self.raw)

    # Scoped-guard surface (same shape as DBox.read/DBox.write).
    def read(self, th) -> ReadGuard:
        return ReadGuard(self.backend, th, self)

    def write(self, th) -> WriteGuard:
        return WriteGuard(self.backend, th, self)


# --------------------------------------------------------------------------
#  GAM-style directory protocol
# --------------------------------------------------------------------------
@dataclass
class DirEntry:
    state: str = "S"                       # S | M
    sharers: set[int] = field(default_factory=set)
    owner: int | None = None               # server holding M


@register_backend
class GamBackend(ProtocolBackend):
    name = "gam"
    # Calibration: cold clean read = base + transfer ~= 16us @ 512B (paper §3).
    COLD_READ_BASE_US = 12.4
    LOCAL_HIT_US = 0.30                    # cached-block access incl. state check
    INV_PROC_US = 1.5                      # per-sharer invalidation handling
    PER_BLOCK_US = 0.6                     # pipelined per-512B-block directory cost

    def __init__(self, sim: Sim, heap: GlobalHeap | None = None,
                 batch_io: bool = True):
        self.sim = sim
        self.heap = heap or GlobalHeap(sim.n)
        self.batch_io = batch_io
        self.directory: dict[int, DirEntry] = {}
        # per-server block cache: raw -> payload snapshot
        self.caches: list[dict[int, Any]] = [dict() for _ in range(sim.n)]

    def alloc(self, th, size: int, data: Any = None, server: int | None = None,
              tie_to=None) -> GHandle:
        server = th.server if server is None else server
        self.sim.busy(th, self.sim.cost.alloc_us)
        if server != th.server:
            self.sim.rpc(th, server, req_bytes=64 + size)
        raw = self.heap.alloc_on(server, size, data)
        self.directory[raw] = DirEntry(state="S", sharers=set())
        return GHandle(raw, size, backend=self)

    def _nblocks(self, h: GHandle) -> int:
        return max(1, -(-h.size // BLOCK))

    def read(self, th, h: GHandle) -> Any:
        sim, d = self.sim, self.directory[h.raw]
        cache = self.caches[th.server]
        if h.home == th.server and d.state == "S":
            sim.local_access(th)
            return self.heap.get(h.raw).data
        if h.raw in cache and th.server in (d.sharers | {d.owner}):
            sim.busy(th, self.LOCAL_HIT_US)
            return cache[h.raw]
        # Cold read: request home; home may bounce to the modified owner.
        hops = 1
        if d.state == "M" and d.owner not in (th.server, None):
            hops = 2                        # home -> owner fetch & downgrade
            d.state = "S"
            d.sharers.add(d.owner)
            d.owner = None
        # wire_done: same shared-link congestion model as DRust's plane (the
        # block payload occupies the home server's link under ooo).
        base = self.COLD_READ_BASE_US * (0.6 + 0.4 * hops)
        th.t_us = (sim.wire_done(th.t_us + base, h.home, h.size)
                   + self.PER_BLOCK_US * (self._nblocks(h) - 1))
        sim.net.two_sided_msgs += 2 * hops
        sim.net.round_trips += hops
        sim.net.bytes_moved += h.size
        sim.servers[h.home].cpu_busy_us += sim.cost.dir_proc_us
        sim.servers[h.home].msgs += 1
        d.sharers.add(th.server)
        cache[h.raw] = _clone(self.heap.get(h.raw).data)
        return cache[h.raw]

    def write(self, th, h: GHandle, data: Any) -> None:
        sim, d = self.sim, self.directory[h.raw]
        if d.state == "M" and d.owner == th.server:
            sim.busy(th, self.LOCAL_HIT_US)          # write hit in Modified
            self.caches[th.server][h.raw] = data
            self.heap.get(h.raw).data = data
            return
        # Request exclusive: home invalidates every sharer, then grants M.
        sharers = d.sharers - {th.server}
        th.t_us = (sim.wire_done(th.t_us + self.COLD_READ_BASE_US, h.home,
                                 h.size)
                   + self.PER_BLOCK_US * (self._nblocks(h) - 1))
        if sharers:
            # invalidation round: parallel sends, serial ACK processing
            th.t_us += (sim.cost.two_sided_rtt_us
                        + self.INV_PROC_US * len(sharers))
        sim.net.two_sided_msgs += 2 + 2 * len(sharers)
        sim.net.round_trips += 1 + (1 if sharers else 0)
        sim.net.invalidations += len(sharers)
        sim.net.bytes_moved += h.size
        sim.servers[h.home].cpu_busy_us += (sim.cost.dir_proc_us
                                            + self.INV_PROC_US * len(sharers))
        for s in sharers:
            self.caches[s].pop(h.raw, None)
            sim.servers[s].cpu_busy_us += self.INV_PROC_US
        d.sharers = set()
        d.state, d.owner = "M", th.server
        self.caches[th.server][h.raw] = data
        self.heap.get(h.raw).data = data

    def read_many(self, th, handles) -> list:
        """Doorbell-batched reads: cold misses to the same home node share
        one directory request round (one base latency + summed transfer +
        pipelined per-block cost), keeping the comparison with DRust's
        batched plane fair.  Per-handle directory state transitions are
        identical to N sequential ``read`` calls."""
        if not self.batch_io:
            return [self.read(th, h) for h in handles]
        sim = self.sim
        vals: dict[int, Any] = {}
        cold: dict[int, list[int]] = {}          # home -> handle indices
        queued: set[int] = set()                 # raws already in this batch
        dups: list[tuple[int, int]] = []         # (index, raw) repeat fetches
        for i, h in enumerate(handles):
            d = self.directory[h.raw]
            cache = self.caches[th.server]
            if h.home == th.server and d.state == "S":
                sim.local_access(th)
                vals[i] = self.heap.get(h.raw).data
            elif h.raw in cache and th.server in (d.sharers | {d.owner}):
                sim.busy(th, self.LOCAL_HIT_US)
                vals[i] = cache[h.raw]
            elif h.raw in queued:                # duplicate: hit after fetch
                dups.append((i, h.raw))
            else:
                queued.add(h.raw)
                cold.setdefault(h.home, []).append(i)
        for home, idxs in cold.items():
            max_hops, blocks, nbytes = 1, 0, 0
            for i in idxs:
                h = handles[i]
                d = self.directory[h.raw]
                if d.state == "M" and d.owner not in (th.server, None):
                    max_hops = 2                 # bounce to the modified owner
                    d.state = "S"
                    d.sharers.add(d.owner)
                    d.owner = None
                blocks += self._nblocks(h)
                nbytes += h.size
            base = self.COLD_READ_BASE_US * (0.6 + 0.4 * max_hops)
            th.t_us = (sim.wire_done(th.t_us + base, home, nbytes)
                       + self.PER_BLOCK_US * (blocks - 1)
                       + sim.cost.doorbell_us * (len(idxs) - 1))
            sim.net.two_sided_msgs += 2 * max_hops
            sim.net.round_trips += max_hops
            sim.net.doorbell_batches += 1
            sim.net.batched_verbs += len(idxs)
            sim.net.bytes_moved += nbytes
            sim.servers[home].cpu_busy_us += (sim.cost.dir_proc_us
                                              + 0.2 * (len(idxs) - 1))
            sim.servers[home].msgs += 1
            for i in idxs:
                h = handles[i]
                self.directory[h.raw].sharers.add(th.server)
                self.caches[th.server][h.raw] = _clone(self.heap.get(h.raw).data)
                vals[i] = self.caches[th.server][h.raw]
        for i, raw in dups:                      # resolved from the warm cache
            sim.busy(th, self.LOCAL_HIT_US)
            vals[i] = self.caches[th.server][raw]
        return [vals[i] for i in range(len(handles))]

    def prefetch(self, th, handles) -> int:
        """Directory protocols have no ownership signal to make speculation
        safe — prefetch is a no-op (apps run unmodified)."""
        return 0

    # ``update`` inherits the ABC default: one write guard = read (charged
    # as a directory read) + write — exactly the legacy fn(read)+write pair.

    def drop(self, th, h: GHandle) -> None:
        self.directory.pop(h.raw, None)
        for c in self.caches:
            c.pop(h.raw, None)
        self.heap.free(h.raw)


# --------------------------------------------------------------------------
#  Grappa-style delegation protocol
# --------------------------------------------------------------------------
@register_backend
class GrappaBackend(ProtocolBackend):
    name = "grappa"
    GRAIN = 2048        # bulk accesses delegate per 2 KiB segment (no caching)

    def __init__(self, sim: Sim, heap: GlobalHeap | None = None,
                 batch_io: bool = True):
        self.sim = sim
        self.heap = heap or GlobalHeap(sim.n)
        self.batch_io = batch_io
        self._release_t: dict[int, float] = {}   # per-object home-core clock

    def alloc(self, th, size: int, data: Any = None, server: int | None = None,
              tie_to=None) -> GHandle:
        server = th.server if server is None else server
        self.sim.busy(th, self.sim.cost.alloc_us)
        if server != th.server:
            self.sim.rpc(th, server, req_bytes=64 + size)
        raw = self.heap.alloc_on(server, size, data)
        return GHandle(raw, size, backend=self)

    def _ndelegations(self, h: GHandle, nbytes: int) -> int:
        """Bulk payloads delegate per segment; small *structured* objects
        (lists: hash-table entries, id arrays) delegate per element — Grappa
        implements every global read/write as a delegated call."""
        data = self.heap.get(h.raw).data
        if isinstance(data, (list, tuple)):
            return 1 + len(data)
        return max(1, -(-nbytes // self.GRAIN))

    def _delegate(self, th, h: GHandle, nbytes_out: int, nbytes_back: int,
                  mutates: bool = False) -> None:
        sim = self.sim
        nsegs = self._ndelegations(h, max(nbytes_out, nbytes_back))
        # Hot-object serialization: *mutating* delegations for the same
        # address execute sequentially on its home core (the paper's
        # skewed-load bottleneck); the hold is the home-core service time.
        proc = sim.cost.delegation_proc_us
        if h.home == th.server:
            # Even local accesses go through the delegation queue in Grappa.
            if mutates:
                th.t_us = max(th.t_us, self._release_t.get(h.raw, 0.0))
            th.t_us += proc
            sim.servers[th.server].cpu_busy_us += proc
            sim.local_access(th)
            if mutates:
                self._release_t[h.raw] = th.t_us
        else:
            per_out = nbytes_out // nsegs
            per_back = nbytes_back // nsegs
            one_way = sim.cost.two_sided_rtt_us / 2
            for seg in range(nsegs):
                # request leg converges on (and may congest) the home's link
                arrive = sim.wire_done(th.t_us + one_way, h.home,
                                       64 + per_out)
                start = arrive
                if mutates:
                    start = max(arrive, self._release_t.get(h.raw, 0.0))
                done = start + proc
                if mutates:
                    self._release_t[h.raw] = done
                # Response leg departs after home processing — charging it to
                # the shared link would smear the home-core serialization into
                # the link's busy-until and over-delay unrelated traffic; the
                # small response rides uncongested.
                th.t_us = done + one_way + sim.cost.xfer_us(16 + per_back)
                sim.net.two_sided_msgs += 2
                sim.net.round_trips += 1
                sim.net.bytes_moved += 80 + per_out + per_back
                sim.servers[h.home].cpu_busy_us += proc
                sim.servers[h.home].msgs += 1

    def read(self, th, h: GHandle) -> Any:
        self._delegate(th, h, 0, h.size)
        return _clone(self.heap.get(h.raw).data)

    def read_many(self, th, handles) -> list:
        """Doorbell-batched delegation: read requests for the same home node
        ride one aggregated message (Grappa's own delegation aggregator);
        the home core still executes every delegated op, so hot-home CPU
        saturation is preserved — only the per-op wire round trip amortizes
        (segments stream inside the aggregate: one round trip per home, the
        same modeling choice as DRust's one-completion-per-doorbell; header
        bytes stay per-segment to match ``_delegate``'s accounting)."""
        if not self.batch_io:
            return [self.read(th, h) for h in handles]
        sim = self.sim
        vals: dict[int, Any] = {}
        by_home: dict[int, list[int]] = {}
        for i, h in enumerate(handles):
            if h.home == th.server:
                proc = sim.cost.delegation_proc_us
                th.t_us += proc
                sim.servers[th.server].cpu_busy_us += proc
                sim.local_access(th)
                vals[i] = _clone(self.heap.get(h.raw).data)
            else:
                by_home.setdefault(h.home, []).append(i)
        for home, idxs in by_home.items():
            nsegs = sum(self._ndelegations(handles[i], handles[i].size)
                        for i in idxs)
            nbytes = sum(handles[i].size for i in idxs)
            proc = sim.cost.delegation_proc_us * nsegs
            th.t_us = (sim.wire_done(th.t_us + sim.cost.two_sided_rtt_us,
                                     home, 80 * nsegs + nbytes) + proc)
            sim.net.two_sided_msgs += 2
            sim.net.round_trips += 1
            sim.net.doorbell_batches += 1
            sim.net.batched_verbs += nsegs
            sim.net.bytes_moved += 80 * nsegs + nbytes
            sim.servers[home].cpu_busy_us += proc
            sim.servers[home].msgs += 1
            for i in idxs:
                vals[i] = _clone(self.heap.get(handles[i].raw).data)
        return [vals[i] for i in range(len(handles))]

    def prefetch(self, th, handles) -> int:
        """Delegation has no caches to prefetch into — no-op."""
        return 0

    def write(self, th, h: GHandle, data: Any) -> None:
        self._delegate(th, h, h.size, 0, mutates=True)
        self.heap.get(h.raw).data = data

    def update(self, th, h: GHandle, fn: Callable[[Any], Any]) -> Any:
        # Delegation executes the closure at the home — single round trip
        # (cheaper than the generic read+write guard pair; keep the
        # override).
        self._delegate(th, h, 64, 64, mutates=True)
        obj = self.heap.get(h.raw)
        obj.data = fn(obj.data)
        return obj.data

    def drop(self, th, h: GHandle) -> None:
        self.heap.free(h.raw)
