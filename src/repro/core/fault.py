"""Fault tolerance (§4.2.3): replicated heap partitions with epoch-batched
write-back and backup promotion.

Each server's heap partition has a backup on another server, at the same
virtual addresses.  Threads are *not* replicated.  A mutable borrow batches
its modifications; the write-back to the backup is delayed until the object
becomes visible to other servers — i.e. at **ownership transfer** (and at
explicit epoch boundaries, which is how the JAX training loop uses this:
one flush per train step).  On failure the controller promotes the backup
partition to primary and enlists a fresh backup.
"""

from __future__ import annotations

from typing import Any

from . import addr as A
from .heap import Obj
from .ownership import _clone


class Replicator:
    def __init__(self, cluster):
        self.cluster = cluster
        rt = cluster.drust
        self.rt = rt
        n = cluster.sim.n
        self.backup_of = {s: (s + 1) % n for s in range(n)}
        # backup stores: primary server -> {raw addr -> payload snapshot}
        self.replicas: dict[int, dict[int, Any]] = {s: {} for s in range(n)}
        self.pending: set[int] = set()          # dirty raw addrs, not yet flushed
        self.failed: set[int] = set()
        self.flushes = 0
        self.bytes_replicated = 0
        rt.on_write_visible = self._on_write
        rt.on_alloc = self._on_alloc
        rt.on_free = self._on_free
        rt.on_transfer = self._on_transfer

    # -- hooks ---------------------------------------------------------------
    def _on_alloc(self, raw: int) -> None:
        self.pending.add(raw)

    def _on_write(self, raw: int) -> None:
        # Batched: mark dirty; actual write-back deferred to the epoch edge.
        self.pending.add(raw)

    def _on_free(self, raw: int) -> None:
        self.pending.discard(raw)
        self.replicas[A.server_of(raw)].pop(raw, None)

    def _on_transfer(self, raw: int) -> None:
        self.flush_addr(raw)

    # -- flushing --------------------------------------------------------------
    def flush_addr(self, raw: int) -> None:
        if raw not in self.pending or not self.rt.heap.contains(raw):
            self.pending.discard(raw)
            return
        primary = A.server_of(raw)
        obj = self.rt.heap.get(raw)
        self.replicas[primary][raw] = _clone(obj.data)
        backup = self.backup_of[primary]
        self.cluster.sim.async_msg(backup, obj.size)      # off critical path
        self.bytes_replicated += obj.size
        self.flushes += 1
        self.pending.discard(raw)

    def flush_epoch(self) -> int:
        """Flush every dirty object (train-step / program epoch boundary)."""
        n = 0
        for raw in list(self.pending):
            self.flush_addr(raw)
            n += 1
        return n

    # -- failure handling --------------------------------------------------------
    def fail(self, server: int) -> None:
        """Crash ``server``: its primary partition contents are lost."""
        self.failed.add(server)
        part = self.rt.heap.partitions[server]
        part.objects.clear()
        part.used = 0

    def promote(self, server: int) -> int:
        """Promote the backup of ``server``'s partition: restore every
        replicated object at its original virtual address; enlist a new
        backup (cost: re-replication of the partition)."""
        part = self.rt.heap.partitions[server]
        restored = 0
        for raw, data in self.replicas[server].items():
            size = max(1, _sizeof(data))
            part.objects[raw] = Obj(_clone(data), size)
            part.used += size
            restored += 1
        # enlist a new backup server and re-replicate
        n = self.cluster.sim.n
        new_backup = (self.backup_of[server] + 1) % n
        while new_backup in self.failed or new_backup == server:
            new_backup = (new_backup + 1) % n
        self.backup_of[server] = new_backup
        for raw, data in self.replicas[server].items():
            self.cluster.sim.async_msg(new_backup, max(1, _sizeof(data)))
        self.failed.discard(server)
        return restored

    def recover(self, server: int) -> int:
        """fail-over entry point used by the controller."""
        return self.promote(server)


def _sizeof(data: Any) -> int:
    try:
        import numpy as np
        if isinstance(data, np.ndarray):
            return int(data.nbytes)
    except Exception:       # pragma: no cover
        pass
    if isinstance(data, bytes):
        return len(data)
    return 64
