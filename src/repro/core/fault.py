"""Fault tolerance (§4.2.3): replicated heap + crash-consistent fail-over.

Replication contract (the epoch-flush staleness contract)
---------------------------------------------------------
Each server's heap partition has a backup on another server at the same
virtual addresses.  Threads are *not* replicated.  A mutable borrow batches
its modifications; the write-back to the backup is delayed until the object
becomes visible to other servers — at **ownership transfer** and at explicit
epoch boundaries (``flush_epoch``, how the JAX training loop uses this: one
flush per train step).  The contract cuts both ways:

* anything flushed before the crash is restored **exactly** at its original
  virtual address (colored pointers into it stay valid);
* anything dirty-but-unflushed at crash time is **lost** — the restored
  object reverts to its last flushed epoch.  Recovery *reports* every such
  loss (``NetStats.lost_writes``, ``RecoveryReport.lost_writes``) and makes
  the stale pre-crash bytes unreachable (cache quarantine below); it never
  silently resurrects them as if they had committed.

An ``int8``-quantized partition checkpoint (``checkpoint_epoch``) is the
coarse second line of defence: objects that never reached the replica map
(allocated and used purely locally) restore from the checkpoint — lossy for
float payloads, exact for everything else.

Fail-over pipeline (``RecoveryManager``)
----------------------------------------
Recovery runs in three phases, each made *exact* by ownership state — the
borrow ledger tells the runtime precisely which objects can be mid-mutation
at crash time, which is the paper's argument for language-guided DSM applied
to resilience:

1. **quiesce** — every in-flight completion id touching the dead server is
   disposed exactly once: pending async WRITEs into it and speculative READ
   doorbells out of it retire at the recovery barrier
   (``WritebackQueue.dispose_server``); speculative cids route through the
   ``spec_log`` exactly-once discipline (``invalidated`` disposition, cache
   entries killed); staged channel sends from dead senders or to dead
   receivers drop; dead threads' verbs *to survivors* were DMA'd before the
   crash, so ``forget(tid)`` retires them at their real completion times.
   A recovery-private ledger asserts no cid is ever disposed twice.
2. **re-home** — the dead partition is restored from the promoted backup
   (``Replicator.promote``), falling back to the int8 checkpoint, at the
   original virtual addresses.  Guard-aware: open ``ReadGuard``s on
   surviving servers keep serving their frozen snapshots (cache entries go
   *suspect*: pinned copies serve existing holders, new lookups miss); an
   open ``WriteGuard`` on a dead-home box is **broken** — it surfaces a
   structured ``ServerLostError`` and releases the borrow without a
   write-back; borrows held by dead threads are force-released through the
   per-tid borrow ledger; ``DMutex`` holders that died are broken with
   lock-state reconstruction (later acquirers serialize behind the recovery
   barrier, not a dead holder).  Boxes with neither replica nor checkpoint
   are marked ``lost`` and raise ``ServerLostError`` on use.
3. **restripe** — ``Sim.rehost`` remaps the dead partition index onto the
   promoted backup (traffic keeps its addresses, lands on the backup's
   NIC/CPU), the QP plane restripes for the new membership, and survivors
   pay one control round trip each for RC re-establishment.  The same
   machinery handles elastic *grow* (``Cluster.add_server``).

Recovery cost is dominated by streaming the restored partition image
(``xfer_us(restored bytes)``), so the makespan scales with the dead
server's working set — not with cluster size (the SLO the recovery
benchmark gates).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from . import addr as A
from .heap import Obj
from .net import ServerLostError                      # noqa: F401 — re-export
from .ownership import _clone

try:
    import numpy as _np
except Exception:      # pragma: no cover
    _np = None


def _chain(prev, mine):
    """Compose runtime hooks: the previously installed hook still fires."""
    def hook(raw: int) -> None:
        prev(raw)
        mine(raw)
    return hook


def _chain2(prev, mine):
    """Two-argument variant of ``_chain`` (the ``on_move`` hook)."""
    def hook(old: int, new: int) -> None:
        prev(old, new)
        mine(old, new)
    return hook


def _quantize(data: Any) -> tuple:
    """Int8 checkpoint encoding: float ndarrays store (int8, scale); every
    other payload snapshots exactly (ints, bytes, pointer tables...)."""
    if _np is not None and isinstance(data, _np.ndarray) and data.dtype.kind == "f":
        amax = float(_np.max(_np.abs(data))) if data.size else 0.0
        scale = amax / 127.0
        if scale == 0.0:
            return ("q8", _np.zeros(data.shape, _np.int8), 0.0, str(data.dtype))
        q = _np.clip(_np.round(data / scale), -127, 127).astype(_np.int8)
        return ("q8", q, scale, str(data.dtype))
    return ("raw", _clone(data))


def _dequantize(snap: tuple) -> Any:
    if snap[0] == "q8":
        _, q, scale, dtype = snap
        return (q.astype(dtype) * scale).astype(dtype)
    return _clone(snap[1])


class Replicator:
    def __init__(self, cluster):
        self.cluster = cluster
        rt = cluster.drust
        self.rt = rt
        if getattr(rt, "_replicator", None) is not None:
            raise RuntimeError(
                "a Replicator is already attached to this runtime: a second "
                "one would double-charge replication traffic and race the "
                "first on the replica maps")
        rt._replicator = self
        n = cluster.sim.n
        self.backup_of = {s: (s + 1) % n for s in range(n)}
        # backup stores: primary server -> {raw addr -> (snapshot, size)}.
        # The size is captured at flush time (not recomputed at promote —
        # a recompute drifts for payloads without an intrinsic byte size).
        self.replicas: dict[int, dict[int, tuple[Any, int]]] = \
            {s: {} for s in range(n)}
        # int8 partition checkpoints: server -> {raw -> (encoded, size)}
        self.checkpoints: dict[int, dict[int, tuple[tuple, int]]] = {}
        self.pending: set[int] = set()          # dirty raw addrs, not yet flushed
        self.failed: set[int] = set()
        self.flushes = 0
        self.bytes_replicated = 0
        # Chain — never clobber — hooks installed before us: the runtime's
        # FT hooks are a shared notification bus, not this object's property.
        rt.on_write_visible = _chain(rt.on_write_visible, self._on_write)
        rt.on_alloc = _chain(rt.on_alloc, self._on_alloc)
        rt.on_free = _chain(rt.on_free, self._on_free)
        rt.on_transfer = _chain(rt.on_transfer, self._on_transfer)
        rt.on_move = _chain2(rt.on_move, self._on_move)

    # -- hooks ---------------------------------------------------------------
    def _on_alloc(self, raw: int) -> None:
        self.pending.add(raw)

    def _on_write(self, raw: int) -> None:
        # Batched: mark dirty; actual write-back deferred to the epoch edge.
        self.pending.add(raw)

    def _on_free(self, raw: int) -> None:
        self.pending.discard(raw)
        self.replicas[A.server_of(raw)].pop(raw, None)
        ckpt = self.checkpoints.get(A.server_of(raw))
        if ckpt is not None:
            ckpt.pop(raw, None)

    def _on_transfer(self, raw: int) -> None:
        self.flush_addr(raw)

    def _on_move(self, old: int, new: int) -> None:
        """The heap relocated an object (remote mutable deref / color
        overflow): FT state keyed by the old address must follow it, or a
        later crash of the OLD home would restore a stale replica at a
        freed — possibly reused — address.  The replica snapshot re-keys
        (it still holds the last flushed epoch, so crash recovery of the
        NEW home can revert to it); the new address is marked pending so
        the next flush re-replicates to the new home's backup.  The int8
        checkpoint entry does NOT follow — it is part of the old
        partition's image, and the bytes at the new address will be
        captured by the next ``checkpoint_epoch``."""
        self.pending.discard(old)
        self.pending.add(new)
        snap = self.replicas.get(A.server_of(old), {}).pop(old, None)
        if snap is not None:
            self.replicas.setdefault(A.server_of(new), {})[new] = snap
        ckpt = self.checkpoints.get(A.server_of(old))
        if ckpt is not None:
            ckpt.pop(old, None)

    # -- flushing --------------------------------------------------------------
    def flush_addr(self, raw: int) -> None:
        if raw not in self.pending or not self.rt.heap.contains(raw):
            self.pending.discard(raw)
            return
        primary = A.server_of(raw)
        obj = self.rt.heap.get(raw)
        self.replicas[primary][raw] = (_clone(obj.data), obj.size)
        backup = self.backup_of[primary]
        self.cluster.sim.async_msg(backup, obj.size)      # off critical path
        self.bytes_replicated += obj.size
        self.flushes += 1
        self.pending.discard(raw)

    def flush_epoch(self) -> int:
        """Flush every dirty object (train-step / program epoch boundary)."""
        n = 0
        for raw in list(self.pending):
            self.flush_addr(raw)
            n += 1
        return n

    def checkpoint_epoch(self) -> int:
        """Int8-quantized checkpoint of every live object, per partition —
        the coarse fallback for objects that never reached the replica map.
        Lossy for float ndarrays (quantized to int8 + scale), exact for
        everything else.  Returns the number of objects checkpointed."""
        n = 0
        for part in self.rt.heap.partitions:
            snap: dict[int, tuple[tuple, int]] = {}
            for raw, obj in part.objects.items():
                snap[raw] = (_quantize(obj.data), obj.size)
                n += 1
            self.checkpoints[part.server] = snap
        return n

    # -- failure handling --------------------------------------------------------
    def fail(self, server: int) -> None:
        """Crash ``server``: its primary partition contents are lost, and —
        critically — surviving servers' cached copies of its boxes may hold
        writes that died unflushed, so they are quarantined: unpinned
        copies invalidate immediately, pinned copies (open ``ReadGuard``s,
        frozen snapshots by contract) go *suspect* — they keep serving
        their holders but new lookups miss and they free at the last
        unpin.  Without the quarantine a post-crash read could silently
        resurrect a lost write from a warm cache."""
        self.failed.add(server)
        part = self.rt.heap.partitions[server]
        part.objects.clear()
        part.used = 0
        quarantine_dead_home(self.rt, self.cluster.sim, server)

    def promote(self, server: int) -> int:
        """Promote the backup of ``server``'s partition: restore every
        replicated object at its original virtual address (exact, stored
        size), fall back to the int8 checkpoint for objects the replica map
        never saw, then enlist a new backup (cost: re-replication of the
        partition)."""
        part = self.rt.heap.partitions[server]
        restored = 0
        seen: set[int] = set()
        for raw, (data, size) in self.replicas[server].items():
            part.objects[raw] = Obj(_clone(data), size)
            part.used += size
            seen.add(raw)
            restored += 1
        for raw, (snap, size) in self.checkpoints.get(server, {}).items():
            if raw in seen:
                continue                     # replica (exact, newer) wins
            part.objects[raw] = Obj(_dequantize(snap), size)
            part.used += size
            restored += 1
        # enlist a new backup server and re-replicate; if no live candidate
        # exists (single survivor) the old assignment stays — degraded
        sim = self.cluster.sim
        n = sim.n
        new_backup = (self.backup_of[server] + 1) % n
        for _ in range(n):
            if (new_backup != server and new_backup not in self.failed
                    and new_backup not in sim.lost):
                break
            new_backup = (new_backup + 1) % n
        else:
            new_backup = self.backup_of[server]
        self.backup_of[server] = new_backup
        for raw, (_, size) in self.replicas[server].items():
            self.cluster.sim.async_msg(new_backup, size)
        self.failed.discard(server)
        return restored

    def restored_bytes(self, server: int) -> int:
        """Bytes a promote of ``server`` streams (replica + checkpoint-only
        objects) — what the recovery makespan is charged for."""
        total = sum(size for _, size in self.replicas[server].values())
        for raw, (_, size) in self.checkpoints.get(server, {}).items():
            if raw not in self.replicas[server]:
                total += size
        return total

    def add_server(self, server: int) -> None:
        """Elastic grow: give the new server an empty replica map and a
        backup assignment (existing assignments are untouched)."""
        self.replicas.setdefault(server, {})
        sim = self.cluster.sim
        backup = (server + 1) % sim.n
        for _ in range(sim.n):
            if backup not in sim.lost and backup != server:
                break
            backup = (backup + 1) % sim.n
        self.backup_of[server] = backup

    def recover(self, server: int) -> int:
        """fail-over entry point used by the controller."""
        return self.promote(server)


def quarantine_dead_home(rt, sim, home: int) -> tuple[int, int]:
    """Scrub surviving caches of copies whose home is the failed server
    (see ``Replicator.fail``).  Speculative entries fire ``on_spec_drop``,
    so their prefetch cids get an ``invalidated`` disposition through the
    exactly-once ``spec_log`` discipline.  Returns cluster-wide
    ``(invalidated, suspected)`` counts and bumps
    ``NetStats.suspect_invalidations``."""
    invalidated = suspected = 0
    for s, H in enumerate(rt.caches):
        if s == home:
            continue                     # its own cache dies with it
        i, p = H.quarantine_home(home)
        invalidated += i
        suspected += p
    sim.net.suspect_invalidations += invalidated + suspected
    return invalidated, suspected


@dataclass
class RecoveryReport:
    """What one fail-over did — the structured receipt an application (or
    test oracle) audits instead of grepping logs."""
    server: int                    # the server that died
    backup: int                    # survivor now serving its partition index
    orphaned_cids: int             # pending verbs disposed at the barrier
    rehomed_boxes: int             # objects restored at original addresses
    lost_boxes: int                # objects with no replica/checkpoint
    lost_writes: int               # dirty-at-crash objects (epoch reverted)
    broken_guards: int             # open WriteGuards surfaced ServerLostError
    released_borrows: int          # dead threads' borrows force-released
    broken_locks: int              # DMutex holders broken
    dropped_channel_msgs: int      # staged sends orphaned by the crash
    dead_threads: int              # threads that died with the server
    restored_bytes: int            # partition image streamed from the backup
    makespan_us: float             # virtual time the fail-over took
    broken_leases: int = 0         # DRwLock reader leases broken


class RecoveryManager:
    """Drives the quiesce → re-home → restripe pipeline (module docstring).

    ``crash(server)`` models the *instant* of failure (data and threads die,
    peers start timing out); ``fail_over(server, th)`` is the controller's
    declared recovery; ``fail_and_recover`` runs both.  The manager keeps an
    exactly-once disposition ledger for every cid it orphans — a double
    disposition is a protocol bug and raises immediately."""

    def __init__(self, cluster):
        self.cluster = cluster
        self.disposed: dict[int, str] = {}       # cid -> disposition
        self.reports: list[RecoveryReport] = []
        self._dead_threads: dict[int, list] = {}  # server -> threads that died
        self.quiescing = False   # True inside fail_over: placement migration
        #                          is suppressed while cids are being disposed

    # -- exactly-once ledger ---------------------------------------------
    def _dispose(self, cid: int, how: str) -> None:
        if cid in self.disposed:
            raise RuntimeError(
                f"cid {cid} disposed twice: {self.disposed[cid]!r} then {how!r}")
        self.disposed[cid] = how

    # -- phase 0: the instant of failure ---------------------------------
    def crash(self, server: int) -> list:
        """The machine dies: partition contents are gone, its threads stop
        mid-quantum, peers' verbs to it start burning the retry ladder
        (``failing``, not yet declared).  Surviving caches are quarantined
        so stale copies of its boxes cannot serve lost writes.  Returns the
        threads that died (their verbs are settled by ``fail_over``)."""
        cl = self.cluster
        sim = cl.sim
        sim.mark_failing(server)
        if cl.replicator is not None:
            cl.replicator.fail(server)           # clears partition + quarantine
        else:
            part = cl.heap.partitions[server]
            part.objects.clear()
            part.used = 0
            quarantine_dead_home(cl.drust, sim, server)
        cl.drust.caches[server].drop_all()       # its own cache died with it
        dead = []
        for th in cl.scheduler.threads:
            if not th.done and th.server == server:
                th.done = True
                dead.append(th)
                if cl.drust.coalescer is not None:
                    # registered derefs can never materialize — release the
                    # registration borrows without posting a doorbell
                    cl.drust.coalescer.discard(th)
                cl.controller.thread_table.pop(th.tid, None)
        self._dead_threads.setdefault(server, []).extend(dead)
        return dead

    # -- phases 1-3: declared fail-over ----------------------------------
    def fail_over(self, dead: int, th=None) -> RecoveryReport:
        """Quiesce, re-home, restripe (module docstring).  ``th`` is the
        surviving thread driving recovery (the controller daemon's); its
        clock pays the recovery makespan.  Defaults to the first live
        thread on a surviving server."""
        cl = self.cluster
        sim, net, cost = cl.sim, cl.sim.net, cl.sim.cost
        rt = cl.drust
        if th is None:
            th = next((t for t in cl.scheduler.threads
                       if not t.done and t.server != dead
                       and t.server not in sim.lost), None)
            if th is None:
                raise RuntimeError("no surviving thread to drive recovery")
        if dead not in sim.failed:
            sim.declare_failed(dead)
        t0 = th.t_us
        self.quiescing = True    # placement migration pauses until recovered

        # ---- 1. quiesce: dispose every orphaned cid exactly once --------
        victims = sim.wb.dispose_server(dead, th.t_us)
        for v in victims:
            # WRITE flavors keep their kind in the ledger: "orphaned-write"
            # (pipelined write-back, incl. the DMutex fire-and-forget
            # unlock), "orphaned-closure" (delegated critical section that
            # never ran), "orphaned-revoke" (lease revocation in flight).
            self._dispose(v.cid,
                          "orphaned-read" if v.is_read
                          else f"orphaned-{v.kind}")
            sim.busy(th, cost.hashmap_us)        # ledger walk, per orphan
            if v.is_read:
                # Speculative READ out of the dead server: route through the
                # spec_log exactly-once discipline.  The cache quarantine at
                # crash time may already have disposed the cid (its entries
                # were unpinned dead-home copies) — _dispose_spec is the
                # idempotent authority; the entries are gone either way.
                rt._dispose_spec(v.cid, "invalidated")
                for H in rt.caches:
                    H.invalidate_cid(v.cid)
        dead_ths = self._dead_threads.pop(dead, [])
        dead_tids = {t.tid for t in dead_ths}
        for t in dead_ths:
            # verbs dead threads posted to SURVIVORS were DMA'd pre-crash:
            # they retire at their real completion times, not the barrier
            sim.wb.forget(t.tid)
        dropped_msgs = 0
        for ch in cl.channels:
            dropped_msgs += ch.drop_for_server(dead)
        net.orphaned_cids += len(victims)

        # ---- 2. re-home: restore the partition, reconcile borrows -------
        rep = cl.replicator
        lost_writes = 0
        restored_bytes = 0
        if rep is not None:
            for raw in [r for r in rep.pending if A.server_of(r) == dead]:
                rep.pending.discard(raw)         # epoch revert: write is lost
                lost_writes += 1
            backup = rep.backup_of[dead]         # the replica holder, promoted
            if backup in sim.lost or backup == dead:
                backup = self._pick_backup(dead)
            restored_bytes = rep.restored_bytes(dead)
            restored = rep.promote(dead)
            # survivors whose backup WAS the dead server re-enlist a live
            # one and re-replicate their partitions (off the critical path);
            # with a single survivor there is no valid backup — it keeps the
            # dead assignment (degraded: unreplicated until the next grow)
            if len(sim.alive_servers()) > 1:
                for s, b in list(rep.backup_of.items()):
                    if s != dead and s not in sim.lost \
                            and (b == dead or b in sim.lost):
                        nb = self._pick_backup(s)
                        rep.backup_of[s] = nb
                        for _, (_, size) in rep.replicas.get(s, {}).items():
                            sim.async_msg(nb, size)
        else:
            restored = 0
            backup = self._pick_backup(dead)
        net.lost_writes += lost_writes
        if restored:
            # the promoted backup streams the partition image back up
            sim.busy(th, cost.alloc_us * restored)
            th.t_us += cost.one_sided_base_us + cost.xfer_us(restored_bytes)
            net.bytes_moved += restored_bytes
            sim.servers[backup].bytes_out += restored_bytes

        broken_guards = 0
        released = 0
        rehomed = 0
        lost_boxes = 0
        for raw, box in list(rt.owner_of.items()):
            if box is None or box.dropped:
                continue
            # borrows held by dead threads force-release (any home server)
            for tid in [t for t in box.ref_tids if t in dead_tids]:
                n = box.ref_tids.pop(tid)
                box.live_refs -= n
                released += n
            if box.live_mut and box.mut_tid in dead_tids:
                box.live_mut = False
                box.mut_tid = None
                released += 1
            if A.server_of(raw) != dead:
                continue
            if box.live_mut:
                # surviving holder's open WriteGuard on a dead-home box:
                # the write-back can never land — break the guard
                box.mut_broken = True
                broken_guards += 1
            if rt.heap.contains(raw):
                rehomed += 1
            else:
                box.lost = True                  # no replica, no checkpoint
                lost_boxes += 1
        net.rehomed_boxes += rehomed

        # Lock/lease-state reconstruction: every registered primitive
        # (DMutex spin *and* delegate convoys, DRwLock reader leases)
        # reconciles itself against the dead server — break locks whose
        # holder died, drop references to closure cids the quiesce above
        # already disposed, and break leases whose cache (or whose lease
        # table, when the home died) is gone.  NOTE: this runs AFTER the
        # borrow force-release loop, so a lease guard whose granting
        # thread died must be abandoned, not closed (the borrow count was
        # already settled there).
        broken_locks = 0
        broken_leases = 0
        for m in getattr(cl, "mutexes", []):
            locks, leases = m.on_server_failed(dead, dead_tids, th.t_us)
            broken_locks += locks
            broken_leases += leases
        net.broken_locks += broken_locks

        # ---- 3. restripe: new membership on the completion plane --------
        sim.rehost(dead, backup)
        sim.restripe()
        # RC re-establishment: one 16 B handshake per survivor, issued
        # back-to-back and completing in PARALLEL (one doorbell batch, the
        # multi-QP plane) — the driver waits one round trip plus the issue
        # costs, not n sequential round trips, so the restripe phase stays
        # flat in cluster size (the recovery SLO the benchmark gates)
        peers = [s for s in sim.alive_servers() if s != th.server]
        if peers:
            batch = sim.batch()
            for s in peers:
                batch.add_read(s, 16)
            batch.commit(th)

        # Sanitizer reconciliation: the dead threads' guards and locks were
        # force-released/abandoned by the phases above — settle their
        # accounting so the borrow-balance checks still hold for survivors.
        san = cl.backend.sanitizer
        if san is not None:
            san.on_failover(dead_tids)

        makespan = th.t_us - t0
        net.recovery_makespan_us = makespan
        report = RecoveryReport(
            server=dead, backup=backup, orphaned_cids=len(victims),
            rehomed_boxes=rehomed, lost_boxes=lost_boxes,
            lost_writes=lost_writes, broken_guards=broken_guards,
            released_borrows=released, broken_locks=broken_locks,
            dropped_channel_msgs=dropped_msgs, dead_threads=len(dead_ths),
            restored_bytes=restored_bytes, makespan_us=makespan,
            broken_leases=broken_leases)
        self.reports.append(report)
        self.quiescing = False
        return report

    def fail_and_recover(self, server: int, th=None) -> RecoveryReport:
        """Crash + immediate declared fail-over (the common test driver;
        production-shaped callers go through the controller's probe loop)."""
        self.crash(server)
        return self.fail_over(server, th)

    # -- helpers ----------------------------------------------------------
    def _pick_backup(self, dead: int) -> int:
        sim = self.cluster.sim
        b = (dead + 1) % sim.n
        for _ in range(sim.n):
            if b not in sim.lost and b != dead:
                return b
            b = (b + 1) % sim.n
        return (dead + 1) % sim.n        # no live candidate: degraded
