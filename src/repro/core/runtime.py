"""DRust runtime system (§4.2): threads, cooperative scheduler, controller.

* ``Thread`` — a user-space green thread with a private (globally aligned)
  stack address range, its own virtual clock, and the access statistics the
  controller's balancing policies need.
* ``Scheduler`` — spawn / spawn_to / join / migrate.  Context switches are
  function calls (cooperative, non-preemptive); migration ships the function
  pointer + saved registers + stack, which keeps its address (Fig. 3).
* ``GlobalController`` — daemon on the launch server: probes per-server
  CPU/memory, picks allocation & spawn targets, and resolves imbalance by
  migrating threads (§4.2.2 policies: mem>90% → evict the biggest-heap
  thread; cpu>90% → move remote-heavy threads toward their data).
* ``CoalescePolicy`` / ``DerefCoalescer`` — the adaptive deref-coalescing
  policy (``Cluster(coalesce="auto")``): non-owning derefs of remote
  objects *register* inside the thread's scheduler quantum instead of
  fetching, and the whole pending set materializes as per-source
  ``read_many`` doorbells when the quantum closes — at an adaptive
  count/byte budget, at a borrow conflict, or at an explicit settle point.
  Registration takes the immutable borrow immediately, so the payload is
  frozen until the flush (ownership makes the deferral coherence-exact,
  not approximate); only the *cost* of the fetch is deferred.
* ``Cluster`` — wires Sim + GlobalHeap + one protocol backend together; the
  single entry point used by the applications and benchmarks.
"""

from __future__ import annotations

import itertools
import math
import os
import warnings
from collections import Counter
from dataclasses import dataclass, field
from typing import Any, Callable

from . import addr as A
from . import baselines as _baselines      # noqa: F401 — registers gam/grappa
from .heap import GlobalHeap
from .net import CostModel, Sim
from .ownership import _clone              # importing also registers drust
from .protocol import Region, backend_class


class Thread:
    _ids = itertools.count()

    def __init__(self, server: int, fn: Callable | None = None,
                 args: tuple = (), stack_bytes: int = 1 << 20):
        self.tid = next(Thread._ids)
        self.server = server
        self.fn, self.args = fn, args
        self.stack_addr = A.STACK_BASE + self.tid * A.STACK_SIZE
        self.stack_bytes = stack_bytes          # live stack payload (for migration)
        self.t_us = 0.0                          # virtual clock
        self.local_heap_bytes = 0                # controller: mem policy input
        self.remote_accesses: Counter = Counter()  # server -> count (cpu policy)
        self.migrations = 0
        self.done = False
        self.result: Any = None

    def note_remote(self, server: int) -> None:
        self.remote_accesses[server] += 1

    def hottest_remote(self) -> int | None:
        if not self.remote_accesses:
            return None
        return self.remote_accesses.most_common(1)[0][0]


class Scheduler:
    """Cooperative user-space scheduler + migration (§4.2.1)."""

    def __init__(self, cluster: "Cluster"):
        self.cluster = cluster
        self.threads: list[Thread] = []
        self.migration_log: list[tuple[int, int, int, float]] = []

    def spawn(self, fn: Callable, *args, server: int | None = None,
              parent: Thread | None = None) -> Thread:
        if server is None:
            server = self.cluster.controller.pick_spawn_server()
        th = Thread(server, fn, args)
        self.threads.append(th)
        if parent is not None:
            # closure + captured pointers ship to the target server
            if parent.server != server:
                self.cluster.sim.rpc(parent, server, req_bytes=256)
            th.t_us = max(th.t_us, parent.t_us)
        san = self.cluster.backend.sanitizer
        if san is not None:
            san.note_spawn(parent, th)
        return th

    def spawn_to(self, box, fn: Callable, *args,
                 parent: Thread | None = None) -> Thread:
        """Data-affinity spawn (§4.1.3): run where ``box``'s object lives.

        Resolved through the backend's ``locate`` — the *current* owner
        location — not the allocation-time home: after an ownership
        ``transfer`` or a write-move the home partition is stale and a
        home-resolved spawn would make every deref remote."""
        server = self.cluster.backend.locate(box)
        return self.spawn(fn, *args, server=server, parent=parent)

    def spawn_near(self, handles, fn: Callable, *args,
                   parent: Thread | None = None) -> Thread:
        """Placement-guided spawn for a *set* of handles (a region's
        pin/prefetch hint set): run on the weighted plurality of the
        handles' current locations, ties to the lowest server id."""
        votes: dict[int, float] = {}
        for h in handles:
            s = self.cluster.backend.locate(h)
            votes[s] = votes.get(s, 0.0) + 1.0
        if not votes:
            return self.spawn(fn, *args, parent=parent)
        server = max(sorted(votes), key=lambda s: votes[s])
        return self.spawn(fn, *args, server=server, parent=parent)

    def run(self, th: Thread) -> Any:
        th.result = th.fn(th, *th.args)
        cl = self.cluster
        if cl.backend_drust and cl.drust.coalescer is not None:
            cl.drust.coalescer.flush(th)     # quantum closes with the fn
        th.done = True
        return th.result

    def run_all(self) -> None:
        for th in self.threads:
            if not th.done and th.fn is not None:
                self.run(th)

    def join(self, th: Thread, waiter: Thread | None = None) -> Any:
        if not th.done and th.fn is not None:
            self.run(th)
        if waiter is not None:
            waiter.t_us = max(waiter.t_us, th.t_us)
        san = self.cluster.backend.sanitizer
        if san is not None and waiter is not None:
            san.note_join(th, waiter)
        return th.result

    def retire(self, th: Thread) -> None:
        """Thread leaves the pool (elastic rescale / worker shutdown): mark
        done and clear the completion plane's per-thread state so a future
        thread reusing the id cannot inherit stale write-back tails or QP
        rings.  The retiree's in-flight write-backs stay in the makespan."""
        th.done = True
        cl = self.cluster
        if cl.backend_drust and cl.drust.coalescer is not None:
            cl.drust.coalescer.flush(th)     # quantum closes with the thread
        # Guard-leak checkpoint: a thread must not leave the pool holding
        # live borrows (the borrow would pin remote state forever).  Under
        # sanitize this raises with provenance; otherwise it warns.
        san = cl.backend.sanitizer
        if san is not None:
            san.check_thread(th, "retire")
        else:
            leaked = getattr(cl.backend, "open_by_tid", {}).get(th.tid, 0)
            if leaked:
                warnings.warn(
                    f"thread {th.tid} retired with {leaked} open guard(s) — "
                    f"borrows leak past the thread lifetime "
                    f"(run with Cluster(sanitize=True) to locate them)",
                    RuntimeWarning, stacklevel=2)
        cl.sim.wb.forget(th.tid)
        cl.controller.thread_table.pop(th.tid, None)

    def migrate(self, th: Thread, dst: int) -> float:
        """Ship fn pointer + registers + stack; stack address is preserved
        because stack ranges are globally aligned (Fig. 3).  Returns the
        migration latency in us (paper measures ~218 us for ~1 MiB stacks)."""
        sim = self.cluster.sim
        src = th.server
        if src == dst:
            return 0.0
        cl = self.cluster
        if cl.backend_drust and cl.drust.coalescer is not None:
            cl.drust.coalescer.flush(th)     # quantum closes on migration
        san = cl.backend.sanitizer
        if san is not None:
            # A migrating stack must not carry live borrows (the borrowed
            # pointer would dangle across the move).
            san.check_thread(th, "migrate", detail=f"server {src}->{dst}")
        lat = (sim.cost.two_sided_rtt_us * 2                    # ctrl handshake
               + sim.cost.xfer_us(th.stack_bytes + 512)         # stack + regs
               + sim.cost.msg_proc_us * 2)
        th.t_us += lat
        sim.net.two_sided_msgs += 4
        sim.net.bytes_moved += th.stack_bytes + 512
        sim.servers[dst].cpu_busy_us += sim.cost.msg_proc_us
        th.server = dst
        th.migrations += 1
        th.local_heap_bytes = 0
        # Telemetry decay: the counters describe the *old* neighborhood.
        # Accesses to ``dst`` are local now (that entry would make the
        # thread look remote-heavy on the server it just moved to, and
        # ``balance`` would bounce it right back); the rest halve so the
        # next round steers on post-migration evidence.
        th.remote_accesses.pop(dst, None)
        for s in list(th.remote_accesses):
            kept = th.remote_accesses[s] // 2
            if kept:
                th.remote_accesses[s] = kept
            else:
                del th.remote_accesses[s]
        self.migration_log.append((th.tid, src, dst, lat))
        self.cluster.controller.thread_table[th.tid] = dst
        return lat


class GlobalController:
    """Cluster-wise resource daemon (§4.2.2)."""

    MEM_HI = 0.90
    CPU_HI = 0.90
    PROBE_MISS_LIMIT = 3      # missed liveness probes before declaring death

    def __init__(self, cluster: "Cluster"):
        self.cluster = cluster
        self.thread_table: dict[int, int] = {}     # tid -> server
        self.missed_probes: dict[int, int] = {}    # server -> misses in a row
        self._rr = 0

    # -- probing ----------------------------------------------------------
    def mem_frac(self, s: int) -> float:
        return self.cluster.heap.partitions[s].frac_used

    def cpu_frac(self, s: int, horizon_us: float) -> float:
        if horizon_us <= 0:
            return 0.0
        sim = self.cluster.sim
        return sim.servers[s].cpu_busy_us / (sim.cores * horizon_us)

    def probe_failures(self, th) -> list[int]:
        """One liveness-probe round of the controller daemon: every
        unresponsive (``failing``) server costs the prober one probe
        timeout and bumps its miss counter; at ``PROBE_MISS_LIMIT`` the
        failure is *declared* and recovery fails the server over.  Returns
        the servers declared dead this round."""
        cl = self.cluster
        sim = cl.sim
        declared: list[int] = []
        for s in sorted(sim.failing):
            sim.busy(th, sim.cost.retry_timeout_us)    # the probe timed out
            sim.net.degraded_retries += 1
            self.missed_probes[s] = self.missed_probes.get(s, 0) + 1
            if self.missed_probes[s] >= self.PROBE_MISS_LIMIT:
                self.missed_probes.pop(s)
                declared.append(s)
                if getattr(cl, "recovery", None) is not None:
                    cl.recovery.fail_over(s, th)
                else:
                    sim.declare_failed(s)
        # a server that answers again clears its strike counter
        for s in list(self.missed_probes):
            if s not in sim.failing:
                self.missed_probes.pop(s)
        return declared

    # -- placement policies -------------------------------------------------
    def _alive(self) -> list[int]:
        return self.cluster.sim.alive_servers()

    def pick_alloc_server(self, prefer: int, size: int) -> int:
        """Local-first; under pressure, the most vacant *alive* server
        (§4.2.1).  Lost servers' partition indices are rehosted read-mostly
        — new allocations never land there."""
        part = self.cluster.heap.partitions[prefer]
        if (part.used + size) / part.capacity < self.MEM_HI:
            return prefer
        return min(self._alive(), key=self.mem_frac)

    def pick_spawn_server(self) -> int:
        """Least-loaded alive server by CPU busy; round-robin tiebreak."""
        sim = self.cluster.sim
        alive = self._alive()
        lo = min(sim.servers[s].cpu_busy_us for s in alive)
        cands = [s for s in alive if sim.servers[s].cpu_busy_us == lo]
        self._rr += 1
        return cands[self._rr % len(cands)]

    # -- straggler mitigation --------------------------------------------
    STRAGGLER_FACTOR = 2.0

    def detect_stragglers(self) -> list[int]:
        """Servers whose observed compute rate lags the fleet median by
        more than STRAGGLER_FACTOR (the controller's periodic probe)."""
        alive = self._alive()
        slow = self.cluster.sim.slowdown
        rates = sorted(slow[s] for s in alive)
        med = rates[len(rates) // 2]
        return [s for s in alive if slow[s] > med * self.STRAGGLER_FACTOR]

    def mitigate_stragglers(self) -> int:
        """Drain threads off straggling servers onto the fastest peers —
        the work-conserving answer while the node is replaced (its heap
        partition stays readable; only compute moves)."""
        moved = 0
        stragglers = set(self.detect_stragglers())
        if not stragglers:
            return 0
        healthy = [s for s in self._alive() if s not in stragglers]
        if not healthy:
            return 0
        sim = self.cluster.sim
        victims = [t for t in self.cluster.scheduler.threads
                   if not t.done and t.server in stragglers]
        # Spread by *projected* load: migration barely moves cpu_busy_us,
        # so re-reading the live snapshot per victim would send the whole
        # drained population to the single fastest peer.  Account each
        # migrated thread's estimated remaining work at its destination
        # before placing the next one.
        projected = {s: sim.servers[s].cpu_busy_us for s in sorted(healthy)}
        per_thread_est = {
            s: max(sim.servers[s].cpu_busy_us
                   / max(1, sum(1 for v in victims if v.server == s)), 1.0)
            for s in stragglers}
        for t in victims:
            dst = min(projected, key=lambda s: (projected[s], s))
            projected[dst] += per_thread_est[t.server]
            self.cluster.scheduler.migrate(t, dst)
            moved += 1
        return moved

    # -- balancing ----------------------------------------------------------
    def balance(self, horizon_us: float) -> int:
        """One balancing round; returns number of migrations performed."""
        cl, moved = self.cluster, 0
        threads = [t for t in cl.scheduler.threads if not t.done]
        alive = self._alive()
        for s in alive:
            if self.mem_frac(s) > self.MEM_HI:
                if cl.backend_drust:
                    # incremental CLOCK eviction toward the watermark — only
                    # the excess bytes are reclaimed, so warm copies below
                    # the high-water mark survive the pressure event
                    part = cl.heap.partitions[s]
                    excess = part.used - int(self.MEM_HI * part.capacity)
                    cl.drust.evict_caches(s, target_bytes=excess)
                victims = sorted((t for t in threads if t.server == s),
                                 key=lambda t: -t.local_heap_bytes)
                if victims and self.mem_frac(s) > self.MEM_HI:
                    dst = min(alive, key=self.mem_frac)
                    if dst != s:
                        cl.scheduler.migrate(victims[0], dst)
                        moved += 1
            if self.cpu_frac(s, horizon_us) > self.CPU_HI:
                remote_heavy = sorted(
                    (t for t in threads if t.server == s and t.remote_accesses),
                    key=lambda t: -sum(t.remote_accesses.values()))
                for t in remote_heavy[:1]:
                    dst = t.hottest_remote()
                    if dst is None or dst not in alive:
                        continue
                    if self.cpu_frac(dst, horizon_us) > self.CPU_HI:
                        dst = min(alive,
                                  key=lambda x: self.cpu_frac(x, horizon_us))
                    if dst != s:
                        cl.scheduler.migrate(t, dst)
                        moved += 1
        return moved


@dataclass
class CoalescePolicy:
    """Knobs for the adaptive deref coalescer.

    In this cost model batching within one quantum is monotone: a flush's
    per-server doorbells already overlap (and, under ooo, stripe across
    the QPs), so each extra coalesced deref only amortizes the doorbell
    base latency further — the makespan curve saturates at an
    *amortization knee*.  What a bigger quantum does cost is deref-latency
    **exposure**: the first registered deref materializes only when the
    quantum closes, and on bulk mixes that window grows linearly.  The
    adaptive budget therefore closes the quantum at the knee:

        ``n* = ceil(base / (amortize_frac * per_verb_cost))``

    where the marginal per-verb cost is the QP engine occupancy
    ``max(xfer(EWMA object size), qp_msg_us)`` under the out-of-order
    plane.  Small-object mixes → per-verb cost is the NIC message rate →
    large quanta (the base latency is the whole cost); bulk mixes →
    bandwidth dominates → moderate quanta (past the knee batching buys
    ~nothing while exposure keeps growing).  Under the legacy plane there
    is no per-QP serialization to price, so the budget sits at
    ``pending_cap`` and quanta close at conflicts / settle points.  The
    static knobs (``max_pending`` / ``max_bytes``) override adaptation —
    that is what the ``coalesce_sweep`` benchmark sweeps against.
    """

    max_pending: int | None = None     # static count budget (None = adaptive)
    max_bytes: int | None = None       # static byte budget (None = off)
    amortize_frac: float = 0.03        # knee target: base <= frac * marginal
    pending_cap: int = 64              # adaptive count budget ceiling
    ewma_alpha: float = 0.25           # deref-size tracker smoothing
    # Latency-exposure SLO: force a flush once the OLDEST registered deref
    # has been pending longer than this budget (us of virtual time).  The
    # count/byte budgets bound doorbell *size*; this bounds how long a
    # registered deref's materialization can be deferred — the exposure
    # cost the amortization knee trades against.  None = no SLO.
    max_expose_us: float | None = None

    def budgets(self, cost, qps: int, ooo: bool,
                ewma_bytes: float) -> tuple[int, int | None]:
        """(count budget, byte budget or None) for the current mix."""
        n = self.max_pending
        if n is None:
            if not ooo:                # legacy plane: bigger is always better
                n = self.pending_cap
            else:                      # price the per-QP engine occupancy
                per_verb = max(cost.xfer_us(max(ewma_bytes, 1.0)),
                               cost.qp_msg_us)
                n = math.ceil(cost.one_sided_base_us
                              / (self.amortize_frac * per_verb))
                n = max(1, min(self.pending_cap, n))
        return n, self.max_bytes


class DerefCoalescer:
    """Per-thread pending-deref registry behind ``Cluster(coalesce="auto")``.

    ``register`` takes the immutable borrow and queues the deref;
    ``flush`` closes the thread's quantum — the queued boxes go through
    ``DrustRuntime.read_many`` (identical verbs, bytes, and end state as
    the hand-written drain-then-fetch choreography), then the registration
    borrows drop.  Conflicting ops (mutable borrow / owner write /
    transfer / drop) call ``flush_box`` through the ownership layer so the
    registered borrows can never turn a legal program into a BorrowError.
    """

    def __init__(self, rt, policy: CoalescePolicy | None = None):
        self.rt = rt
        self.policy = policy or CoalescePolicy()
        self.pending: dict[int, tuple[Any, list]] = {}  # tid -> (th, [(box, ref)])
        self.pending_bytes: dict[int, int] = {}
        self.first_reg_t: dict[int, float] = {}         # tid -> oldest reg time
        self.by_box: dict[Any, set[int]] = {}           # box -> tids (identity)
        self.ewma_bytes = 0.0
        self.flushes = 0
        self.flushed_derefs = 0
        self.registered = 0
        self.expose_flushes = 0                         # SLO-forced flushes
        self.align = False      # placement: merge sibling threads' pending
        #                         derefs for the same destinations at flush

    def wants(self, th, box) -> bool:
        """Registration applies to non-owning derefs of *cold remote*
        objects; local, warm, speculative-hit, dropped, and mutably
        borrowed boxes take the eager path (which raises/materializes
        exactly as the manual plane would)."""
        if box.dropped or box.live_mut:
            return False
        if A.server_of(box.g) == th.server:
            return False
        return box.g not in self.rt.caches[th.server].entries

    def register(self, th, box) -> Any:
        """Queue a deref; returns a *snapshot* of the payload immediately —
        the borrow freezes it, so the bytes cannot differ from what the
        flush materializes, and the clone matches the manual plane's
        semantics (a reader holds a copy, never an alias of the owner's
        heap object).  Fetch cost is charged at the flush."""
        rt = self.rt
        tid = th.tid
        ent = self.pending.get(tid)
        if ent is None:
            ent = (th, [])
            self.pending[tid] = ent
            self.pending_bytes[tid] = 0
        _, items = ent
        if any(b is box for b, _ in items):
            # re-deref inside the same quantum: charged like a warm re-read
            sim = rt.sim
            sim.deref_check(th)
            sim.busy(th, sim.cost.hashmap_us)
            sim.local_access(th)
            return _clone(rt.heap.get(A.clear_color(box.g)).data)
        ref = box.borrow(th)
        items.append((box, ref))
        self.by_box.setdefault(box, set()).add(tid)
        self.first_reg_t.setdefault(tid, th.t_us)
        nbytes = rt.heap.group_bytes(A.clear_color(box.g))
        self.pending_bytes[tid] += nbytes
        a = self.policy.ewma_alpha
        self.ewma_bytes = (nbytes if self.ewma_bytes == 0.0
                           else (1 - a) * self.ewma_bytes + a * nbytes)
        self.registered += 1
        n_budget, b_budget = self.policy.budgets(
            rt.sim.cost, rt.sim.qps, rt.sim.ooo, self.ewma_bytes)
        expose = self.policy.max_expose_us
        if (len(items) >= n_budget
                or (b_budget is not None
                    and self.pending_bytes[tid] >= b_budget)):
            self.flush(th)
        elif (expose is not None
                and th.t_us - self.first_reg_t[tid] >= expose):
            # Latency-exposure SLO: the oldest registered deref has been
            # deferred past the budget — close the quantum now.
            self.expose_flushes += 1
            self.flush(th)
        return _clone(rt.heap.get(A.clear_color(box.g)).data)

    def flush(self, th) -> int:
        """Close ``th``'s quantum: one coalesced ``read_many`` over the
        pending set, then the registration borrows drop."""
        ent = self.pending.pop(th.tid, None)
        self.pending_bytes.pop(th.tid, None)
        self.first_reg_t.pop(th.tid, None)
        if not ent:
            return 0
        _, items = ent
        for box, _ in items:
            tids = self.by_box.get(box)
            if tids is not None:
                tids.discard(th.tid)
                if not tids:
                    self.by_box.pop(box, None)
        merged: list[tuple[Any, Any, Any]] = []         # (oth, box, ref)
        if self.align and self.pending:
            # Cross-thread quantum alignment (placement subsystem): sibling
            # threads on the same server with pending derefs bound for the
            # destinations this flush is already dialing join the same
            # read_many — one doorbell per source instead of one per
            # quantum.  The payload lands in the shared per-server cache,
            # so the end state is identical to the siblings flushing on
            # their own; only the doorbell count drops.  Their never-
            # deref'd registration borrows release here (no cache pin).
            dests = {A.server_of(b.g) for b, _ in items}
            for tid in sorted(self.pending):
                oth, oitems = self.pending[tid]
                if oth.server != th.server:
                    continue
                take = [(b, r) for b, r in oitems
                        if A.server_of(b.g) in dests]
                if not take:
                    continue
                keep = [(b, r) for b, r in oitems
                        if A.server_of(b.g) not in dests]
                if keep:
                    self.pending[tid] = (oth, keep)
                    self.pending_bytes[tid] -= sum(
                        self.rt.heap.group_bytes(A.clear_color(b.g))
                        for b, _ in take)
                else:
                    self.pending.pop(tid)
                    self.pending_bytes.pop(tid, None)
                    self.first_reg_t.pop(tid, None)
                for b, r in take:
                    tids = self.by_box.get(b)
                    if tids is not None:
                        tids.discard(tid)
                        if not tids:
                            self.by_box.pop(b, None)
                    merged.append((oth, b, r))
            self.rt.sim.net.quantum_merges += len(merged)
        if merged:
            # The least-loaded participant drives the shared doorbell (the
            # first thread to reach the flush point posts it; the others'
            # registrations ride along).  Driving rotates with load, so
            # the merged fetch work spreads across the sibling pool
            # instead of piling onto whichever tid sorts first.
            parts = {th.tid: th}
            for oth, _, _ in merged:
                parts[oth.tid] = oth
            driver = min(parts.values(), key=lambda t: (t.t_us, t.tid))
            self.rt.read_many(driver, [b for b, _ in items]
                              + [b for _, b, _ in merged])
        else:
            self.rt.read_many(th, [b for b, _ in items])
        for _, ref in items:
            ref.drop(th)
        for oth, _, ref in merged:
            ref.drop(oth)
        self.flushes += 1
        self.flushed_derefs += len(items) + len(merged)
        return len(items)

    def discard(self, th) -> int:
        """``th`` died mid-quantum (its server crashed): its registered
        derefs can never materialize — no doorbell may be posted from a
        dead server — so the registration borrows release *without* a
        ``read_many``.  Returns the number of derefs discarded."""
        ent = self.pending.pop(th.tid, None)
        self.pending_bytes.pop(th.tid, None)
        self.first_reg_t.pop(th.tid, None)
        if not ent:
            return 0
        _, items = ent
        for box, ref in items:
            tids = self.by_box.get(box)
            if tids is not None:
                tids.discard(th.tid)
                if not tids:
                    self.by_box.pop(box, None)
            ref.drop(th)         # registration never deref'd: no cache pin
        return len(items)

    def flush_box(self, box) -> None:
        """A mutable op is about to touch ``box``: close the quantum of
        every thread holding a registered deref on it (sorted by tid —
        deterministic)."""
        for tid in sorted(self.by_box.get(box, ())):
            ent = self.pending.get(tid)
            if ent is not None:
                self.flush(ent[0])

    def flush_all(self) -> int:
        """Settle point (end of trace / snapshot): close every quantum."""
        n = 0
        for tid in sorted(self.pending):
            ent = self.pending.get(tid)
            if ent is not None:
                n += self.flush(ent[0])
        return n


@dataclass
class PlacementPolicy:
    """Knobs for telemetry-driven placement (``Cluster(placement="auto")``).

    The guard layer feeds per-box access-locality counters (accessor
    server × box, attributed to the TBox tie root so affinity groups are
    judged — and moved — as one closure).  Weights decay by ``decay`` per
    quantum epoch (EWMA), so the window tracks the *current* phase, not
    the run's history.  When one server's weight dominates — at least
    ``min_weight`` absolute and ``dominance`` × the runner-up — and the
    payload lives elsewhere, the hot accessor pulls ownership to itself
    with a fence-scoped live migration (``DrustRuntime.migrate_here``).
    ``cooldown`` epochs of hysteresis after each move stop a contended box
    from ping-ponging between two comparably hot servers.
    """

    decay: float = 0.5          # per-epoch EWMA multiplier on counters
    min_weight: float = 3.0     # absolute weight floor to trigger a move
    dominance: float = 2.0      # hot server must beat the runner-up by this
    cooldown: int = 1           # epochs a box rests after migrating
    quantum_align: bool = True  # merge sibling same-destination doorbells
    # Write accesses vote with this weight.  Default 0: the drust
    # write-move already relocates an object to any remote writer, so a
    # write is always *local by construction* when its guard closes —
    # counting it would anchor the box wherever compute last touched it
    # and veto every read-affinity move.  Reads are what a static
    # placement cannot fix; they carry the vote.
    write_weight: float = 0.0


class PlacementTracker:
    """Access-locality telemetry + migration trigger behind
    ``Cluster(placement="auto")``.

    Installed as ``backend.placement``; ``ReadGuard``/``WriteGuard`` close
    call ``note_access`` — guard exit is the one point where the borrow
    just released, so a triggered migration can never race the recording
    access's own borrow.  Migration is additionally suppressed while any
    borrow in the moving closure is live (``migrate_here`` re-checks after
    flushing registered derefs) and during recovery quiesce.
    """

    def __init__(self, cluster: "Cluster",
                 policy: PlacementPolicy | None = None):
        self.cluster = cluster
        self.policy = policy or PlacementPolicy()
        self.epoch = 0
        # root box -> [weights {server: w}, last-decay epoch, last-mig epoch]
        self._rec: dict[Any, list] = {}
        self.samples = 0
        self.migrations = 0

    def tick(self) -> None:
        """Close a quantum epoch: subsequent accesses see decayed weights
        (applied lazily per box on its next access)."""
        self.epoch += 1

    def weights(self, box) -> dict[int, float]:
        """Current (decayed) per-server weights for ``box``'s tie root."""
        root = self.cluster.drust.placement_root(box)
        rec = self._rec.get(root)
        if rec is None:
            return {}
        f = self.policy.decay ** (self.epoch - rec[1])
        return {s: w * f for s, w in rec[0].items()}

    def note_access(self, th, h, write: bool = False) -> None:
        cl = self.cluster
        if cl.recovery is not None and cl.recovery.quiescing:
            return                       # no placement churn mid fail-over
        rt = cl.drust
        root = rt.placement_root(h)
        if root.dropped or root.lost:
            self._rec.pop(root, None)
            return
        src = A.server_of(root.g)
        if src != th.server:
            th.note_remote(src)          # controller cpu-policy telemetry
        p = self.policy
        rec = self._rec.get(root)
        if rec is None:
            rec = [{}, self.epoch, -(1 << 30)]
            self._rec[root] = rec
        w = rec[0]
        if rec[1] != self.epoch:         # lazy EWMA decay since last touch
            f = p.decay ** (self.epoch - rec[1])
            for s in list(w):
                w[s] *= f
                if w[s] < 1e-6:
                    del w[s]
            rec[1] = self.epoch
        vote = p.write_weight if write else 1.0
        if vote > 0.0:
            w[th.server] = w.get(th.server, 0.0) + vote
        self.samples += 1
        if not w:
            return
        if self.epoch - rec[2] < p.cooldown:
            return                       # hysteresis: box rested recently
        hot = max(sorted(w), key=lambda s: w[s])
        if hot != th.server or hot == src:
            return   # only the hot accessor pulls, and only if remote
        whot = w[hot]
        second = max((v for s, v in w.items() if s != hot), default=0.0)
        if whot < p.min_weight or whot < p.dominance * second:
            return
        if rt.migrate_here(th, root):
            rec[2] = self.epoch
            rec[0] = {}                  # fresh window after the move
            self.migrations += 1

    def spawn_hint(self, handles) -> int | None:
        """Weighted-plurality location of a region's pin/prefetch hint
        set — the ``spawn_near`` placement target (None = no preference)."""
        votes: dict[int, float] = {}
        for h in handles:
            s = self.cluster.backend.locate(h)
            votes[s] = votes.get(s, 0.0) + 1.0
        if not votes:
            return None
        return max(sorted(votes), key=lambda s: votes[s])


class Cluster:
    """One simulated deployment: N servers, one protocol backend."""

    def __init__(self, n_servers: int, backend: str = "drust",
                 cores_per_server: int = 16, cost: CostModel | None = None,
                 partition_bytes: int | None = None, replicate: bool = False,
                 batch_io: bool = True, qps_per_thread: int = 1,
                 ooo: bool = False, coalesce: str = "manual",
                 coalesce_policy: CoalescePolicy | None = None,
                 placement: str = "static",
                 placement_policy: PlacementPolicy | None = None,
                 sanitize: bool | None = None):
        if coalesce not in ("manual", "auto"):
            raise ValueError(f"unknown coalesce mode {coalesce!r}")
        if placement not in ("static", "auto"):
            raise ValueError(f"unknown placement mode {placement!r}")
        self.sim = Sim(n_servers, cores_per_server, cost,
                       qps_per_thread=qps_per_thread, ooo=ooo)
        self.heap = GlobalHeap(n_servers, partition_bytes)
        self.partition_bytes = partition_bytes  # for elastic add_server
        self.backend_name = backend
        self.batch_io = batch_io
        self.channels: list = []               # auto mode: quantum-settled
        self.mutexes: list = []                # recovery: lock reconstruction
        # Every protocol engine implements the ProtocolBackend ABC and is
        # constructed uniformly from the registry; capability flags
        # (supports_*) replace backend-name special cases downstream.
        self.backend = backend_class(backend)(self.sim, self.heap,
                                              batch_io=batch_io)
        self.backend_drust = self.backend.supports_ownership
        if self.backend_drust:
            self.drust = self.backend
        # The deref coalescer needs the batched plane (it flushes through
        # read_many doorbells) and ownership-derived borrows (drust only);
        # channel send staging applies under "auto" for every backend.
        self.coalesce = coalesce
        if coalesce == "auto" and self.backend_drust and batch_io:
            self.drust.coalescer = DerefCoalescer(self.drust, coalesce_policy)
        # Telemetry-driven placement (opt-in: the default "static" keeps
        # every run byte-identical to the pre-placement planes).  The
        # tracker installs as ``backend.placement`` — the guard layer's
        # close hooks feed it — and flips the coalescer's cross-thread
        # quantum alignment on.
        self.placement_mode = placement
        self.placement: PlacementTracker | None = None
        if placement == "auto":
            if not self.backend_drust:
                raise RuntimeError(
                    "placement='auto' requires an ownership-capable backend")
            self.placement = PlacementTracker(self, placement_policy)
            self.backend.placement = self.placement
            if (self.drust.coalescer is not None
                    and self.placement.policy.quantum_align):
                self.drust.coalescer.align = True
        self.scheduler = Scheduler(self)
        self.controller = GlobalController(self)
        self.replicator = None
        if replicate and backend == "drust":
            from .fault import Replicator
            self.replicator = Replicator(self)
        # Crash fail-over pipeline (drust only: it reconciles ownership
        # state — borrows, guards, spec cids — the baselines don't track).
        self.recovery = None
        if self.backend_drust:
            from .fault import RecoveryManager
            self.recovery = RecoveryManager(self)
        # Runtime borrow/cid sanitizer (``repro.analysis``): opt-in via the
        # ``sanitize`` flag or the ``REPRO_SANITIZE`` env var (so CI can run
        # an unmodified test subset under sanitize).  Observation only —
        # sanitize-off runs are byte-identical, sanitize-on runs add checks
        # and an event trace but never charge the cost model.
        if sanitize is None:
            sanitize = os.environ.get("REPRO_SANITIZE", "") not in ("", "0")
        self.sanitizer = None
        if sanitize:
            from repro.analysis.sanitizer import Sanitizer
            self.sanitizer = Sanitizer(self)
            self.backend.sanitizer = self.sanitizer
            self.sim.tracer = self.sanitizer

    # elasticity ----------------------------------------------------------
    def add_server(self) -> int:
        """Elastic grow: a fresh server joins the live cluster — Sim stats
        + QP restripe, a new heap partition (the PGAS address space already
        reserves its range), an empty cache wired into the spec-disposition
        ledger, and a replica map if replication is on.  Returns the new
        server index.  Only the drust backend supports growing (the
        baselines size per-server state once, at construction)."""
        if not self.backend_drust:
            raise RuntimeError("elastic grow requires the drust backend")
        s = self.sim.add_server()
        part = self.heap.add_partition(self.partition_bytes)
        from .cache import LocalCache
        H = LocalCache(s, part)
        H.on_spec_drop = (
            lambda cid: self.drust._dispose_spec(cid, "invalidated"))
        self.drust.caches.append(H)
        if self.replicator is not None:
            self.replicator.add_server(s)
        return s

    # convenience ---------------------------------------------------------
    def main_thread(self, server: int = 0) -> Thread:
        th = Thread(server)
        self.scheduler.threads.append(th)
        return th

    def region(self, th, prefetch=(), pin=(), lease=()) -> Region:
        """``with cluster.region(th) as r:`` — scoped batching region.

        Entry applies the optional ``prefetch``/``pin``/``lease`` hints
        (prefetch/pin also available as ``r.prefetch(...)`` /
        ``r.pin(...)`` inside the scope; ``lease`` takes reader leases on
        ``DRwLock``s that persist past the region — see ``core/sync.py``);
        exit is a settle point for exactly this thread's pending work —
        registered derefs flush as ``read_many`` doorbells, staged channel
        sends ring, pins release (see ``protocol.Region``)."""
        return Region(self, th, prefetch=prefetch, pin=pin, lease=lease)

    def settle(self, th) -> None:
        """Per-thread settle point (a region exit): flush ``th``'s staged
        channel sends and close its coalescer quantum.  Idempotent — no-op
        under ``coalesce="manual"`` or when nothing is pending."""
        for ch in self.channels:
            ch.flush_sends(only_tid=th.tid)
        if self.backend_drust and self.drust.coalescer is not None:
            self.drust.coalescer.flush(th)

    def close_quanta(self) -> None:
        """End-of-quantum settle (runtime policy, not app code): flush
        staged channel sends and every pending coalesced deref.  Idempotent
        — a no-op under ``coalesce="manual"`` or when nothing is pending."""
        for ch in self.channels:
            ch.flush_sends()
        if self.backend_drust and self.drust.coalescer is not None:
            self.drust.coalescer.flush_all()
        if self.placement is not None:
            self.placement.tick()        # quantum epoch: counters decay

    def makespan_us(self) -> float:
        self.close_quanta()
        if self.sanitizer is not None:
            self.sanitizer.final_check()     # spec-cid ledger must balance
        return self.sim.makespan_us(self.scheduler.threads)

    def throughput(self, n_ops: int) -> float:
        """ops/sec given the virtual makespan."""
        span = self.makespan_us()
        return n_ops / (span / 1e6) if span > 0 else float("inf")
