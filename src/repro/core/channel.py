"""Distributed mpsc channels (§4.1.2).

Because the heap is globally shared, a message containing Box pointers /
references is valid on any server: the sender pushes the object *as is*
(pointer words, no serialization) and the receiver recovers it by type
conversion (no deserialization).  Cross-server sends cost one two-sided
message of the pointer bytes; same-server sends are queue ops.

This is the mechanism behind the SocialNet result: pass-by-reference RPC
eliminates the serialize/deserialize cycle entirely.

Under ``Cluster(coalesce="auto")`` reference sends are *staged*: the
runtime buffers them per (sender, destination) and rings one wire message
per pair at the quantum settle point — a receiver's first ``recv`` or
``Cluster.close_quanta()`` — the runtime-policy counterpart of the
hand-written ``send_many`` drain.  Values are delivered in original send
order, so program-visible FIFO semantics are unchanged; only the wire
accounting coalesces.  By-value sends (``nbytes`` given) are never staged:
the payload copy is the cost being measured in that baseline.
"""

from __future__ import annotations

from collections import deque
from typing import Any

POINTER_BYTES = 16      # colored global address + extension word


class Channel:
    def __init__(self, cluster, capacity: int = 1 << 16):
        self.cluster = cluster
        self.q: deque = deque()
        self.capacity = capacity
        self.sent = 0
        self.recv_server: int | None = None   # pinned at rx() time
        self._staged: list = []               # [(value, sender th, dst server)]
        chans = getattr(cluster, "channels", None)
        if chans is not None:
            chans.append(self)                # Cluster.close_quanta settles us

    def _auto(self) -> bool:
        return getattr(self.cluster, "coalesce", "manual") == "auto"

    def send(self, th, value: Any, nbytes: int | None = None) -> None:
        """``nbytes`` is the wire size: pointer words for references (the
        DRust fast path), or the full payload for by-value sends."""
        if nbytes is None and self._auto():
            self._staged.append((value, th, self.recv_server))
            self.sent += 1
            return
        sim = self.cluster.sim
        wire = POINTER_BYTES if nbytes is None else nbytes
        if self.recv_server is not None and self.recv_server != th.server:
            sim.rpc(th, self.recv_server, req_bytes=wire, resp_bytes=0)
        else:
            sim.local_access(th)
        self.q.append((value, th.t_us))
        self.sent += 1

    def send_many(self, th, values, nbytes_each: int | None = None) -> None:
        """Doorbell-coalesced send: K messages to the same receiver ride ONE
        wire message carrying K pointer words (or K payloads for by-value),
        amortizing the per-message round trip — the batched counterpart of
        a service handing its drained inbox downstream."""
        sim = self.cluster.sim
        per = POINTER_BYTES if nbytes_each is None else nbytes_each
        if self.recv_server is not None and self.recv_server != th.server:
            sim.rpc(th, self.recv_server, req_bytes=per * len(values),
                    resp_bytes=0)
        else:
            sim.local_access(th)
        for v in values:
            self.q.append((v, th.t_us))
        self.sent += len(values)

    def flush_sends(self, only_tid: int | None = None) -> None:
        """Settle staged sends: one wire message per (sender, destination
        server) pair carrying that pair's pointer words; values enqueue in
        original send order (FIFO preserved).  ``only_tid`` settles a
        single sender (a region exit): that thread's staged sends ring,
        other senders' stay staged — per-sender FIFO is unaffected."""
        if not self._staged:
            return
        sim = self.cluster.sim
        if only_tid is None:
            staged, self._staged = self._staged, []
        else:
            staged = [e for e in self._staged if e[1].tid == only_tid]
            if not staged:
                return
            self._staged = [e for e in self._staged if e[1].tid != only_tid]
        groups: dict[tuple[int, int | None], list] = {}
        for v, th, dst in staged:
            groups.setdefault((th.tid, dst), []).append(th)
        t_of: dict[tuple[int, int | None], float] = {}
        for key, senders in groups.items():
            th, dst = senders[0], key[1]
            if dst is not None and dst != th.server:
                sim.rpc(th, dst, req_bytes=POINTER_BYTES * len(senders),
                        resp_bytes=0)
            else:
                sim.local_access(th)
            t_of[key] = th.t_us
        for v, th, dst in staged:
            self.q.append((v, t_of[(th.tid, dst)]))

    def drop_for_server(self, dead: int) -> int:
        """Recovery quiesce: dispose staged sends orphaned by ``dead`` —
        sends FROM threads on the dead server (the sender died before its
        quantum settled, so the message was never on the wire) and sends
        TO a receiver pinned on the dead server (nobody will drain them).
        If the receiver itself lived on the dead server its queue dies
        with it.  Returns the number of orphaned messages dropped."""
        n = 0
        if self._staged:
            keep = []
            for v, th, dst in self._staged:
                if th.server == dead or dst == dead:
                    n += 1
                else:
                    keep.append((v, th, dst))
            self._staged = keep
        if self.recv_server == dead:
            n += len(self.q)
            self.q.clear()
            self.recv_server = None
        return n

    def recv(self, th) -> Any:
        self.flush_sends()                   # staged sends land before drain
        sim = self.cluster.sim
        self.recv_server = th.server
        sim.local_access(th)
        value, t_sent = self.q.popleft()
        th.t_us = max(th.t_us, t_sent)       # happens-before: msg arrival
        return value

    def __len__(self) -> int:
        return len(self.q) + len(self._staged)
