"""Color-versioned checkpointing with elastic resharding.

DRust's fault-tolerance design (§4.2.3) applied to training state:

  * write-backs are batched per ownership epoch — the checkpoint hook fires
    at the train step's mutable-borrow drop, and only every
    ``every_n_epochs`` (the controller's pressure/latency trade);
  * the checkpoint is addressed by the state's *colored address*: restore
    verifies it resumes the exact write epoch (no torn state);
  * leaves are stored per logical address with their global shapes, so a
    checkpoint taken on one mesh restores onto any other mesh ("promote the
    backup on a different cluster" — elastic resharding is a re-partition
    of the PGAS, not a format change).

Format: one ``.npz`` per snapshot + a JSON manifest (leaf paths, shapes,
dtypes, color, step).

``quantize=True`` stores large float leaves int8 on disk
(``repro.dist.compression.quantize_int8``: symmetric per-tensor scale,
``|x - q*scale| <= scale/2`` — the error-feedback bound, asserted at save
time) and dequantizes transparently on restore; small leaves (norms,
scalars, integer steps) stay exact.  ~4x smaller snapshots for the cost of
one quantization step of noise — the same trade the wire compression makes.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jaxstate import ColoredAddr, OwnedState


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out[path] = leaf
    return out, treedef


def save(path: str | Path, tree: Any, *, color: int = 0, step: int = 0,
         extra: dict | None = None, quantize: bool = False,
         min_quant_size: int = 64) -> Path:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    leaves, _ = _flatten(tree)
    arrays = {}
    manifest_leaves = {}
    for k, v in leaves.items():
        a = np.asarray(v)
        if a.dtype.kind == "V" or str(a.dtype) == "bfloat16":
            a = np.asarray(jnp.asarray(v).astype(jnp.float32))
        entry = {"shape": list(a.shape), "dtype": str(a.dtype)}
        if quantize and a.dtype.kind == "f" and a.size >= min_quant_size:
            from repro.dist.compression import quantize_int8
            q, scale = quantize_int8(a)
            q, scale = np.asarray(q), np.asarray(scale, dtype=np.float32)
            # Error-feedback bound (repro.dist.compression): the on-disk
            # representation may never be more than half a quantization
            # step from the live value.
            err = np.max(np.abs(a.astype(np.float32)
                                - q.astype(np.float32) * scale))
            assert err <= float(scale) / 2 + 1e-12, \
                f"{k}: int8 checkpoint error {err} exceeds scale/2"
            arrays[k + "::q"] = q
            arrays[k + "::scale"] = scale
            entry["quantized"] = True
        else:
            arrays[k] = a
        manifest_leaves[k] = entry
    np.savez(str(path) + ".npz", **arrays)
    manifest = {
        "color": color, "step": step,
        "leaves": manifest_leaves,
        "extra": extra or {},
    }
    Path(str(path) + ".json").write_text(json.dumps(manifest, indent=1))
    return path


def restore(path: str | Path, like: Any, *, mesh=None, specs=None) -> tuple:
    """Restore into the structure of ``like`` (abstract or concrete pytree).
    With ``mesh``+``specs`` the leaves are placed with NamedSharding —
    restoring onto a different mesh reshards transparently."""
    path = Path(path)
    manifest = json.loads(Path(str(path) + ".json").read_text())
    data = np.load(str(path) + ".npz")
    leaves_like, treedef = _flatten(like)
    specs_flat = None
    if specs is not None:
        specs_flat, _ = _flatten(specs)
    out = {}
    for k, ref_leaf in leaves_like.items():
        if manifest["leaves"].get(k, {}).get("quantized"):
            from repro.dist.compression import dequantize_int8
            arr = np.asarray(
                dequantize_int8(jnp.asarray(data[k + "::q"]),
                                jnp.asarray(data[k + "::scale"])))
        else:
            arr = data[k]
        want = jnp.dtype(ref_leaf.dtype)
        a = jnp.asarray(arr).astype(want)
        if mesh is not None and specs_flat is not None and k in specs_flat:
            a = jax.device_put(a, jax.sharding.NamedSharding(
                mesh, specs_flat[k]))
        out[k] = a
    restored = treedef.unflatten([out[k] for k in leaves_like])
    return restored, manifest


class CheckpointManager:
    """Epoch-batched async-style checkpointing for an OwnedState."""

    def __init__(self, directory: str | Path, state: OwnedState,
                 every_n_epochs: int = 1, keep: int = 3,
                 quantize: bool = False):
        self.dir = Path(directory)
        self.state = state
        self.every = every_n_epochs
        self.keep = keep
        self.quantize = quantize           # int8 on disk, exact manifest
        self.saved: list[tuple[int, Path]] = []
        state.on_epoch.append(self._hook)

    def _hook(self, addr: ColoredAddr, tree: Any) -> None:
        if addr.color % self.every != 0:
            return
        p = self.dir / f"ckpt_{addr.color:08d}"
        save(p, tree, color=addr.color, step=addr.color,
             quantize=self.quantize)
        self.saved.append((addr.color, p))
        while len(self.saved) > self.keep:
            _, old = self.saved.pop(0)
            for suffix in (".npz", ".json"):
                Path(str(old) + suffix).unlink(missing_ok=True)

    def latest(self) -> tuple[int, Path] | None:
        return self.saved[-1] if self.saved else None

    def restore_latest(self, like: Any, mesh=None, specs=None):
        if not self.saved:
            raise FileNotFoundError("no checkpoints saved")
        color, p = self.saved[-1]
        tree, manifest = restore(p, like, mesh=mesh, specs=specs)
        self.state._tree = tree
        self.state.addr = ColoredAddr(self.state.addr.name, manifest["color"])
        return tree, manifest
