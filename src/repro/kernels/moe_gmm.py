"""Per-expert grouped matmul (MoE expert compute), Pallas TPU.

Operates on the capacity-buffer layout the router produces:
x (E, C, D) @ w (E, D, F) -> y (E, C, F).  Grid (E, C/bc, F/bf, D/bd) with
the contraction axis innermost; f32 accumulation in VMEM scratch.

  vmem = bc*bd (x) + bd*bf (w) + bc*bf f32 (acc)

bc=bf=256, bd=512: ~0.9 MB.  All tile dims are 128-multiples (MXU-aligned).
This is the hot 65% of MoE train-step FLOPs (see EXPERIMENTS §Roofline).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _kernel(x_ref, w_ref, y_ref, acc_ref):
    d = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)

    @pl.when(d == nd - 1)
    def _finish():
        y_ref[0] = acc_ref[...].astype(y_ref.dtype)


def moe_gmm(x, w, *, block_c: int = 256, block_f: int = 256,
            block_d: int = 512, interpret: bool = False):
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    F = w.shape[2]
    bc, bf, bd = min(block_c, C), min(block_f, F), min(block_d, D)
    grid = (E, C // bc, F // bf, D // bd)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, i, j, d: (e, i, d)),
            pl.BlockSpec((1, bd, bf), lambda e, i, j, d: (e, d, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, i, j, d: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, w)
