"""Chunked WKV6 recurrence (RWKV6 time-mix core), Pallas TPU.

Same matmul-dense chunk math as ``models.rwkv._wkv_chunk`` (exponents
relative to the chunk start, all bounded), with the cross-chunk state S
(M x M, f32) living in VMEM scratch across the sequential chunk axis.

Grid (B*H, T/C); per-program VMEM:
  4*C*M (r,k,v,logw) + C*M (o) + M*M f32 (S) + C*C f32 (scores)
C=128, M=64: ~0.3 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, sout_ref, s_ref, *,
            chunk: int):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)            # (C, M)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)          # logw <= 0
    u = u_ref[0].astype(jnp.float32)            # (M,)

    cs = jnp.cumsum(lw, axis=0)                 # logA_t (inclusive)
    q_in = r * jnp.exp(cs - lw)                 # r * A_{t-1}   (<= |r|)
    k_in = k * jnp.exp(-cs)                     # bounded by exp(C*decay_max)
    scores = jax.lax.dot_general(q_in, k_in, (((1,), (1,)), ((), ())))
    ti = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    si = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(si < ti, scores, 0.0)    # strict lower triangle
    diag = jnp.sum(r * u[None, :] * k, axis=1)  # bonus (s == t)
    o = scores @ v + diag[:, None] * v
    o = o + q_in @ s_ref[...]                   # cross-chunk history

    a_tail = jnp.exp(cs[-1:, :] - cs)           # prod_{s>t} w_s
    s_ref[...] = (jnp.exp(cs[-1])[:, None] * s_ref[...]
                  + jax.lax.dot_general(k * a_tail, v,
                                        (((0,), (0,)), ((), ()))))
    o_ref[0] = o.astype(o_ref.dtype)

    @pl.when(c == nc - 1)
    def _finish():
        sout_ref[0] = s_ref[...].astype(sout_ref.dtype)


def rwkv_scan(r, k, v, logw, u, *, chunk: int = 128,
              interpret: bool = False):
    """r,k,v,logw: (B, H, T, M); u: (H, M) -> (o (B,H,T,M), S (B,H,M,M))."""
    B, H, T, M = r.shape
    chunk = min(chunk, T)
    BH = B * H
    shp = (BH, T, M)
    rf, kf, vf, lwf = (a.reshape(shp) for a in (r, k, v, logw))
    uf = jnp.broadcast_to(u[None], (B, H, M)).reshape(BH, M)
    grid = (BH, T // chunk)

    o, s = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, M), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, M), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, M), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, M), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, M), lambda b, c: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, M), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, M, M), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, M), jnp.float32),
            jax.ShapeDtypeStruct((BH, M, M), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((M, M), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rf, kf, vf, lwf, uf)
    return o.reshape(B, H, T, M), s.reshape(B, H, M, M)
