"""RG-LRU diagonal linear recurrence h_t = a_t*h_{t-1} + b_t, Pallas TPU.

Grid (B, D/bd, T/C): channel blocks are parallel programs, the time axis is
sequential with the carry h (1, bd) in VMEM scratch.  Inside a chunk the
recurrence runs as a fori_loop over rows — elementwise VPU work streaming
(C, bd) tiles once from HBM (this layer is bandwidth-bound by design).

  vmem = 2*C*bd (a, b) + C*bd (h out) + bd f32 (carry)
C=256, bd=512: ~1.6 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams


def _kernel(a_ref, b_ref, h_ref, carry_ref, *, chunk: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)            # (C, bd)
    b = b_ref[0].astype(jnp.float32)

    def step(i, carry):
        h = a[i] * carry + b[i]
        h_ref[0, i, :] = h.astype(h_ref.dtype)
        return h

    carry_ref[...] = jax.lax.fori_loop(0, chunk, step, carry_ref[...])


def rglru_scan(a, b, *, chunk: int = 256, block_d: int = 512,
               interpret: bool = False):
    """a, b: (B, T, D) -> h: (B, T, D) with h_t = a_t h_{t-1} + b_t."""
    B, T, D = a.shape
    chunk = min(chunk, T)
    bd = min(block_d, D)
    grid = (B, D // bd, T // chunk)

    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, chunk, bd), lambda bi, di, ti: (bi, ti, di)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd), lambda bi, di, ti: (bi, ti, di)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
