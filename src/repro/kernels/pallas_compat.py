"""Version tolerance for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
JAX releases; the kernels are written against the new name and this shim
maps it onto whichever spelling the installed JAX provides.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
