"""Causal GQA flash attention (forward), Pallas TPU.

Dataflow: grid (B*H, Tq/block_q, S/block_k); the KV dimension is the
innermost (sequential) grid axis, so the per-program VMEM working set is
one q block + one kv block + f32 accumulators:

  vmem = block_q*hd (q) + 2*block_k*hd (k,v) + block_q*(hd+2) f32 (acc,m,l)

With block_q = block_k = 512, hd = 128: ~0.8 MB — comfortably in the 16 MB
VMEM budget; block sizes are multiples of the MXU tile (128).  Fully-masked
KV blocks (block start beyond the causal frontier) are skipped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, causal: bool):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k
    run = jnp.logical_or(not causal, k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            qi = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kj <= qi, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_k: int = 512, interpret: bool = False):
    """q: (B, H, T, hd); k, v: (B, Hkv, S, hd) -> (B, H, T, hd)."""
    B, H, T, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    scale = hd ** -0.5

    qf = q.reshape(B * H, T, hd)
    kf = k.reshape(B * Hkv, S, hd)
    vf = v.reshape(B * Hkv, S, hd)
    grid = (B * H, T // block_q, S // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // G, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, T, hd)
