"""Jit'd public wrappers for every kernel.

On TPU the Pallas kernels compile natively; elsewhere ``interpret=True``
executes the same blocked dataflow in Python (correctness validation — the
per-kernel tests sweep shapes/dtypes against the ``ref`` oracles).
"""

from __future__ import annotations

import functools

import jax

from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .moe_gmm import moe_gmm as _gmm
from .rglru_scan import rglru_scan as _rglru
from .rwkv_scan import rwkv_scan as _rwkv


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 512,
                    block_k: int = 512):
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, lengths, block_k: int = 512):
    return _decode(q, k, v, lengths, block_k=block_k,
                   interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("block_c", "block_f", "block_d"))
def moe_gmm(x, w, block_c: int = 256, block_f: int = 256, block_d: int = 512):
    return _gmm(x, w, block_c=block_c, block_f=block_f, block_d=block_d,
                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv_scan(r, k, v, logw, u, chunk: int = 128):
    return _rwkv(r, k, v, logw, u, chunk=chunk, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "block_d"))
def rglru_scan(a, b, chunk: int = 256, block_d: int = 512):
    return _rglru(a, b, chunk=chunk, block_d=block_d,
                  interpret=_interpret())
