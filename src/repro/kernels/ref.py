"""Pure-jnp oracles for every kernel (the correctness ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal: bool = True):
    """q: (B,H,T,hd); k,v: (B,Hkv,S,hd) -> (B,H,T,hd)."""
    B, H, T, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, T, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgth,bksh->bkgts", qg, k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((T, S), bool), k=S - T)
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bksh->bkgth", p, v.astype(jnp.float32))
    return o.reshape(B, H, T, hd).astype(q.dtype)


def decode_attention(q, k, v, lengths):
    """q: (B,H,hd); k,v: (B,Hkv,S,hd); lengths: (B,) -> (B,H,hd)."""
    B, H, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)


def moe_gmm(x, w):
    """x: (E,C,D); w: (E,D,F) -> (E,C,F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def rwkv_scan(r, k, v, logw, u):
    """Naive per-step WKV6: the definitional recurrence.
    r,k,v,logw: (B,H,T,M); u: (H,M) -> (o (B,H,T,M) f32, S (B,H,M,M) f32)."""
    B, H, T, M = r.shape
    rf = r.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    w = jnp.exp(logw.astype(jnp.float32))

    def step(S, t):
        kv = jnp.einsum("bhm,bhn->bhmn", kf[:, :, t], vf[:, :, t])
        o = jnp.einsum("bhm,bhmn->bhn", rf[:, :, t],
                       S + u[None, :, :, None] * kv)
        S = w[:, :, t][..., None] * S + kv
        return S, o

    S0 = jnp.zeros((B, H, M, M), jnp.float32)
    S, os = jax.lax.scan(step, S0, jnp.arange(T))
    return os.transpose(1, 2, 0, 3), S


def rglru_scan(a, b):
    """Naive h_t = a_t h_{t-1} + b_t.  a, b: (B,T,D) -> (B,T,D) f32."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(h, t):
        h = af[:, t] * h + bf[:, t]
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, h0, jnp.arange(a.shape[1]))
    return hs.transpose(1, 0, 2)
