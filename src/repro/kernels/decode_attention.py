"""Decode attention (flash-decode), Pallas TPU: one new token per sequence
attends to a long KV cache.  Memory-bound — the kernel's job is to stream
the cache through VMEM exactly once at full HBM bandwidth.

Grid (B, Hkv, S/block_k), KV innermost/sequential; all G=H/Hkv query heads
of a kv group ride in one program so the cache block is read once per
group, not once per head.

  vmem = G*hd (q) + 2*block_k*hd (k,v) + G*(hd+2) f32 accumulators
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_compat import CompilerParams

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_k: int):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[pl.program_id(0)]
    k_start = j * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, bk)
        kj = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kj < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, lengths, *, block_k: int = 512,
                     interpret: bool = False):
    """q: (B, H, hd); k, v: (B, Hkv, S, hd); lengths: (B,) int32
    -> (B, H, hd).  Positions >= lengths[b] are masked."""
    B, H, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    block_k = min(block_k, S)
    scale = hd ** -0.5
    qg = q.reshape(B, Hkv, G, hd)
    grid = (B, Hkv, S // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # lengths prefetch
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, hd)
