"""Pallas TPU kernels for the compute hot spots.

Each kernel has: ``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM
tiling), a jit'd wrapper in ``ops.py``, and a pure-jnp oracle in ``ref.py``.
On non-TPU backends the wrappers run in interpret mode (correctness only);
the blocked dataflow is identical to what the MXU executes.

Kernels:
  flash_attention  — causal GQA attention, online softmax over KV blocks
  decode_attention — one-token query vs a long KV cache (serve hot loop)
  moe_gmm          — per-expert grouped matmul over capacity buffers
  rwkv_scan        — chunked WKV6 recurrence (data-dependent decay)
  rglru_scan       — RG-LRU diagonal linear recurrence
"""

from . import ops, ref

__all__ = ["ops", "ref"]
