"""SocialNet (§7.1): a microservice pipeline passing references, not values.

DeathStarBench's social network decomposed into services (compose → text →
media → storage) connected by channels.  The original deployment serializes
every payload into RPC byte streams; on DSM the services pass 16-byte heap
references and the receiving service fetches the object on dereference.
DRust's win (Fig. 5b): no serialize/deserialize compute, no redundant
copies, one one-sided READ per actual use.

``by_value=True`` reproduces the original (non-DSM) distributed baseline.

``coalesce`` selects who batches the I/O:

* ``"auto"`` (default, drust + batched plane only) — the services run the
  *plain* per-request send/recv/deref loop with zero drain/fetch
  choreography; the runtime stages the reference sends per (sender,
  destination) pair and registers the derefs, coalescing both into wire
  messages / ``read_many`` doorbells at quantum close (see
  ``core/runtime.py``'s ``DerefCoalescer``).
* ``"manual"`` — the PR-1 hand-batched choreography: every service drains
  its inbox per request class and fetches the batch through one explicit
  ``read_many`` (kept for A/B; this is what the golden fixtures pin).

``batch_io=False`` keeps the legacy per-object plane — protocol state ends
up identical in every mode, only the verb accounting coalesces.
``qps_per_thread``/``ooo``/``cost`` select the completion model (multi-QP
out-of-order plane vs the legacy in-order plane; see ``core/net.py``).
"""

from __future__ import annotations

import numpy as np

from repro.core import Channel, backend_caps
from .common import (AppResult, hot_layout_server, make_cluster,
                     placement_cluster_kw, run_skewed_phases, spread_threads)

TEXT_BYTES = 1024
MEDIA_BYTES = 50 * 1024
SER_CYCLES_PER_BYTE = 1.5          # serialize + deserialize, each way
POST_PROC_CYCLES = 60_000          # per-service request handling
STORE_PROC_CYCLES = 30_000         # storage-service write path
RPC_STACK_CYCLES = 40_000          # Thrift/HTTP stack per side, cross-server


def drain_order(class_map: dict) -> list:
    """Deterministic inbox-drain order for the manual batched plane: the
    per-class map is keyed ``(k, src_server)`` and drained in sorted key
    order, whatever order the classes were built in — golden counters must
    depend on the workload, not on dict-insertion iteration."""
    return sorted(class_map)


def run_socialnet(n_servers: int, backend: str = "drust",
                  n_requests: int = 400, media_frac: float = 0.25,
                  workers_per_server: int = 4, cores: int = 16,
                  by_value: bool = False, batch_io: bool = True,
                  coalesce: str = "auto", qps_per_thread: int = 1,
                  ooo: bool = False, cost=None, seed: int = 0,
                  placement: str = "static",
                  skew: float | None = None) -> AppResult:
    # The runtime deref coalescer needs ownership borrows + the batched
    # plane; every other configuration runs the manual choreography.
    auto = (coalesce == "auto" and backend_caps(backend).supports_coalescing
            and batch_io and not by_value)
    cl = make_cluster(n_servers, backend, cores, batch_io=batch_io,
                      qps_per_thread=qps_per_thread, ooo=ooo, cost=cost,
                      coalesce="auto" if auto else "manual",
                      **placement_cluster_kw(placement))
    rng = np.random.default_rng(seed)
    boot = cl.main_thread(0)

    if skew is not None:
        # Zipf-skewed hot-profile mix (the placement_sweep workload): a
        # small set of hot user profiles updated by movable compose
        # workers and read mostly by one phase-dominant timeline service
        # per phase — see ``common.run_skewed_phases``.
        # A fixed-size hot set: the skew is the workload's point — a
        # bigger cluster does not mint more celebrities, it just puts
        # more distance between them and their readers.
        hot_profiles = 8
        hot = [cl.backend.alloc(boot, TEXT_BYTES, (j, 0),
                                server=hot_layout_server(
                                    placement, j, n_servers))
               for j in range(hot_profiles)]
        boot.t_us = 0.0
        ths = spread_threads(cl, workers_per_server)
        digest, ops = run_skewed_phases(
            cl, ths, hot, alpha=skew, seed=seed,
            accesses_per_phase=max(1, n_requests // 6))
        span = cl.makespan_us()
        return AppResult("socialnet", backend, n_servers, ops, span,
                         net=cl.sim.snapshot()["net"],
                         extra={"placement": placement, "skew": skew,
                                "payload_digest": digest})

    ths = spread_threads(cl, workers_per_server)
    n_stages = 4                                   # compose→text→media→storage
    # Stages 0-2 scale out over every server (stateless replicas, spread like
    # Docker Swarm); the post-storage service is stateful and stays sharded on
    # server 0 — the dependency that limits SocialNet's scaling in Fig. 5b.
    stride = max(1, len(ths) // n_stages)
    storage_pool = [t for t in ths if t.server == 0]
    stage_workers = [[ths[(k + s * stride) % len(ths)] for k in range(len(ths))]
                     for s in range(n_stages - 1)]
    stage_workers.append([storage_pool[k % len(storage_pool)]
                          for k in range(len(ths))])
    chans = [Channel(cl) for _ in range(n_stages - 1)]
    has_media = rng.random(n_requests) < media_frac
    nbytes_of = [TEXT_BYTES + (MEDIA_BYTES if has_media[i] else 0)
                 for i in range(n_requests)]

    # Stage-phased (batched) execution: every service drains its inbox, then
    # hands the batch downstream — a steady-state throughput pipeline.  Sends
    # and receives are separate sub-phases so independent requests overlap
    # (the FIFO happens-before only orders each message, not the batch).
    inflight: list = [None] * n_requests
    digest = 0                                     # fetched payload bytes
    for i in range(n_requests):                    # stage 0: compose
        th0 = stage_workers[0][i % len(ths)]
        cl.sim.compute(th0, POST_PROC_CYCLES)
        inflight[i] = cl.backend.alloc(th0, nbytes_of[i],
                                       bytes(min(nbytes_of[i], 4096)))
    batched = batch_io and not by_value and not auto
    for s in range(1, n_stages):
        chan = chans[s - 1]
        if batched:
            # Manual choreography: requests in the same class k = i %
            # len(ths) share their (src, dst) worker pair in every stage —
            # one wire message and one batched fetch per class, drained in
            # the deterministic (k, src server) order.
            class_map: dict = {}
            for k in range(len(ths)):
                idxs = [i for i in range(n_requests) if i % len(ths) == k]
                if idxs:
                    class_map[(k, stage_workers[s - 1][k].server)] = idxs
            for key in drain_order(class_map):     # send sub-phase: one wire
                k, _src = key                      # message per worker pair
                idxs = class_map[key]
                src = stage_workers[s - 1][k]
                dst = stage_workers[s][k]
                chan.recv_server = dst.server
                chan.send_many(src, [inflight[i] for i in idxs])
            for key in drain_order(class_map):     # recv sub-phase: drain the
                k, _src = key                      # inbox, then batched fetch
                idxs = class_map[key]
                dst = stage_workers[s][k]
                handles = []
                for i in idxs:
                    handle = chan.recv(dst)
                    proc = (STORE_PROC_CYCLES if s == n_stages - 1
                            else POST_PROC_CYCLES)
                    cl.sim.compute(dst, proc)
                    handles.append(handle)
                    inflight[i] = handle
                for data in cl.backend.read_many(dst, handles):
                    digest += len(data)
            continue
        for i in range(n_requests):                # send sub-phase
            src = stage_workers[s - 1][i % len(ths)]
            dst = stage_workers[s][i % len(ths)]
            chan.recv_server = dst.server
            if by_value:
                cl.sim.compute(src, SER_CYCLES_PER_BYTE * nbytes_of[i])
                if src.server != dst.server:
                    cl.sim.compute(src, RPC_STACK_CYCLES)
                chan.send(src, inflight[i], nbytes=nbytes_of[i])
            else:
                chan.send(src, inflight[i])        # 16-byte reference
        for i in range(n_requests):                # recv sub-phase
            src = stage_workers[s - 1][i % len(ths)]
            dst = stage_workers[s][i % len(ths)]
            handle = chan.recv(dst)
            if by_value:
                cl.sim.compute(dst, SER_CYCLES_PER_BYTE * nbytes_of[i])
                if src.server != dst.server:
                    cl.sim.compute(dst, RPC_STACK_CYCLES)
            proc = STORE_PROC_CYCLES if s == n_stages - 1 else POST_PROC_CYCLES
            cl.sim.compute(dst, proc)
            if not by_value:
                with handle.read(dst) as data:        # fetch on dereference
                    digest += len(data)               # (scoped borrow)
            inflight[i] = handle

    span = cl.makespan_us()                        # settles pending quanta
    return AppResult("socialnet", backend if not by_value else "original",
                     n_servers, n_requests, span,
                     net=cl.sim.snapshot()["net"],
                     extra={"batch_io": batch_io and not by_value,
                            "coalesce": "auto" if auto else "manual",
                            "payload_digest": digest})


def plain_socialnet_us(n_requests: int = 400, media_frac: float = 0.25,
                       workers_per_server: int = 4) -> float:
    """Original single-node deployment: the Docker-composed RPC version —
    services still serialize every payload into byte streams even on one
    machine (loopback transport, so no cross-host RPC stack cost).  This is
    the paper's Fig. 5b normalizer, which is why even the single-node DSM
    versions beat it ~2x."""
    avg_bytes = TEXT_BYTES + MEDIA_BYTES * media_frac
    per_req = ((3 * POST_PROC_CYCLES + STORE_PROC_CYCLES) / 2.6e3
               + 3 * 2 * SER_CYCLES_PER_BYTE * avg_bytes / 2.6e3  # ser+deser
               + 3 * (0.14 + avg_bytes / 2e4)  # loopback RPC hand-offs
               + 0.14)                         # alloc
    return n_requests * per_req / workers_per_server
