"""SocialNet (§7.1): a microservice pipeline passing references, not values.

DeathStarBench's social network decomposed into services (compose → text →
media → storage) connected by channels.  The original deployment serializes
every payload into RPC byte streams; on DSM the services pass 16-byte heap
references and the receiving service fetches the object on dereference.
DRust's win (Fig. 5b): no serialize/deserialize compute, no redundant
copies, one one-sided READ per actual use.

``by_value=True`` reproduces the original (non-DSM) distributed baseline.
``batch_io=True`` (default) lets each service drain its inbox and fetch the
whole batch of referenced payloads through the doorbell-coalesced I/O plane
(one fetch round per source server per drain instead of one verb per
request); ``batch_io=False`` keeps the legacy per-object path — protocol
state ends up identical either way, only the verb accounting coalesces.
``qps_per_thread``/``ooo``/``cost`` select the completion model (multi-QP
out-of-order plane vs the legacy in-order plane; see ``core/net.py``).
"""

from __future__ import annotations

import numpy as np

from repro.core import Channel
from .common import AppResult, make_cluster, spread_threads

TEXT_BYTES = 1024
MEDIA_BYTES = 50 * 1024
SER_CYCLES_PER_BYTE = 1.5          # serialize + deserialize, each way
POST_PROC_CYCLES = 60_000          # per-service request handling
STORE_PROC_CYCLES = 30_000         # storage-service write path
RPC_STACK_CYCLES = 40_000          # Thrift/HTTP stack per side, cross-server


def run_socialnet(n_servers: int, backend: str = "drust",
                  n_requests: int = 400, media_frac: float = 0.25,
                  workers_per_server: int = 4, cores: int = 16,
                  by_value: bool = False, batch_io: bool = True,
                  qps_per_thread: int = 1, ooo: bool = False,
                  cost=None, seed: int = 0) -> AppResult:
    cl = make_cluster(n_servers, backend, cores, batch_io=batch_io,
                      qps_per_thread=qps_per_thread, ooo=ooo, cost=cost)
    rng = np.random.default_rng(seed)
    boot = cl.main_thread(0)

    ths = spread_threads(cl, workers_per_server)
    n_stages = 4                                   # compose→text→media→storage
    # Stages 0-2 scale out over every server (stateless replicas, spread like
    # Docker Swarm); the post-storage service is stateful and stays sharded on
    # server 0 — the dependency that limits SocialNet's scaling in Fig. 5b.
    stride = max(1, len(ths) // n_stages)
    storage_pool = [t for t in ths if t.server == 0]
    stage_workers = [[ths[(k + s * stride) % len(ths)] for k in range(len(ths))]
                     for s in range(n_stages - 1)]
    stage_workers.append([storage_pool[k % len(storage_pool)]
                          for k in range(len(ths))])
    chans = [Channel(cl) for _ in range(n_stages - 1)]
    has_media = rng.random(n_requests) < media_frac
    nbytes_of = [TEXT_BYTES + (MEDIA_BYTES if has_media[i] else 0)
                 for i in range(n_requests)]

    # Stage-phased (batched) execution: every service drains its inbox, then
    # hands the batch downstream — a steady-state throughput pipeline.  Sends
    # and receives are separate sub-phases so independent requests overlap
    # (the FIFO happens-before only orders each message, not the batch).
    inflight: list = [None] * n_requests
    for i in range(n_requests):                    # stage 0: compose
        th0 = stage_workers[0][i % len(ths)]
        cl.sim.compute(th0, POST_PROC_CYCLES)
        inflight[i] = cl.backend.alloc(th0, nbytes_of[i],
                                       bytes(min(nbytes_of[i], 4096)))
    # Requests in the same class k = i % len(ths) share their (src, dst)
    # worker pair in every stage — the batched plane coalesces each class's
    # messages/fetches, which changes no pairing and no worker assignment.
    batched = batch_io and not by_value
    classes = [[i for i in range(n_requests) if i % len(ths) == k]
               for k in range(len(ths))]
    for s in range(1, n_stages):
        chan = chans[s - 1]
        if batched:
            for k, idxs in enumerate(classes):     # send sub-phase: one wire
                if not idxs:                       # message per worker pair
                    continue
                src = stage_workers[s - 1][k]
                dst = stage_workers[s][k]
                chan.recv_server = dst.server
                chan.send_many(src, [inflight[i] for i in idxs])
            for k, idxs in enumerate(classes):     # recv sub-phase: drain the
                if not idxs:                       # inbox, then batched fetch
                    continue
                dst = stage_workers[s][k]
                handles = []
                for i in idxs:
                    handle = chan.recv(dst)
                    proc = (STORE_PROC_CYCLES if s == n_stages - 1
                            else POST_PROC_CYCLES)
                    cl.sim.compute(dst, proc)
                    handles.append(handle)
                    inflight[i] = handle
                cl.backend.read_many(dst, handles)
            continue
        for i in range(n_requests):                # send sub-phase
            src = stage_workers[s - 1][i % len(ths)]
            dst = stage_workers[s][i % len(ths)]
            chan.recv_server = dst.server
            if by_value:
                cl.sim.compute(src, SER_CYCLES_PER_BYTE * nbytes_of[i])
                if src.server != dst.server:
                    cl.sim.compute(src, RPC_STACK_CYCLES)
                chan.send(src, inflight[i], nbytes=nbytes_of[i])
            else:
                chan.send(src, inflight[i])        # 16-byte reference
        for i in range(n_requests):                # recv sub-phase
            src = stage_workers[s - 1][i % len(ths)]
            dst = stage_workers[s][i % len(ths)]
            handle = chan.recv(dst)
            if by_value:
                cl.sim.compute(dst, SER_CYCLES_PER_BYTE * nbytes_of[i])
                if src.server != dst.server:
                    cl.sim.compute(dst, RPC_STACK_CYCLES)
            proc = STORE_PROC_CYCLES if s == n_stages - 1 else POST_PROC_CYCLES
            cl.sim.compute(dst, proc)
            if not by_value:
                cl.backend.read(dst, handle)       # fetch on dereference
            inflight[i] = handle

    return AppResult("socialnet", backend if not by_value else "original",
                     n_servers, n_requests, cl.makespan_us(),
                     net=cl.sim.snapshot()["net"],
                     extra={"batch_io": batch_io and not by_value})


def plain_socialnet_us(n_requests: int = 400, media_frac: float = 0.25,
                       workers_per_server: int = 4) -> float:
    """Original single-node deployment: the Docker-composed RPC version —
    services still serialize every payload into byte streams even on one
    machine (loopback transport, so no cross-host RPC stack cost).  This is
    the paper's Fig. 5b normalizer, which is why even the single-node DSM
    versions beat it ~2x."""
    avg_bytes = TEXT_BYTES + MEDIA_BYTES * media_frac
    per_req = ((3 * POST_PROC_CYCLES + STORE_PROC_CYCLES) / 2.6e3
               + 3 * 2 * SER_CYCLES_PER_BYTE * avg_bytes / 2.6e3  # ser+deser
               + 3 * (0.14 + avg_bytes / 2e4)  # loopback RPC hand-offs
               + 0.14)                         # alloc
    return n_requests * per_req / workers_per_server
