"""Shared harness for the evaluation applications."""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import Cluster


@dataclass
class AppResult:
    app: str
    backend: str
    n_servers: int
    ops: int
    makespan_us: float
    net: dict = field(default_factory=dict)
    extra: dict = field(default_factory=dict)

    @property
    def throughput(self) -> float:
        return self.ops / (self.makespan_us / 1e6) if self.makespan_us else 0.0


def plain_time_us(total_cycles: float, total_local_accesses: int,
                  cores: int, ghz: float = 2.6,
                  local_access_us: float = 0.14) -> float:
    """The original single-machine program: perfect parallelism over
    ``cores``, no DSM checks, all accesses local."""
    return (total_cycles / (ghz * 1e3) + total_local_accesses * local_access_us) / cores


def zipf_keys(n_ops: int, n_keys: int, alpha: float = 0.99,
              seed: int = 0) -> np.ndarray:
    """YCSB-style zipfian key sequence (default skew 0.99)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return rng.choice(n_keys, size=n_ops, p=p)


def make_cluster(n_servers: int, backend: str, cores: int = 16,
                 **kw) -> Cluster:
    return Cluster(n_servers, backend=backend, cores_per_server=cores, **kw)


PLACEMENT_MODES = ("static", "spread", "packed", "auto")


def placement_cluster_kw(placement: str) -> dict:
    """Cluster kwargs for an app ``placement=`` mode: only ``"auto"``
    installs the tracker; every static layout runs the byte-identical
    default plane."""
    if placement not in PLACEMENT_MODES:
        raise ValueError(f"unknown placement mode {placement!r}")
    return {"placement": "auto"} if placement == "auto" else {}


def hot_layout_server(placement: str, j: int, n_servers: int) -> int:
    """Static home for hot object ``j``: ``packed`` piles the hot set on
    server 0 (co-located with one accessor, worst for the rest);
    ``spread``/``static`` stripe it round-robin (balanced, but every
    phase-dominant reader still crosses the wire for most of the set).
    ``auto`` starts from the spread layout and lets migration move it."""
    return 0 if placement == "packed" else j % n_servers


def run_skewed_phases(cl, ths, hot, *, n_phases: int = 6,
                      accesses_per_phase: int = 96, alpha: float = 0.99,
                      write_stride: int = 2, minority_stride: int = 6,
                      seed: int = 0) -> tuple[int, int]:
    """Phase-rotating zipf-skewed read/write mix over the ``hot`` handles —
    the placement-sweep workload (both skewed apps drive it with their own
    hot-set shapes).

    Each phase ``p`` has a *dominant* reader server ``p % n`` whose pinned
    workers issue most reads (a minority lands one server over, so
    dominance — not mere presence — must trigger migration); writers are
    *movable* compute placed by ``backend.locate``, i.e. they follow the
    data like a ``spawn_to`` operator would.  Every write bumps the
    object's version, so under a static layout the dominant server's next
    read is a cold re-fetch; with ``placement="auto"`` the box (and its
    TBox closure) migrates to the dominant server once per phase and the
    read-after-write cycle goes fully local.  The rotation guarantees no
    single static layout wins every phase.

    Returns ``(digest, ops)`` — the digest folds every value read, in
    schedule order, and the schedule is placement-independent, so any two
    placement modes must produce identical digests.
    """
    n = cl.sim.n
    by_server: dict[int, list] = {}
    for t in ths:
        by_server.setdefault(t.server, []).append(t)
    rr = {s: 0 for s in by_server}

    def worker_on(s):
        pool = by_server.get(s)
        if not pool:
            pool = by_server[min(by_server)]
            s = pool[0].server
        th = pool[rr[s] % len(pool)]
        rr[s] += 1
        return th

    versions = [0] * len(hot)
    digest = 0
    ops = 0
    for p in range(n_phases):
        dom = p % n
        keys = zipf_keys(accesses_per_phase, len(hot), alpha,
                         seed=seed * 1009 + p)
        for a, j in enumerate(keys):
            j = int(j)
            box = hot[j]
            if a % write_stride == 0:
                wt = worker_on(cl.backend.locate(box))
                versions[j] += 1
                with box.write(wt) as slot:
                    slot.set((j, versions[j]))
                ops += 1
            reader = worker_on((dom + 1) % n if a % minority_stride == 0
                               else dom)
            with box.read(reader) as v:
                digest = (digest * 1000003 + v[0] * 31 + v[1]) & ((1 << 61) - 1)
            ops += 1
        cl.close_quanta()            # phase boundary: quantum epoch ticks
    return digest, ops


def spread_threads(cluster: Cluster, per_server: int):
    """One batch of worker threads, evenly spread (paper methodology for the
    baselines; DRust's controller could do this adaptively)."""
    ths = []
    for s in range(cluster.sim.n):
        for _ in range(per_server):
            th = cluster.main_thread(0)
            th.server = s
            ths.append(th)
    return ths
