"""Shared harness for the evaluation applications."""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import Cluster


@dataclass
class AppResult:
    app: str
    backend: str
    n_servers: int
    ops: int
    makespan_us: float
    net: dict = field(default_factory=dict)
    extra: dict = field(default_factory=dict)

    @property
    def throughput(self) -> float:
        return self.ops / (self.makespan_us / 1e6) if self.makespan_us else 0.0


def plain_time_us(total_cycles: float, total_local_accesses: int,
                  cores: int, ghz: float = 2.6,
                  local_access_us: float = 0.14) -> float:
    """The original single-machine program: perfect parallelism over
    ``cores``, no DSM checks, all accesses local."""
    return (total_cycles / (ghz * 1e3) + total_local_accesses * local_access_us) / cores


def zipf_keys(n_ops: int, n_keys: int, alpha: float = 0.99,
              seed: int = 0) -> np.ndarray:
    """YCSB-style zipfian key sequence (default skew 0.99)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    p /= p.sum()
    return rng.choice(n_keys, size=n_ops, p=p)


def make_cluster(n_servers: int, backend: str, cores: int = 16,
                 **kw) -> Cluster:
    return Cluster(n_servers, backend=backend, cores_per_server=cores, **kw)


def spread_threads(cluster: Cluster, per_server: int):
    """One batch of worker threads, evenly spread (paper methodology for the
    baselines; DRust's controller could do this adaptively)."""
    ths = []
    for s in range(cluster.sim.n):
        for _ in range(per_server):
            th = cluster.main_thread(0)
            th.server = s
            ths.append(th)
    return ths
