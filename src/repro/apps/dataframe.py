"""DataFrame (§7.1): columnar analytics with a shared index table.

Mirrors the paper's Polars-based workload: tables are columns partitioned
by row into chunks (heap objects).  Every operation builds a shared *index
table* mapping destination chunks to source chunks — index-builder threads
WRITE entries concurrently (each builder owns its entry shard: SWMR), then
worker threads probe SEVERAL entries (hash-table probing) and process the
source chunks (low compute intensity: the coherence overhead stands out,
Fig. 5a).  Dependent operations re-read chunks (cacheable reuse).

Affinity annotations (§4.1.3, Fig. 6):
  * ``use_tbox``     — chunks of a column are tied into one affinity group:
                       fetched in a single batched READ, deref check skipped.
  * ``use_spawn_to`` — columnar operators run on the server hosting their
                       input column instead of round-robin placement.

``coalesce`` selects who batches the I/O:

* ``"auto"`` (default, drust + batched plane only) — probes and chunk
  scans are *plain per-object derefs*; the runtime registers them and
  coalesces the fetches at quantum close — here mostly at the borrow
  conflict when the next index-entry WRITE lands on a probed entry (the
  write/read ping-pong closes the quantum), so the app carries zero
  drain/fetch choreography.
* ``"manual"`` — the PR-1 choreography: explicit ``read_many`` batches
  for the probe set and both chunk passes (kept for A/B golden pins).

``batch_io=False`` keeps the legacy per-object plane with identical final
heap/cache state.  ``qps_per_thread``/``ooo``/``cost`` select the
completion model (multi-QP out-of-order plane vs the legacy in-order
plane; see ``core/net.py``).
"""

from __future__ import annotations

import numpy as np

from repro.core import backend_caps
from .common import (AppResult, hot_layout_server, make_cluster,
                     placement_cluster_kw, run_skewed_phases, spread_threads)

CYCLES_PER_BYTE = 110.13
SIMD_LANES = 8                    # AVX2 over f64 rows


def run_dataframe(n_servers: int, backend: str = "drust",
                  n_columns: int = 8, chunks_per_column: int = 32,
                  chunk_rows: int = 512, n_ops: int = 8,
                  probes: int = 4, workers_per_server: int = 4,
                  cores: int = 16, use_tbox: bool = False,
                  use_spawn_to: bool = False, batch_io: bool = True,
                  coalesce: str = "auto", qps_per_thread: int = 1,
                  ooo: bool = False, cost=None, seed: int = 0,
                  placement: str = "static",
                  skew: float | None = None) -> AppResult:
    caps = backend_caps(backend)
    use_tbox = use_tbox and caps.supports_affinity
    use_spawn_to = use_spawn_to and caps.supports_affinity
    auto = coalesce == "auto" and caps.supports_coalescing and batch_io
    cl = make_cluster(n_servers, backend, cores, batch_io=batch_io,
                      qps_per_thread=qps_per_thread, ooo=ooo, cost=cost,
                      coalesce="auto" if auto else "manual",
                      **placement_cluster_kw(placement))
    rng = np.random.default_rng(seed)
    chunk_bytes = chunk_rows * 8
    chunk_cycles = CYCLES_PER_BYTE * chunk_bytes / SIMD_LANES

    boot = cl.main_thread(0)

    if skew is not None:
        # Zipf-skewed hot-partition mix (the placement_sweep workload):
        # each hot item is a small index entry with a TBox-tied chunk
        # behind it, so a migration moves the whole affinity group as one
        # closure — see ``common.run_skewed_phases``.
        # Fixed-size hot partition set: skew concentrates on the same few
        # groups regardless of cluster size.
        hot_groups = 8
        hot = []
        for j in range(hot_groups):
            root = cl.backend.alloc(boot, 64, (j, 0),
                                    server=hot_layout_server(
                                        placement, j, n_servers))
            if caps.supports_affinity:
                cl.backend.alloc(boot, chunk_bytes, None, tie_to=root)
            hot.append(root)
        boot.t_us = 0.0
        for s in cl.sim.servers:
            s.cpu_busy_us = 0.0
        ths = spread_threads(cl, workers_per_server)
        digest, ops = run_skewed_phases(
            cl, ths, hot, alpha=skew, seed=seed,
            accesses_per_phase=max(1, n_ops * chunks_per_column // 6))
        span = cl.makespan_us()
        return AppResult("dataframe", backend, n_servers, ops, span,
                         net=cl.sim.snapshot()["net"],
                         extra={"placement": placement, "skew": skew,
                                "result_digest": digest})
    columns = []                    # column -> list of chunk handles
    for c in range(n_columns):
        prev = None
        handles = []
        for k in range(chunks_per_column):
            data = rng.standard_normal(chunk_rows)
            if use_tbox and prev is not None:
                # Listing 3: chunks chained with TBox — one affinity group,
                # co-located with the head, fetched in a single batched READ.
                h = cl.backend.alloc(boot, chunk_bytes, data, tie_to=prev)
            else:
                srv = (c if use_tbox else c * chunks_per_column + k) % n_servers
                h = cl.backend.alloc(boot, chunk_bytes, data, server=srv)
            prev = h
            handles.append(h)
        columns.append(handles)

    # Shared index table: one entry object per destination chunk.
    index = [cl.backend.alloc(boot, 64, None, server=k % n_servers)
             for k in range(chunks_per_column)]
    boot.t_us = 0.0
    for s in cl.sim.servers:
        s.cpu_busy_us = 0.0

    ths = spread_threads(cl, workers_per_server)
    choreograph = batch_io and not auto            # manual read_many batches
    digest = 0.0                                   # result bytes (A/B pin)
    ops = 0
    w = 0
    # n_ops independent single-column queries run concurrently (h2oai-style);
    # iteration is k-major so at every step the in-flight items span all
    # columns.  Index builders and workers interleave on the shared table: a
    # fresh entry is written, then probed by workers on other servers — the
    # write/read ping-pong that hammers invalidation-based protocols.
    for k in range(chunks_per_column):
        for op in range(n_ops):
            col = columns[op % n_columns]
            entry = index[k]
            # builder and worker pools rotate independently (co-prime offsets)
            th = ths[w % len(ths)]
            srcs = [(k + d) % chunks_per_column for d in range(2)]
            with entry.write(th) as slot:             # builder owns its shard
                slot.set(srcs)
            ops += 1
            if use_spawn_to:
                # current owner location (tracks transfers/write-moves),
                # not the allocation-time home
                data_srv = cl.backend.locate(col[k])
                cand = [t for t in ths if t.server == data_srv]
                th = min(cand, key=lambda t: t.t_us) if cand \
                    else ths[(w + len(ths) // 2) % len(ths)]
            else:
                th = ths[(w + len(ths) // 2) % len(ths)]
            w += 1
            probe_handles = [index[(k - p) % len(index)]
                             for p in range(1, probes)] + [index[k]]
            if choreograph:                               # batched probing
                srcs = cl.backend.read_many(th, probe_handles)[-1]
            else:
                # plain hash-table probing: per-entry scoped derefs
                # (registered and coalesced by the runtime under
                # coalesce="auto")
                for h in probe_handles[:-1]:
                    with h.read(th):
                        pass
                # Copy while the guard is open: `srcs` outlives this block
                # (scan/materialize passes below), and the payload itself is
                # only valid under the guard.
                with index[k].read(th) as v:
                    srcs = list(v)
            if use_tbox:
                # iterating the column dereferences the head TBox chain:
                # the whole group lands in the local cache in one READ
                with col[0].read(th):
                    pass
            acc = 0.0
            if choreograph:
                scan = cl.backend.read_many(th, [col[s] for s in srcs])
                for chunk in scan:                        # scan pass
                    acc += float(np.sum(chunk))
                    cl.sim.compute(th, chunk_cycles)
                cl.backend.read_many(th, [col[s] for s in srcs])
                for _ in srcs:                            # materialize pass
                    cl.sim.compute(th, chunk_cycles * 0.25)
            else:
                for s_idx in srcs:
                    with col[s_idx].read(th) as chunk:    # scan pass
                        acc += float(np.sum(chunk))
                        cl.sim.compute(th, chunk_cycles)
                    with col[s_idx].read(th):             # materialize
                        cl.sim.compute(th, chunk_cycles * 0.25)
            digest += acc
            out = cl.backend.alloc(th, chunk_bytes, acc)
            with out.write(th) as slot:
                slot.set(acc)
            ops += 1

    span = cl.makespan_us()                        # settles pending quanta
    return AppResult("dataframe", backend, n_servers, ops, span,
                     net=cl.sim.snapshot()["net"],
                     extra={"use_tbox": use_tbox, "use_spawn_to": use_spawn_to,
                            "batch_io": batch_io,
                            "coalesce": "auto" if auto else "manual",
                            "result_digest": digest})


def plain_dataframe_us(n_columns: int = 8, chunks_per_column: int = 32,
                       chunk_rows: int = 512, n_ops: int = 8,
                       probes: int = 4, workers_per_server: int = 4) -> float:
    chunk_bytes = chunk_rows * 8
    chunk_cycles = CYCLES_PER_BYTE * chunk_bytes / SIMD_LANES
    compute = n_ops * chunks_per_column * 2 * chunk_cycles * 1.25
    accesses = n_ops * chunks_per_column * (1 + probes + 1 + 4 + 2)
    return (compute / 2.6e3 + accesses * 0.14) / workers_per_server
