"""GEMM (§7.1): blocked divide-and-conquer matmul over the shared heap.

A, B are tiled into T×T blocks stored as heap objects, spread round-robin
over the servers' partitions.  Workers own contiguous ranges of output
tiles; for C[i,j] a worker reads the A[i,:] row tiles and B[:,j] column
tiles (immutable → cacheable) and writes C[i,j] locally.  High compute
intensity (Table 1: ~300 cycles/byte) means protocols that cache
sub-matrices (DRust, GAM) scale; always-delegating Grappa does not
(Fig. 5c: 5.93× / 3.82× / 2.02× at 8 nodes).

The numerics are real: the distributed result is asserted against the
single-shot ``A @ B`` oracle on every run.

``prefetch=True`` (drust only) posts a speculative fetch of the A-row and
B-column tiles before each output tile's k-loop: the read doorbells go out
while the first MACs run, and each tile deref pays only the deferred
completion fence (``late_fences``) instead of a synchronous round trip.
Tiles are immutable here, so no prefetch is ever wasted — the staleness
machinery (``wasted_prefetches``) stays at zero by construction.
"""

from __future__ import annotations

import numpy as np

from .common import AppResult, make_cluster, spread_threads

FLOPS_PER_CYCLE = 16.0          # AVX2 sgemm-ish per core


def run_gemm(n_servers: int, backend: str = "drust", n: int = 1024,
             tile: int = 128, workers_per_server: int = 4,
             cores: int = 16, seed: int = 0, prefetch: bool = False,
             check: bool = True) -> AppResult:
    cl = make_cluster(n_servers, backend, cores)
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((n, n)).astype(np.float32)
    B = rng.standard_normal((n, n)).astype(np.float32)
    nt = n // tile
    tile_bytes = tile * tile * 4

    boot = cl.main_thread(0)
    a_h, b_h = {}, {}
    for i in range(nt):
        for k in range(nt):
            a_h[(i, k)] = cl.backend.alloc(
                boot, tile_bytes, A[i*tile:(i+1)*tile, k*tile:(k+1)*tile].copy(),
                server=(i * nt + k) % n_servers)
            b_h[(k, i)] = cl.backend.alloc(
                boot, tile_bytes, B[k*tile:(k+1)*tile, i*tile:(i+1)*tile].copy(),
                server=(k * nt + i + 1) % n_servers)
    boot.t_us = 0.0                       # setup off the measured path
    for s in cl.sim.servers:
        s.cpu_busy_us = 0.0

    ths = spread_threads(cl, workers_per_server)
    out = np.zeros((n, n), dtype=np.float32)
    tiles = [(i, j) for i in range(nt) for j in range(nt)]
    # contiguous row-major ranges per worker: A-row / B-column tile reuse
    per_worker = -(-len(tiles) // len(ths))
    flops_per_mac = 2.0 * tile * tile * tile
    ops = 0
    for w, th in enumerate(ths):
        for (i, j) in tiles[w * per_worker:(w + 1) * per_worker]:
            # One region per output tile: the k-loop's working set is the
            # region's scope, and the speculative fetch of the whole A-row
            # / B-column set is an entry hint (already-cached tiles from
            # row/column reuse are skipped by the backend).
            hint = ([a_h[(i, k)] for k in range(nt)]
                    + [b_h[(k, j)] for k in range(nt)]) if prefetch else ()
            with cl.region(th, prefetch=hint):
                acc = np.zeros((tile, tile), dtype=np.float32)
                for k in range(nt):
                    with a_h[(i, k)].read(th) as at, \
                            b_h[(k, j)].read(th) as bt:
                        acc += at @ bt
                    cl.sim.compute(th, flops_per_mac / FLOPS_PER_CYCLE)
                    ops += 1
                c_handle = cl.backend.alloc(th, tile_bytes, acc)
                with c_handle.write(th) as wr:
                    wr.set(acc)
            out[i*tile:(i+1)*tile, j*tile:(j+1)*tile] = acc

    if check:
        np.testing.assert_allclose(out, A @ B, rtol=2e-3, atol=5e-2)

    return AppResult("gemm", backend, n_servers, ops, cl.makespan_us(),
                     net=cl.sim.snapshot()["net"],
                     extra={"flops": flops_per_mac * ops,
                            "prefetch": prefetch})


def plain_gemm_us(n: int = 1024, tile: int = 128,
                  workers_per_server: int = 4) -> float:
    """Single-machine original: same blocked schedule and thread count as the
    single-server DSM run, but no protocol instrumentation."""
    nt = n // tile
    cycles = 2.0 * n * n * n / FLOPS_PER_CYCLE
    accesses = nt * nt * nt * 2 + nt * nt * 2       # tile reads + C alloc/write
    return (cycles / 2.6e3 + accesses * 0.14) / workers_per_server
