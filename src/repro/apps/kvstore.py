"""KV Store (§7.1): chained-hash in-memory cache under YCSB zipf load.

The paper's most DSM-unfriendly app: poor locality, low compute intensity
(~48 cycles/byte), and mutex-synchronized buckets whose shared-state
semantics defeat ownership-based ordering — DRust degenerates gracefully
(one-sided RDMA atomics for the mutex + single object fetch), GAM pays
two-sided synchronization, Grappa serializes every hot key on its home
core (the skew collapse in Fig. 5d, and the dip every system takes when
going from one to two nodes).

The bucket mutex guards only the chain walk (as in Memcached); value
processing happens outside the lock.  Workload: 90% GET / 10% SET over
zipf(0.99) keys (YCSB defaults).

``prefetch_window=W`` (drust only) speculatively fetches the value nodes
of the next W queued keys before taking the bucket lock — the fetch
overlaps the chain walk, and the value deref pays only a deferred
completion fence (``late_fences``).  Unlike GEMM's immutable tiles, SETs
race the lookahead: a write landing on a prefetched-but-unused node
invalidates its speculative copy (``wasted_prefetches``) — the
ownership-transfer visibility rule is what keeps the speculation safe.
"""

from __future__ import annotations

import numpy as np

from repro.core import DMutex
from .common import AppResult, make_cluster, spread_threads, zipf_keys

CYCLES_PER_BYTE = 48.15
SIMD_LANES = 8                   # value memcmp/copy vectorizes


def run_kvstore(n_servers: int, backend: str = "drust",
                n_keys: int = 4096, value_bytes: int = 1024,
                n_ops: int = 3000, get_frac: float = 0.9,
                workers_per_server: int = 4, cores: int = 16,
                nodes_per_bucket: int = 2, prefetch_window: int = 0,
                seed: int = 0) -> AppResult:
    cl = make_cluster(n_servers, backend, cores)
    rng = np.random.default_rng(seed)
    boot = cl.main_thread(0)

    n_buckets = max(1, n_keys // nodes_per_bucket)
    buckets = []                     # bucket -> (mutex, [value handles])
    for b in range(n_buckets):
        mtx = DMutex(cl, boot, value=b, size=64)
        nodes = [cl.backend.alloc(boot, value_bytes, bytes(value_bytes),
                                  server=b % n_servers)
                 for _ in range(nodes_per_bucket)]
        buckets.append((mtx, nodes))

    boot.t_us = 0.0
    for s in cl.sim.servers:
        s.cpu_busy_us = 0.0

    ths = spread_threads(cl, workers_per_server)
    keys = zipf_keys(n_ops, n_keys, seed=seed)
    is_get = rng.random(n_ops) < get_frac
    value_cycles = CYCLES_PER_BYTE * value_bytes / SIMD_LANES

    for i in range(n_ops):
        th = ths[i % len(ths)]
        key = int(keys[i])
        b, j = divmod(key, nodes_per_bucket)
        mtx, nodes = buckets[b]

        ahead = []
        if prefetch_window:
            # Lookahead: this worker's next queued keys — fetches overlap
            # the lock walk; a SET racing the window wastes its prefetch.
            for i2 in range(i + len(ths), i + len(ths) * (prefetch_window + 1),
                            len(ths)):
                if i2 >= n_ops:
                    break
                b2, j2 = divmod(int(keys[i2]), nodes_per_bucket)
                ahead.append(buckets[b2][1][j2])

        # One region per request: the lookahead is an entry hint, the lock
        # walk + value access are the scope.
        with cl.region(th, prefetch=ahead):
            # Lock guards the chain walk only (hash + j pointer hops).
            def chain_walk(_obj, th=th, j=j):
                for _ in range(j + 1):
                    cl.sim.local_access(th)
                return None
            mtx.with_lock(th, chain_walk)

            # Value access outside the lock (SWMR per key).
            with nodes[j].read(th):
                cl.sim.compute(th, value_cycles)
            if not is_get[i]:
                with nodes[j].write(th) as w:
                    w.set(bytes(value_bytes))

    return AppResult("kvstore", backend, n_servers, n_ops, cl.makespan_us(),
                     net=cl.sim.snapshot()["net"],
                     extra={"prefetch_window": prefetch_window})


def plain_kvstore_us(n_ops: int = 3000, value_bytes: int = 1024,
                     workers_per_server: int = 4,
                     nodes_per_bucket: int = 2) -> float:
    per_op = (CYCLES_PER_BYTE * value_bytes / SIMD_LANES / 2.6e3
              + (nodes_per_bucket / 2 + 3) * 0.14)       # chase + lock + read
    return n_ops * per_op / workers_per_server
