"""KV Store (§7.1): chained-hash in-memory cache under YCSB zipf load.

The paper's most DSM-unfriendly app: poor locality, low compute intensity
(~48 cycles/byte), and mutex-synchronized buckets whose shared-state
semantics defeat ownership-based ordering — DRust degenerates gracefully
(one-sided RDMA atomics for the mutex + single object fetch), GAM pays
two-sided synchronization, Grappa serializes every hot key on its home
core (the skew collapse in Fig. 5d, and the dip every system takes when
going from one to two nodes).

Each bucket's mutex is homed on the bucket's server (co-located with its
value nodes) and guards only the chain walk (as in Memcached); value
processing happens outside the lock.  Workload: 90% GET / 10% SET over
zipf(0.99) keys (YCSB defaults).  ``lock_mode="delegate"`` ships the
chain walks to the bucket homes as combining-lock convoys instead of
spinning (see ``core/sync.py`` and ``docs/sync.md``).

``txn_frac=f`` turns that fraction of ops into **multi-key transactions**:
each atomically updates 2–4 keys under sorted bucket-lock acquisition
(deadlock-free by global lock order), walking each chain and writing each
value *while holding the locks*.  SET/transaction payloads are
deterministic functions of (key, op index), so the final store contents
digest (``extra["digest"]``) is byte-identical across backends and
completion planes — the transactional correctness oracle.

``prefetch_window=W`` (drust only) speculatively fetches the value nodes
of the next W queued keys before taking the bucket lock — the fetch
overlaps the chain walk, and the value deref pays only a deferred
completion fence (``late_fences``).  Unlike GEMM's immutable tiles, SETs
race the lookahead: a write landing on a prefetched-but-unused node
invalidates its speculative copy (``wasted_prefetches``) — the
ownership-transfer visibility rule is what keeps the speculation safe.
"""

from __future__ import annotations

import hashlib

import numpy as np

from repro.core import DMutex
from .common import AppResult, make_cluster, spread_threads, zipf_keys

CYCLES_PER_BYTE = 48.15
SIMD_LANES = 8                   # value memcmp/copy vectorizes


def _val(key: int, i: int, value_bytes: int) -> bytes:
    """Deterministic SET payload: a (key, op-index) tag padded to size —
    what makes the cross-backend digest a meaningful oracle."""
    tag = b"k%d:i%d" % (key, i)
    return tag.ljust(value_bytes, b"\0")[:value_bytes]


def _peek(cl, h) -> bytes:
    """Oracle-only heap peek (no verbs): the node's current payload."""
    import repro.core.addr as A
    raw = A.clear_color(h.g) if hasattr(h, "g") else h.raw
    return bytes(cl.heap.get(raw).data)


def run_kvstore(n_servers: int, backend: str = "drust",
                n_keys: int = 4096, value_bytes: int = 1024,
                n_ops: int = 3000, get_frac: float = 0.9,
                workers_per_server: int = 4, cores: int = 16,
                nodes_per_bucket: int = 2, prefetch_window: int = 0,
                lock_mode: str = "spin", txn_frac: float = 0.0,
                seed: int = 0, **cluster_kw) -> AppResult:
    cl = make_cluster(n_servers, backend, cores, **cluster_kw)
    rng = np.random.default_rng(seed)
    boot = cl.main_thread(0)

    # Ceiling division: every key's bucket (key // nodes_per_bucket) must
    # exist even when nodes_per_bucket does not divide n_keys — floor
    # division under-allocated and the tail keys raised IndexError.
    n_buckets = max(1, -(-n_keys // nodes_per_bucket))
    buckets = []                     # bucket -> (mutex, [value handles])
    for b in range(n_buckets):
        mtx = DMutex(cl, boot, value=b, size=64, mode=lock_mode,
                     server=b % n_servers)
        nodes = [cl.backend.alloc(boot, value_bytes, bytes(value_bytes),
                                  server=b % n_servers)
                 for _ in range(nodes_per_bucket)]
        buckets.append((mtx, nodes))

    boot.t_us = 0.0
    for s in cl.sim.servers:
        s.cpu_busy_us = 0.0

    ths = spread_threads(cl, workers_per_server)
    keys = zipf_keys(n_ops, n_keys, seed=seed)
    is_get = rng.random(n_ops) < get_frac
    # Transactional mix: drawn after is_get so txn_frac=0 replays the
    # exact legacy op stream.
    is_txn = rng.random(n_ops) < txn_frac
    txn_extra = rng.integers(0, n_keys, size=(n_ops, 3))
    txn_nkeys = rng.integers(2, 5, size=n_ops)
    value_cycles = CYCLES_PER_BYTE * value_bytes / SIMD_LANES
    txn_ops = 0

    for i in range(n_ops):
        th = ths[i % len(ths)]
        key = int(keys[i])
        b, j = divmod(key, nodes_per_bucket)
        mtx, nodes = buckets[b]

        if is_txn[i]:
            # Multi-key atomic update: 2-4 distinct keys, locks taken in
            # global bucket order (deadlock-free), chains walked and
            # values written while ALL locks are held, released in
            # reverse order.  This is the workload that convoys on a
            # single-home lock design — and what delegation/distributed
            # homes unlock.
            txn_ops += 1
            tkeys = {key}
            for x in txn_extra[i][:int(txn_nkeys[i]) - 1]:
                tkeys.add(int(x))
            targets: dict[int, list[int]] = {}
            for k in sorted(tkeys):
                tb, tj = divmod(k, nodes_per_bucket)
                targets.setdefault(tb, []).append(tj)
            order = sorted(targets)
            held = []
            try:
                for tb in order:
                    buckets[tb][0].lock(th)
                    held.append(buckets[tb][0])
                for tb in order:
                    tmtx, tnodes = buckets[tb]
                    for tj in targets[tb]:
                        tmtx.charge_section(th, reads=tj + 1)  # chain walk
                        with tnodes[tj].write(th) as w:
                            w.set(_val(tb * nodes_per_bucket + tj, i,
                                       value_bytes))
            finally:
                for m in reversed(held):
                    m.unlock(th)
            continue

        ahead = []
        if prefetch_window:
            # Lookahead: this worker's next queued keys — fetches overlap
            # the lock walk; a SET racing the window wastes its prefetch.
            for i2 in range(i + len(ths), i + len(ths) * (prefetch_window + 1),
                            len(ths)):
                if i2 >= n_ops:
                    break
                b2, j2 = divmod(int(keys[i2]), nodes_per_bucket)
                ahead.append(buckets[b2][1][j2])

        # One region per request: the lookahead is an entry hint, the lock
        # walk + value access are the scope.
        with cl.region(th, prefetch=ahead):
            if lock_mode == "delegate":
                # The walk ships to the bucket home with the lock closure:
                # hash + j pointer hops run as local accesses there.
                mtx.with_lock(th, lambda _o: None, reads=j + 1)
            else:
                # Spin: lock remotely, walk the chain at the caller (the
                # per-hop summaries ride back in the acquire's cache line).
                def chain_walk(_obj, th=th, j=j):
                    for _ in range(j + 1):
                        cl.sim.local_access(th)
                    return None
                mtx.with_lock(th, chain_walk)

            # Value access outside the lock (SWMR per key).
            with nodes[j].read(th):
                cl.sim.compute(th, value_cycles)
            if not is_get[i]:
                with nodes[j].write(th) as w:
                    w.set(_val(key, i, value_bytes))

    makespan = cl.makespan_us()
    # Content digest over the final store, in key order — the cross-
    # backend / cross-plane transactional oracle (oracle-only peek, after
    # the makespan so it cannot perturb the run).
    dig = hashlib.sha256()
    for b, (_m, nodes) in enumerate(buckets):
        for j, h in enumerate(nodes):
            dig.update(b"%d:%d:" % (b, j))
            dig.update(_peek(cl, h))
    return AppResult("kvstore", backend, n_servers, n_ops, makespan,
                     net=cl.sim.snapshot()["net"],
                     extra={"prefetch_window": prefetch_window,
                            "lock_mode": lock_mode, "txn_ops": txn_ops,
                            "digest": dig.hexdigest()})


def plain_kvstore_us(n_ops: int = 3000, value_bytes: int = 1024,
                     workers_per_server: int = 4,
                     nodes_per_bucket: int = 2) -> float:
    per_op = (CYCLES_PER_BYTE * value_bytes / SIMD_LANES / 2.6e3
              + (nodes_per_bucket / 2 + 3) * 0.14)       # chase + lock + read
    return n_ops * per_op / workers_per_server
