"""The paper's four evaluation applications (§7.1), backend-agnostic.

Each app runs unmodified against the three protocol backends
(drust | gam | grappa) on a simulated cluster, plus a ``plain`` analytic
baseline = the original single-machine program (compute + local accesses,
no DSM instrumentation).  Throughputs are reported in ops/virtual-second,
normalized exactly like the paper's Fig. 5.
"""

from .common import AppResult, plain_time_us, zipf_keys
from .gemm import run_gemm
from .dataframe import run_dataframe
from .kvstore import run_kvstore
from .socialnet import run_socialnet

APPS = {
    "gemm": run_gemm,
    "dataframe": run_dataframe,
    "kvstore": run_kvstore,
    "socialnet": run_socialnet,
}

__all__ = ["APPS", "AppResult", "plain_time_us", "run_dataframe", "run_gemm",
           "run_kvstore", "run_socialnet", "zipf_keys"]
