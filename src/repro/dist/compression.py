"""Wire / checkpoint compression: symmetric int8 quantization.

``quantize_int8`` maps a float tensor to (int8 codes, f32 scale) with
absolute error bounded by ``scale / 2`` — the bound the error-feedback
trick relies on: carrying the residual into the next quantization keeps
the accumulated bias below one quantization step instead of growing with
the step count.  4x fewer bytes on the wire (gradients, weight refresh)
and in checkpoints.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_TINY = 1e-30


def _is_packed(x) -> bool:
    """A leaf produced by ``quantize_tree``."""
    return isinstance(x, dict) and set(x) == {"q", "scale", "dtype"}


def quantize_int8(x, axis=None):
    """Quantize to int8 with a symmetric scale.

    ``axis=None`` uses one scale per tensor; an int/tuple keeps a scale
    per remaining dim (channel-wise, tighter error for skewed tensors).
    Returns ``(codes int8, scale f32)`` with ``|x - codes*scale| <= scale/2``.
    """
    xf = jnp.asarray(x).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf)) if axis is None \
        else jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, _TINY) / 127.0
    codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def dequantize_int8(codes, scale):
    return codes.astype(jnp.float32) * scale


def quantize_tree(tree, min_size: int = 64):
    """Quantize every float leaf with ``size >= min_size``; small leaves
    (norms, scalars) stay exact.  Returns a pytree of
    ``{"q": int8, "scale": f32}`` dicts / passthrough leaves."""
    def one(leaf):
        arr = jnp.asarray(leaf)
        if arr.size < min_size or not jnp.issubdtype(arr.dtype, jnp.floating):
            return arr
        q, s = quantize_int8(arr)
        return {"q": q, "scale": s, "dtype": str(arr.dtype)}
    return jax.tree.map(one, tree)


def dequantize_tree(tree):
    def one(leaf):
        if _is_packed(leaf):
            return dequantize_int8(leaf["q"], leaf["scale"]).astype(
                jnp.dtype(leaf["dtype"]))
        return leaf
    return jax.tree.map(one, tree, is_leaf=_is_packed)


def wire_bytes(tree) -> int:
    """Bytes a (possibly quantized) pytree occupies on the wire."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=_is_packed):
        if _is_packed(leaf):
            total += leaf["q"].size + leaf["scale"].size * 4
        else:
            arr = jnp.asarray(leaf)
            total += arr.size * arr.dtype.itemsize
    return total
