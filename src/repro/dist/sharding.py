"""Sharding rules: the partition map between logical tensors and the mesh.

The DSM core gives every object one logical address and a per-server
partition of the physical backing (GlobalHeap); this module is the same
contract for the JAX stack.  Every spec produced here goes through
``_fit``, which drops any mesh axis that does not evenly divide the
corresponding tensor dimension — so one rule table serves every
architecture and every mesh shape, degrading gracefully to replication
instead of failing to partition (ownership can always fall back to a
single owner; it can never be ambiguous).

Layout contract (see ``models/layers.py``):
  * attention projections:  wq (D, H, hd)   wk/wv (D, Hkv, hd)   wo (H, hd, D)
  * MLP:                    w_gate/w_up (D, F)   w_down (F, D)
  * MoE experts:            (E, D, F) / (E, F, D), expert dim over ``model``
  * scan-stacked trees carry a leading layer-group dim — rules match the
    *trailing* dims, so stacked and unrolled trees share one table.

Rule flags (process-wide, like the mesh registry):
  * ``dp_only``       — pure ZeRO-3: every leaf FSDP-sharded along its first
                        dividing dim over *all* mesh axes; batch over all axes.
  * ``serve_weights`` — TP-only weights (no FSDP data axes): serving has no
                        optimizer state to amortize, and re-gathering weights
                        per token dominates decode collectives.
  * ``ulysses``       — inputs arrive sequence-sharded over ``model``; the
                        attention all-to-all (``ulysses_heads``) re-shards
                        seq<->heads around the score computation.
"""

from __future__ import annotations

import re

import jax
from jax.sharding import PartitionSpec as P
from jax.tree_util import tree_flatten_with_path, tree_unflatten

# ---------------------------------------------------------------------------
#  mesh + rule-flag registry
# ---------------------------------------------------------------------------
_MESH = None
_FLAGS = {"ulysses": False, "dp_only": False, "serve_weights": False}


def set_mesh(mesh):
    """Install (or clear, with ``None``) the process-wide mesh."""
    global _MESH
    _MESH = mesh
    return mesh


def current_mesh():
    return _MESH


def set_rule_flags(**flags):
    """Update rule flags; unknown keys are rejected."""
    for k, v in flags.items():
        if k not in _FLAGS:
            raise ValueError(f"unknown rule flag {k!r}")
        _FLAGS[k] = bool(v)
    return dict(_FLAGS)


def rule_flags() -> dict:
    return dict(_FLAGS)


# ---------------------------------------------------------------------------
#  divisor fitting
# ---------------------------------------------------------------------------
def _axis_size(mesh, axes) -> int | None:
    """Product of the named axes' sizes; None if any axis is absent."""
    n = 1
    for a in axes if isinstance(axes, tuple) else (axes,):
        sz = dict(mesh.shape).get(a)
        if sz is None:
            return None
        n *= sz
    return n


def _fit(mesh, spec, shape) -> P:
    """Fit ``spec`` to ``shape``: drop every axis that does not divide.

    Tuple entries keep their longest dividing *prefix* (partial sharding
    beats replication); plain entries are kept or dropped whole.  A spec
    longer than the shape is truncated; shorter is padded with None.
    """
    entries = tuple(spec)[:len(shape)]
    entries = entries + (None,) * (len(shape) - len(entries))
    out = []
    for dim, axes in zip(shape, entries):
        if axes is None:
            out.append(None)
        elif isinstance(axes, tuple):
            kept = axes
            while kept:
                n = _axis_size(mesh, kept)
                if n is not None and dim % n == 0:
                    break
                kept = kept[:-1]
            out.append(kept if kept else None)
        else:
            n = _axis_size(mesh, axes)
            out.append(axes if n is not None and dim % n == 0 else None)
    return P(*out)


def _pod_data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in dict(mesh.shape))


def _dp_axes(mesh) -> tuple:
    """Axes that carry the batch: (pod, data) normally, every axis under
    dp_only, nothing for weight specs under serve_weights (see callers)."""
    if _FLAGS["dp_only"]:
        return tuple(dict(mesh.shape))
    return _pod_data_axes(mesh)


# ---------------------------------------------------------------------------
#  parameter rules
# ---------------------------------------------------------------------------
# (path regex, trailing-dim tokens).  First match wins; tokens are
# "dp" (FSDP axes), "model" (TP axis), or None (replicated).  Rules name
# only the trailing dims — scan stacking pads None on the left.
_PARAM_RULES: tuple[tuple[str, tuple], ...] = (
    # attention projections
    (r"attn/wq$",            ("dp", "model", None)),
    (r"attn/(wk|wv)$",       ("dp", "model", None)),
    (r"attn/wo$",            ("model", None, "dp")),
    # dense MLP
    (r"mlp/(w_gate|w_up)$",  ("dp", "model")),
    (r"mlp/w_down$",         ("model", "dp")),
    # MoE: expert dim over model (expert parallelism), D FSDP-sharded
    (r"moe/(w_gate|w_up)$",  ("model", "dp", None)),
    (r"moe/w_down$",         ("model", None, "dp")),
    (r"moe/router$",         (None, "model")),
    # RG-LRU recurrent block
    (r"rec/(w_in|w_gate)$",  ("dp", "model")),
    (r"rec/(wa|wx)$",        ("dp", "model")),
    (r"rec/w_out$",          ("model", "dp")),
    (r"rec/conv$",           (None, "model")),
    # RWKV time-mix / channel-mix (flat under the layer dict)
    (r"(wr|wk|wv|wg|wo|cr)$", ("dp", "model")),
    (r"ck$",                 ("dp", "model")),
    (r"cv$",                 ("model", "dp")),
    # embeddings / head
    (r"embed$",              ("model", "dp")),
    (r"lm_head$",            ("dp", "model")),
)
_COMPILED_RULES = tuple((re.compile(rx), spec) for rx, spec in _PARAM_RULES)


def _path_str(path) -> str:
    toks = []
    for k in path:
        if hasattr(k, "key"):
            toks.append(str(k.key))
        elif hasattr(k, "idx"):
            toks.append(str(k.idx))
        else:                                           # pragma: no cover
            toks.append(str(k))
    return "/".join(toks)


def _resolve(token, mesh):
    if token == "dp":
        if _FLAGS["serve_weights"]:
            return None
        return _pod_data_axes(mesh) or None
    if token == "model":
        return "model"
    return token


def _zero3_spec(mesh, shape) -> P:
    """dp_only: FSDP-shard the dim covering the most mesh axes (longest
    dividing prefix of the full axis tuple); earliest dim wins ties."""
    axes = tuple(dict(mesh.shape))
    best = None                                  # (coverage, dim, kept)
    for i, dim in enumerate(shape):
        kept = axes
        while kept:
            n = _axis_size(mesh, kept)
            if n is not None and dim % n == 0:
                break
            kept = kept[:-1]
        if kept:
            cov = _axis_size(mesh, kept)
            if best is None or cov > best[0]:
                best = (cov, i, kept)
    entries = [None] * len(shape)
    if best is not None:
        entries[best[1]] = best[2]
    return P(*entries)


def param_specs(mesh, params):
    """PartitionSpec pytree mirroring ``params`` (abstract or concrete)."""
    flat, treedef = tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        shape = leaf.shape
        if _FLAGS["dp_only"]:
            specs.append(_zero3_spec(mesh, shape))
            continue
        name = _path_str(path)
        for rx, tokens in _COMPILED_RULES:
            if rx.search(name):
                resolved = tuple(_resolve(t, mesh) for t in tokens)
                resolved = resolved[-len(shape):] if shape else ()
                full = (None,) * (len(shape) - len(resolved)) + resolved
                specs.append(_fit(mesh, P(*full), shape))
                break
        else:
            specs.append(P(*([None] * len(shape))))
    return tree_unflatten(treedef, specs)


def opt_state_specs(mesh, opt_state, params):
    """Moments are TBox-tied to their parameters: each moment leaf inherits
    the parameter's spec, re-fitted to its own shape (Adafactor's collapsed
    dims fall back to replication along that dim)."""
    pspecs = param_specs(mesh, params)

    def tied(subtree):
        return jax.tree.map(lambda leaf, s: _fit(mesh, s, leaf.shape),
                            subtree, pspecs)

    return {k: (tied(v) if isinstance(v, dict) else P())
            for k, v in opt_state.items()}


# ---------------------------------------------------------------------------
#  data / activation / cache specs
# ---------------------------------------------------------------------------
def batch_specs(mesh, batch):
    """Inputs: batch dim over the dp axes; under the ulysses flag the
    sequence dim is additionally sharded over ``model`` (the attention
    all-to-all re-shards it to heads)."""
    dp = _dp_axes(mesh)
    seq = "model" if (_FLAGS["ulysses"] and not _FLAGS["dp_only"]) else None

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        entries = (dp or None,) + (seq,) * (1 if nd > 1 else 0) \
            + (None,) * max(0, nd - 2)
        return _fit(mesh, P(*entries), leaf.shape)

    return jax.tree.map(one, batch)


def activation_spec(mesh, shape) -> P:
    """(B, T, D) residual-stream layout: batch over dp, sequence over
    ``model`` (Megatron-style sequence parallelism).  dp_only drops the
    sequence sharding (pure data parallel)."""
    dp = _dp_axes(mesh)
    if len(shape) == 0:
        return P()
    if _FLAGS["dp_only"]:
        entries = (dp or None,) + (None,) * (len(shape) - 1)
    else:
        entries = (dp or None,) \
            + (("model",) if len(shape) > 1 else ()) \
            + (None,) * max(0, len(shape) - 2)
    return _fit(mesh, P(*entries), shape)


def shard_act(x, mesh=None):
    """Constrain an activation to the canonical layout (no-op off-mesh)."""
    mesh = mesh if mesh is not None else _MESH
    if mesh is None:
        return x
    spec = activation_spec(mesh, x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def ulysses_heads(x, mesh=None):
    """Ulysses sequence parallelism: re-shard (B, T, H, hd) from
    sequence-over-model to heads-over-model.  XLA lowers the constraint
    flip to a single all-to-all; identity when no mesh is installed."""
    mesh = mesh if mesh is not None else _MESH
    if mesh is None or "model" not in dict(mesh.shape):
        return x
    dp = _pod_data_axes(mesh)
    spec = _fit(mesh, P(dp or None, None, "model", None), x.shape)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def cache_specs(mesh, cache):
    """KV / recurrent-state caches: batch over dp; 4-D leaves (attention
    k/v (B, S, Hkv, hd), rwkv S (B, H, M, M)) shard dim 1 over ``model``
    so decode attention can keep every cache shard local."""
    dp = _dp_axes(mesh)
    # dp_only already spreads the batch over `model`; reusing it on the
    # sequence dim would duplicate the axis in one spec
    seq = None if _FLAGS["dp_only"] else "model"

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if nd >= 4:
            entries = (dp or None, seq) + (None,) * (nd - 2)
        elif nd >= 2:
            entries = (dp or None,) + (None,) * (nd - 1)
        else:
            entries = (None,)
        return _fit(mesh, P(*entries), leaf.shape)

    return jax.tree.map(one, cache)
