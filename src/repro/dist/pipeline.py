"""Pipeline parallelism: GPipe-style microbatch scheduling over a mesh axis.

``pipeline_apply`` runs a stage function over stage-stacked parameters
(leading dim = number of stages) with the stages laid out along one mesh
axis.  Each schedule step every stage computes one microbatch and ships
its activation to the next stage with a single collective-permute — the
ML-stack analogue of the DSM channel: ownership of the activation moves,
the bytes cross the wire exactly once, and no coherence traffic follows.

Schedule shape (S stages, M microbatches): ``M + S - 1`` steps; the
pipeline "bubble" is the ``S * (S - 1)`` idle stage-steps at fill/drain,
i.e. a fraction ``(S - 1) / (M + S - 1)`` of every stage's time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def schedule_steps(n_stages: int, n_microbatches: int) -> int:
    """Total schedule steps for a GPipe fill-steady-drain schedule."""
    return n_microbatches + n_stages - 1


def bubble_stage_steps(n_stages: int, n_microbatches: int) -> int:
    """Idle (stage, step) slots: S * (M + S - 1) total minus S * M useful."""
    return n_stages * schedule_steps(n_stages, n_microbatches) \
        - n_stages * n_microbatches


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """Fraction of stage-time lost to fill/drain: (S - 1) / (M + S - 1)."""
    return bubble_stage_steps(n_stages, n_microbatches) / (
        n_stages * schedule_steps(n_stages, n_microbatches))


def _pick_axis(mesh, n_stages: int, axis_name: str | None) -> str:
    if axis_name is not None:
        return axis_name
    shape = dict(mesh.shape)
    if shape.get("pod") == n_stages:
        return "pod"
    for a, n in shape.items():
        if n == n_stages:
            return a
    raise ValueError(
        f"no mesh axis of size {n_stages} for the stage dim: {shape}")


def pipeline_apply(fn, mesh, stage_params, x, n_microbatches: int = 1,
                   axis_name: str | None = None):
    """Apply ``fn(stage_param, x) -> y`` sequentially over stacked stages.

    * ``stage_params``: pytree whose leaves carry a leading stage dim S;
      stage ``i`` runs on mesh rank ``i`` of the pipeline axis.
    * ``x``: global batch, split into ``n_microbatches`` along dim 0.
    * ``fn`` must preserve the activation shape/dtype (its output feeds
      the next stage's input).

    Returns the final-stage output for the whole batch, replicated.
    """
    leaves = jax.tree.leaves(stage_params)
    if not leaves:
        raise ValueError("stage_params has no leaves")
    S = leaves[0].shape[0]
    axis = _pick_axis(mesh, S, axis_name)
    if dict(mesh.shape)[axis] != S:
        raise ValueError(
            f"stage dim {S} != mesh axis {axis!r}={dict(mesh.shape)[axis]}")
    B = x.shape[0]
    M = int(n_microbatches)
    if M < 1 or B % M:
        raise ValueError(f"batch {B} not divisible into {M} microbatches")
    mb = B // M
    xm = x.reshape(M, mb, *x.shape[1:])
    perm = [(i, (i + 1) % S) for i in range(S)]

    def run(p_loc, xm_loc):
        stage = jax.lax.axis_index(axis)
        p_stage = jax.tree.map(lambda l: l[0], p_loc)

        def step(t, carry):
            inp, outs = carry
            # stage 0 consumes microbatch t; the rest consume the activation
            # the previous stage shipped at the end of step t-1
            feed = xm_loc[jnp.clip(t, 0, M - 1)]
            y = fn(p_stage, jnp.where(stage == 0, feed, inp))
            # the last stage completes microbatch t-(S-1) once the fill ends
            o_idx = jnp.clip(t - (S - 1), 0, M - 1)
            done = jnp.logical_and(stage == S - 1, t >= S - 1)
            outs = jnp.where(done, outs.at[o_idx].set(y), outs)
            return jax.lax.ppermute(y, axis, perm), outs

        init = (jnp.zeros_like(xm_loc[0]), jnp.zeros_like(xm_loc))
        _, outs = jax.lax.fori_loop(0, schedule_steps(S, M), step, init)
        # only the last stage holds results; psum broadcasts them
        return jax.lax.psum(outs, axis)

    y = shard_map(run, mesh=mesh, in_specs=(P(axis), P()), out_specs=P(),
                  check_rep=False)(stage_params, xm)
    return y.reshape(B, *y.shape[2:])
