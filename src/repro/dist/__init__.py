"""Distributed execution rules for the JAX side of the reproduction.

This package is the ML-stack analogue of the DSM core's "global heap +
per-server sharded ownership" (DESIGN §2.2): a single *logical* view of
every tensor (the PGAS address space) plus a per-mesh partition map that
says which server owns which shard.  Three submodules:

* ``sharding``    — the partition map: mesh registry, name-based parameter
                    rules, batch/cache/activation specs, divisor fitting.
* ``pipeline``    — GPipe-style microbatch scheduling of a stage-stacked
                    function over a mesh axis.
* ``compression`` — int8 wire/checkpoint compression with error bounds
                    compatible with error-feedback accumulation.
"""

from . import compression, pipeline, sharding

__all__ = ["compression", "pipeline", "sharding"]
