"""RWKV6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent decay, plus squared-ReLU channel-mix.

The WKV6 recurrence per head (state S: hd×hd):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

is evaluated in *chunks* (matmul-dense, MXU-friendly — the same dataflow the
Pallas kernel uses): within a chunk all pairwise decay products are expressed
relative to the chunk start so every exponent is ≤ 0 (no overflow).  The
data-dependent decay is LoRA-produced as in Finch; its per-step magnitude is
bounded (|log w| ≤ 0.105) so that cross-chunk ratios stay in f32 range — a
kernel-stability re-parameterization, noted in DESIGN.md.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rms_norm

CHUNK = 64
LORA_R = 32
DECAY_SCALE = 0.105


def rwkv_params(cfg: ModelConfig, key, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    M = cfg.rwkv_head_dim
    H = d // M
    ks = jax.random.split(key, 12)
    s = d ** -0.5
    return {
        # time-mix
        "mu": jax.random.normal(ks[0], (5, d), dtype) * 0.02,   # r,k,v,w,g shifts
        "wr": jax.random.normal(ks[1], (d, d), dtype) * s,
        "wk": jax.random.normal(ks[2], (d, d), dtype) * s,
        "wv": jax.random.normal(ks[3], (d, d), dtype) * s,
        "wg": jax.random.normal(ks[4], (d, d), dtype) * s,
        "wo": jax.random.normal(ks[5], (d, d), dtype) * s,
        "w0": jax.random.normal(ks[6], (d,), jnp.float32) * 0.5,
        "w_lora_a": jax.random.normal(ks[7], (d, LORA_R), dtype) * s,
        "w_lora_b": jax.random.normal(ks[8], (LORA_R, d), dtype) * LORA_R ** -0.5,
        "u": jax.random.normal(ks[9], (H, M), jnp.float32) * 0.1,
        "ln_x": jnp.zeros((d,), dtype),
        # channel-mix
        "mu_c": jax.random.normal(ks[10], (2, d), dtype) * 0.02,
        "ck": jax.random.normal(ks[11], (d, ff), dtype) * s,
        "cv": jax.random.normal(jax.random.fold_in(key, 99), (ff, d), dtype)
              * ff ** -0.5,
        "cr": jax.random.normal(jax.random.fold_in(key, 98), (d, d), dtype) * s,
    }


def _token_shift(x, last):
    """shift(x)_t = x_{t-1}; position 0 takes `last` (B, D) from the cache."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _wkv_chunk(r, k, v, logw, u, S0):
    """One chunk of the WKV6 recurrence, matmul form.

    r,k,v: (B,H,C,M)  logw: (B,H,C,M) (≤0)  u: (H,M)  S0: (B,H,M,M)
    Returns (o: (B,H,C,M), S_next).
    """
    cs = jnp.cumsum(logw, axis=2)                       # logA_t, inclusive
    A = jnp.exp(cs)                                     # ≤ 1
    A_prev = jnp.exp(cs - logw)                         # logA_{t-1}
    A_tail = jnp.exp(cs[:, :, -1:, :] - cs)             # Π_{s>t} w_s ≤ 1

    q_in = r * A_prev                                   # decay from chunk start
    k_in = k * jnp.exp(-cs + cs[:, :, :1, :] - logw[:, :, :1, :])
    # k_in decays *backwards*: exponent = -(logA_s - logA_0) ≥ 0 but bounded
    # by C*DECAY_SCALE ≈ 6.7 → e^6.7 ≈ 800, f32-safe.

    C = r.shape[2]
    scores = jnp.einsum("bhtm,bhsm->bhts", q_in, k_in)
    mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
    scores = jnp.where(mask[None, None], scores, 0.0)
    diag = jnp.einsum("bhtm,hm,bhtm->bht", r, u, k)     # bonus term (s == t)
    o = jnp.einsum("bhts,bhsm->bhtm", scores, v) + diag[..., None] * v
    o = o + jnp.einsum("bhtm,bhmn->bhtn", q_in, S0)     # cross-chunk history

    k_tail = k * A_tail
    S_next = jnp.exp(cs[:, :, -1, :])[..., None] * S0 \
        + jnp.einsum("bhtm,bhtn->bhmn", k_tail, v)
    return o, S_next


def time_mix(cfg: ModelConfig, p, x, state):
    """x: (B,T,D). state: {"S": (B,H,M,M), "last": (B,D)} or None (training
    uses zeros).  Returns (out, new_state)."""
    B, T, D = x.shape
    M = cfg.rwkv_head_dim
    H = D // M
    if state is None:
        S = jnp.zeros((B, H, M, M), jnp.float32)
        last = jnp.zeros((B, D), x.dtype)
    else:
        S, last = state["S"], state["last"]

    prev = _token_shift(x, last)
    mix = x[None] + p["mu"][:, None, None, :] * (prev - x)[None]  # (5,B,T,D)
    xr, xk, xv, xw, xg = mix
    r = (xr @ p["wr"]).reshape(B, T, H, M).transpose(0, 2, 1, 3)
    k = (xk @ p["wk"]).reshape(B, T, H, M).transpose(0, 2, 1, 3)
    v = (xv @ p["wv"]).reshape(B, T, H, M).transpose(0, 2, 1, 3)
    g = jax.nn.silu(xg @ p["wg"])
    # Finch data-dependent decay, bounded for chunked stability
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    logw = -DECAY_SCALE * jax.nn.sigmoid(
        p["w0"][None, None, :] + lora.astype(jnp.float32))       # (B,T,D) ≤ 0
    logw = logw.reshape(B, T, H, M).transpose(0, 2, 1, 3)

    if T == 1:                          # decode fast path: plain recurrence
        r1 = r[:, :, 0].astype(jnp.float32)
        k1 = k[:, :, 0].astype(jnp.float32)
        v1 = v[:, :, 0].astype(jnp.float32)
        w1 = jnp.exp(logw[:, :, 0])
        kv = jnp.einsum("bhm,bhn->bhmn", k1, v1)
        o = jnp.einsum("bhm,bhmn->bhn", r1, S + p["u"][None, :, :, None] * kv)
        S = w1[..., None] * S + kv
        o = o.reshape(B, 1, D).astype(x.dtype)
        o = rms_norm(o, p["ln_x"], cfg.norm_eps) * g
        return o @ p["wo"], {"S": S, "last": x[:, -1, :]}

    Tpad = -(-T // CHUNK) * CHUNK
    if Tpad != T:
        pad = [(0, 0), (0, 0), (0, Tpad - T), (0, 0)]
        r, k, v = (jnp.pad(a, pad) for a in (r, k, v))
        logw = jnp.pad(logw, pad)
    nc = Tpad // CHUNK

    def step(S, xs):
        rc, kc, vc, wc = xs
        o, S2 = _wkv_chunk(rc.astype(jnp.float32), kc.astype(jnp.float32),
                           vc.astype(jnp.float32), wc, p["u"], S)
        return S2, o

    split = lambda a: a.reshape(B, H, nc, CHUNK, M).transpose(2, 0, 1, 3, 4)
    if cfg.unroll_chunks:            # flops-calibration path (no while loop)
        xs = (split(r), split(k), split(v), split(logw))
        os = []
        for c in range(nc):
            S, o_c = step(S, jax.tree.map(lambda a: a[c], xs))
            os.append(o_c)
        o = jnp.stack(os, axis=0)
    else:
        S, o = jax.lax.scan(step, S,
                            (split(r), split(k), split(v), split(logw)))
    o = o.transpose(1, 2, 0, 3, 4).reshape(B, H, Tpad, M)[:, :, :T]
    o = o.transpose(0, 2, 1, 3).reshape(B, T, D).astype(x.dtype)
    o = rms_norm(o, p["ln_x"], cfg.norm_eps) * g
    out = o @ p["wo"]
    new_state = {"S": S, "last": x[:, -1, :]}
    return out, new_state


def channel_mix(cfg: ModelConfig, p, x, state):
    """Squared-ReLU channel mix with token shift."""
    last = state["last_c"] if state is not None else jnp.zeros(
        (x.shape[0], x.shape[2]), x.dtype)
    prev = _token_shift(x, last)
    mix = x[None] + p["mu_c"][:, None, None, :] * (prev - x)[None]
    xk, xr = mix
    kk = jnp.square(jax.nn.relu(xk @ p["ck"]))
    out = jax.nn.sigmoid(xr @ p["cr"]) * (kk @ p["cv"])
    return out, {"last_c": x[:, -1, :]}
