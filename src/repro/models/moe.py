"""Mixture-of-Experts block: top-k token-choice routing with capacity,
expert-parallel over the `model` mesh axis.

Distribution (EP = the paper's spawn_to / compute-to-data, see DESIGN §2.2):
expert weights are sharded E over `model`; inside a shard_map the tokens
(replicated across model ranks by the enclosing partitioner) are processed
only by the rank owning the chosen expert, and partial outputs are psum'd.
XLA turns the boundary replication + psum into an all-gather/reduce-scatter
pair against the sequence-parallel residual stream.

Dispatch is sort-free: per local expert, take the top-C tokens by router
score (static shapes, capacity drop like GShard).  FLOPs are exactly
capacity_factor × active-expert compute — no dense-dispatch einsum waste.

``axis_name=None`` runs the same code on one device (tests / smoke).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def moe_params(cfg: ModelConfig, key, dtype):
    d, f, E = cfg.d_model, cfg.e_ff, cfg.n_experts
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "router": jax.random.normal(k1, (d, E), jnp.float32) * s,
        "w_gate": jax.random.normal(k2, (E, d, f), dtype) * s,
        "w_up": jax.random.normal(k3, (E, d, f), dtype) * s,
        "w_down": jax.random.normal(k4, (E, f, d), dtype) * f ** -0.5,
    }


def _capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = max(1, int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    c = -(-c // 4) * 4                                  # multiple of 4
    return min(n_tokens, c)


def moe_block(cfg: ModelConfig, p, x, *, axis_name: str | None = None,
              axis_size: int = 1):
    """x: (B, T, D) local tokens.  Returns (y, aux_loss)."""
    B, T, D = x.shape
    N = B * T
    E = cfg.n_experts
    xt = x.reshape(N, D)

    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, cfg.top_k)            # (N, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                      # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[top_ids.reshape(-1)].add(
        1.0 / (N * cfg.top_k))
    aux = E * jnp.sum(me * ce)

    # per-token score for each expert: router prob if chosen, else -inf
    assigned = jnp.full((N, E), -jnp.inf, jnp.float32)
    rows = jnp.arange(N)[:, None].repeat(cfg.top_k, 1).reshape(-1)
    assigned = assigned.at[rows, top_ids.reshape(-1)].set(top_p.reshape(-1))

    C = _capacity(cfg, N)
    E_loc = E // axis_size
    if axis_name is not None:
        rank = jax.lax.axis_index(axis_name)
        e0 = rank * E_loc
    else:
        e0 = 0

    def one_expert(carry, e_idx):
        y = carry
        e = e0 + e_idx
        score = assigned[:, e]                                   # (N,)
        g, idx = jax.lax.top_k(score, C)                         # top-C tokens
        keep = (g > -jnp.inf)
        gate = jnp.where(keep, g, 0.0).astype(x.dtype)           # (C,)
        xe = jnp.take(xt, idx, axis=0)                           # (C, D)
        wg = p["w_gate"][e_idx] if axis_name else p["w_gate"][e]
        wu = p["w_up"][e_idx] if axis_name else p["w_up"][e]
        wd = p["w_down"][e_idx] if axis_name else p["w_down"][e]
        h = jax.nn.silu(xe @ wg) * (xe @ wu)
        out = (h @ wd) * gate[:, None]                           # (C, D)
        y = y.at[idx].add(jnp.where(keep[:, None], out, 0.0))
        return y, None

    y0 = jnp.zeros_like(xt)
    if cfg.unroll_experts:           # flops-calibration path (no while loop)
        y = y0
        for e_idx in range(E_loc):
            y, _ = one_expert(y, jnp.int32(e_idx))
    else:
        y, _ = jax.lax.scan(one_expert, y0, jnp.arange(E_loc))
    if axis_name is not None:
        y = jax.lax.psum(y, axis_name)
    return y.reshape(B, T, D), aux


def moe_shardmap(cfg: ModelConfig, mesh, p, x):
    """Wrap the MoE in a shard_map over (data, model): tokens sharded over
    `data`, experts over `model`.

    Default dispatch replicates tokens across model ranks (gather) and
    psums partial outputs.  With ``cfg.moe_a2a`` that is replaced by true
    expert-parallel routing: each model rank keeps only its T-shard, ships
    its tokens' top-k copies to the owning ranks with an all-to-all,
    processes its local experts, and ships results back — wire bytes drop
    from (full-T gather + psum) to 2 x (tokens*k*cap/ranks) per device
    (the paper's spawn_to: computation moves to the data owner)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_model = mesh.shape["model"]

    pspec_p = {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }

    if cfg.moe_a2a and x.shape[1] % n_model == 0:
        def inner_a2a(p_loc, x_loc):
            y, aux = moe_a2a_block(cfg, p_loc, x_loc, n_model)
            return y, jax.lax.pmean(aux, data_axes + ("model",))

        pspec_x = P(data_axes, "model", None)       # keep the T-shard local
        return shard_map(inner_a2a, mesh=mesh,
                         in_specs=(pspec_p, pspec_x),
                         out_specs=(pspec_x, P()),
                         check_rep=False)(p, x)

    def inner(p_loc, x_loc):
        y, aux = moe_block(cfg, p_loc, x_loc, axis_name="model",
                           axis_size=mesh.shape["model"])
        return y, jax.lax.pmean(aux, data_axes + ("model",))

    pspec_x = P(data_axes, None, None)
    y, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(pspec_p, pspec_x),
        out_specs=(pspec_x, P()),
        check_rep=False,
    )(p, x)
    return y, aux


def moe_a2a_block(cfg: ModelConfig, p, x, n_model: int,
                  axis_name: str = "model"):
    """Expert-parallel MoE with all-to-all dispatch (inside shard_map).

    x: (B_loc, T_loc, D) — this rank's token shard; p holds the local
    expert slice (E_loc, D, F)."""
    B, T, D = x.shape
    N = B * T
    E = cfg.n_experts
    E_loc = E // n_model
    xt = x.reshape(N, D)

    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_ids.reshape(-1)].add(
        1.0 / (N * cfg.top_k))
    aux = E * jnp.sum(me * ce)

    # per-destination send buffers: top-C (token, expert) pairs per rank
    dest = top_ids // E_loc                                  # (N, K)
    C = max(4, -(-int(N * cfg.top_k * cfg.capacity_factor / n_model)
                 // 4) * 4)
    C = min(C, N * cfg.top_k)

    flat_tok = jnp.arange(N)[:, None].repeat(cfg.top_k, 1).reshape(-1)
    flat_exp = top_ids.reshape(-1)
    flat_gate = top_p.reshape(-1)
    flat_dest = dest.reshape(-1)

    send_x = jnp.zeros((n_model, C, D), x.dtype)
    send_tok = jnp.full((n_model, C), -1, jnp.int32)
    send_eloc = jnp.zeros((n_model, C), jnp.int32)
    send_gate = jnp.zeros((n_model, C), jnp.float32)
    for r in range(n_model):
        score = jnp.where(flat_dest == r, flat_gate, -jnp.inf)
        g, idx = jax.lax.top_k(score, C)
        keep = g > -jnp.inf
        send_x = send_x.at[r].set(
            jnp.where(keep[:, None], jnp.take(xt, flat_tok[idx], axis=0), 0))
        send_tok = send_tok.at[r].set(
            jnp.where(keep, flat_tok[idx], -1))
        send_eloc = send_eloc.at[r].set(flat_exp[idx] % E_loc)
        send_gate = send_gate.at[r].set(jnp.where(keep, g, 0.0))

    recv_x = jax.lax.all_to_all(send_x, axis_name, 0, 0)
    recv_tok = jax.lax.all_to_all(send_tok, axis_name, 0, 0)
    recv_eloc = jax.lax.all_to_all(send_eloc, axis_name, 0, 0)
    rx = recv_x.reshape(n_model * C, D)
    r_eloc = recv_eloc.reshape(-1)
    r_valid = recv_tok.reshape(-1) >= 0

    # process local experts over the received buffer
    out = jnp.zeros((n_model * C, D), x.dtype)
    for e in range(E_loc):
        sel = jnp.logical_and(r_valid, r_eloc == e)
        xe = jnp.where(sel[:, None], rx, 0)
        h = jax.nn.silu(xe @ p["w_gate"][e]) * (xe @ p["w_up"][e])
        out = out + jnp.where(sel[:, None], h @ p["w_down"][e], 0)

    back = jax.lax.all_to_all(out.reshape(n_model, C, D), axis_name, 0, 0)
    y = jnp.zeros((N, D), x.dtype)
    tok = jnp.maximum(send_tok, 0).reshape(-1)
    gate = jnp.where(send_tok >= 0, send_gate, 0.0).reshape(-1)
    y = y.at[tok].add(back.reshape(-1, D) * gate[:, None].astype(x.dtype))
    return y.reshape(B, T, D), aux
