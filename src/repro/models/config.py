"""Unified model configuration for every assigned architecture."""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"            # dense | moe | rwkv | rglru | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    act: str = "silu"                # silu | geglu (gated in both cases)
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # expert hidden dim (0 -> d_ff)
    dense_residual: bool = False     # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25

    # RWKV6
    rwkv_head_dim: int = 64

    # RG-LRU hybrid (RecurrentGemma)
    attn_every: int = 0              # 1 attention layer per `attn_every` layers
    window: int = 0                  # local attention window (0 -> global)
    lru_width: int = 0               # 0 -> d_model

    # modality frontend stubs
    prefix_len: int = 0              # precomputed patch/frame embeddings

    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 1024           # kv-block size for the chunked XLA path
    attn_impl: str = "xla"           # xla | pallas (pallas: TPU, interpret on CPU)
    max_target_len: int = 8192       # serving cache default
    unroll_chunks: bool = False      # rwkv: python loop (flops calibration)
    unroll_experts: bool = False     # moe: python loop (flops calibration)
    # ---- beyond-paper perf knobs (EXPERIMENTS §Perf) ----
    ulysses: bool = False            # all-to-all seq<->head resharding
    chunked_ce: int = 0              # CE loss in vocab-chunks (0 = off)
    decode_shard_s: bool = False     # shard_map decode attn (S stays local)
    moe_a2a: bool = False            # all-to-all token dispatch for EP
    serve_weights_tp_only: bool = False  # serving: no FSDP (no opt state to
                                         # amortize; re-gathering per token
                                         # dominates decode collectives)
    dp_only: bool = False            # pure ZeRO-3: batch over every mesh
                                     # axis, weights FSDP-sharded, no TP/SP

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def e_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def lru_d(self) -> int:
        return self.lru_width or self.d_model

    def smoke(self) -> "ModelConfig":
        """A reduced same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=min(self.n_layers, 2 if self.attn_every == 0 else 3),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads)),
            head_dim=32 if self.head_dim else 0,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            moe_d_ff=128 if self.n_experts else 0,
            window=min(self.window, 64) if self.window else 0,
            lru_width=128 if self.lru_width else 0,
            prefix_len=min(self.prefix_len, 8),
            attn_chunk=64,
            max_target_len=128,
        )

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        n = emb + d  # final norm
        for i in range(self.n_layers):
            if self.family == "rwkv":
                # time-mix: r,k,v,g,o projections + decay/lora params
                n += 5 * d * d + 2 * d + 6 * 2 * d * 32
                # channel-mix
                n += 2 * d * self.d_ff + d * d // 8
                n += 2 * d
                continue
            is_attn = self._is_attn_layer(i)
            if is_attn:
                n += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                    + self.n_heads * hd * d
            else:  # RG-LRU recurrent block
                dl = self.lru_d
                n += 2 * d * dl + dl * d + 2 * dl + 2 * dl * dl // 8
            if self.n_experts:
                n += d * self.n_experts                      # router
                n += self.n_experts * 3 * d * self.e_ff      # experts
                if self.dense_residual:
                    n += 3 * d * self.d_ff
            else:
                n += 3 * d * self.d_ff
            n += 2 * d                                        # norms
        return n

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        inactive = self.n_layers * (self.n_experts - self.top_k) \
            * 3 * self.d_model * self.e_ff
        return full - inactive

    def _is_attn_layer(self, i: int) -> bool:
        if self.family == "rwkv":
            return False
        if self.attn_every:
            return (i % self.attn_every) == (self.attn_every - 1)
        return True
