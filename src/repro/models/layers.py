"""Shared pure-JAX layers: RMSNorm, RoPE, GQA attention (chunked flash-style
for long sequences), gated MLP.

Sharding notes (the ``dist.sharding`` rules assume these layouts):
  * attention projections:  wq (D, H, hd)   wk/wv (D, Hkv, hd)   wo (H, hd, D)
  * MLP:                    w_gate/w_up (D, F)   w_down (F, D)
  * activations between blocks carry P(data, model, None) — batch sharded
    over `data`, sequence over `model` (Megatron-style sequence parallelism);
    XLA inserts the all-gather / reduce-scatter pairs at the block boundary.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig


def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta: float = 1e6):
    """x: (..., T, n, hd); positions: (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (jnp.log(theta) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs     # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _mask_bias(q_pos, k_pos, window: int):
    """Causal (+ optional sliding-window) additive bias."""
    causal = k_pos[None, :] <= q_pos[:, None]
    if window:
        causal &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(causal, 0.0, -1e30).astype(jnp.float32)


def attention(q, k, v, q_pos, k_pos, *, window: int = 0, chunk: int = 1024):
    """GQA attention.  q: (B,T,H,hd)  k,v: (B,S,Hkv,hd).

    Short sequences use one einsum; long sequences use an online-softmax scan
    over KV chunks (flash-style) so the score matrix never materializes.
    """
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = hd ** -0.5
    qg = q.reshape(B, T, Hkv, G, hd) * scale

    if S <= max(2 * chunk, 2048):
        scores = jnp.einsum("btkgh,bskh->bktgs", qg, k).astype(jnp.float32)
        bias = _mask_bias(q_pos, k_pos, window)                  # (T, S)
        scores = scores + bias[None, None, :, None, :]
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bktgs,bskh->btkgh", probs, v)
        return out.reshape(B, T, H, hd)

    # flash-style: scan over KV chunks with running (max, sum, acc)
    if S % chunk:                         # pad to a chunk multiple (masked)
        pad = chunk - S % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.concatenate(
            [k_pos, jnp.full((pad,), 1 << 30, k_pos.dtype)])   # future: masked
        S += pad
    n_chunks = S // chunk
    k_c = k.reshape(B, n_chunks, chunk, Hkv, hd)
    v_c = v.reshape(B, n_chunks, chunk, Hkv, hd)
    kpos_c = k_pos.reshape(n_chunks, chunk)

    def step(carry, xs):
        m, l, acc = carry
        kc, vc, kp = xs
        s = jnp.einsum("btkgh,bskh->bktgs", qg, kc).astype(jnp.float32)
        s = s + _mask_bias(q_pos, kp, window)[None, None, :, None, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bktgs,bskh->bktgh", p.astype(vc.dtype), vc).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, T, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, T, G), jnp.float32)
    a0 = jnp.zeros((B, Hkv, T, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (k_c.swapaxes(0, 1), v_c.swapaxes(0, 1), kpos_c))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.swapaxes(1, 2).reshape(B, T, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
#  Attention block (projections + rope + qk-norm + cache handling)
# ---------------------------------------------------------------------------
def attn_params(cfg: ModelConfig, key, dtype):
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (d, H, hd), dtype) * s),
        "wk": (jax.random.normal(k2, (d, Hkv, hd), dtype) * s),
        "wv": (jax.random.normal(k3, (d, Hkv, hd), dtype) * s),
        "wo": (jax.random.normal(k4, (H, hd, d), dtype) * (H * hd) ** -0.5),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def attn_block(cfg: ModelConfig, p, x, positions, *, cache=None,
               window: int = 0):
    """x: (B,T,D); positions: (T,) int32, shared across the batch.
    cache: dict(k/v: (B,S,Hkv,hd), length) for decode."""
    q = jnp.einsum("btd,dnh->btnh", x, p["wq"])
    k = jnp.einsum("btd,dnh->btnh", x, p["wk"])
    v = jnp.einsum("btd,dnh->btnh", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cfg.ulysses and cache is None:
        from repro.dist.sharding import ulysses_heads
        q, k, v = ulysses_heads(q), ulysses_heads(k), ulysses_heads(v)

    if cache is None:
        out = attention(q, k, v, positions, positions, window=window,
                        chunk=cfg.attn_chunk)
    else:
        # decode: append the new token's k/v at `length`, attend to the cache
        length = cache["length"]
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, length, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, length, 0, 0))
        cache = {"k": kc, "v": vc, "length": length + q.shape[1]}
        k_pos = jnp.arange(kc.shape[1])
        # entries beyond `length` are masked by the causal bias (q_pos=length)
        out = attention(q, kc, vc, positions, k_pos, window=window,
                        chunk=cfg.attn_chunk)
    y = jnp.einsum("btnh,nhd->btd", out, p["wo"])
    return y, cache


# ---------------------------------------------------------------------------
#  Gated MLP
# ---------------------------------------------------------------------------
def mlp_params(cfg: ModelConfig, key, dtype, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": jax.random.normal(k1, (d, f), dtype) * d ** -0.5,
        "w_up": jax.random.normal(k2, (d, f), dtype) * d ** -0.5,
        "w_down": jax.random.normal(k3, (f, d), dtype) * f ** -0.5,
    }


def mlp_block(cfg: ModelConfig, p, x):
    g = jnp.einsum("btd,df->btf", x, p["w_gate"])
    u = jnp.einsum("btd,df->btf", x, p["w_up"])
    if cfg.act == "geglu":
        h = jax.nn.gelu(g) * u
    else:
        h = jax.nn.silu(g) * u
    return jnp.einsum("btf,fd->btd", h, p["w_down"])
