"""Decoder assembly for every family: scan-over-layers (compile-time at
512 devices), per-layer remat, KV / ring / recurrent-state caches.

Layer recipes
  dense/vlm/audio : x += attn(norm(x));  x += mlp(norm(x))
  moe             : x += attn(norm(x));  x += moe(norm(x)) [+ dense residual]
  rwkv            : x += time_mix(norm(x));  x += channel_mix(norm(x))
  rglru           : blocks of `attn_every` layers — (attn_every-1) recurrent
                    + 1 local-attention — scanned; remainder unrolled.

Caches
  attention (global) : k/v (B, S, Hkv, hd) + scalar length
  attention (window) : ring buffer (B, W, ...) + slot positions
  rwkv               : S (B, H, M, M) + token-shift states
  rglru              : h (B, dl) + conv state
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import moe as MOE
from . import rwkv as RWKV
from . import rglru as RGLRU
from repro.dist.sharding import shard_act, current_mesh


# ---------------------------------------------------------------------------
#  parameter init
# ---------------------------------------------------------------------------
def _layer_params(cfg: ModelConfig, key, i: int, dtype):
    p = {"norm1": jnp.zeros((cfg.d_model,), dtype),
         "norm2": jnp.zeros((cfg.d_model,), dtype)}
    k1, k2 = jax.random.split(key)
    if cfg.family == "rwkv":
        p.update(RWKV.rwkv_params(cfg, k1, dtype))
        return p
    if cfg._is_attn_layer(i):
        p["attn"] = L.attn_params(cfg, k1, dtype)
    else:
        p["rec"] = RGLRU.rglru_params(cfg, k1, dtype)
    if cfg.n_experts:
        p["moe"] = MOE.moe_params(cfg, k2, dtype)
        if cfg.dense_residual:
            p["mlp"] = L.mlp_params(cfg, jax.random.fold_in(k2, 1), dtype)
    else:
        p["mlp"] = L.mlp_params(cfg, k2, dtype)
    return p


def _layer_plan(cfg: ModelConfig):
    """(n_scanned, tail_indices): homogeneous stacks scan everything; hybrids
    scan whole blocks and unroll the remainder."""
    if cfg.attn_every:
        n_blocks = cfg.n_layers // cfg.attn_every
        n_scanned = n_blocks * cfg.attn_every
        return n_scanned, list(range(n_scanned, cfg.n_layers))
    return cfg.n_layers, []


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ke, kh, kl = jax.random.split(key, 3)
    params: dict[str, Any] = {
        "embed": jax.random.normal(ke, (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            kh, (cfg.d_model, cfg.vocab), dtype) * cfg.d_model ** -0.5

    n_scanned, tail = _layer_plan(cfg)
    if cfg.scan_layers and n_scanned > 0:
        period = cfg.attn_every or 1
        n_steps = n_scanned // period

        def one_step(k):
            ks = jax.random.split(k, period)
            if period == 1:
                return _layer_params(cfg, ks[0], 0, dtype)
            return [_layer_params(cfg, ks[j], j, dtype) for j in range(period)]

        keys = jax.random.split(jax.random.fold_in(kl, 0), n_steps)
        params["layers"] = jax.vmap(one_step)(keys)       # leaves: (n_steps, ...)
    else:
        params["layers"] = [
            _layer_params(cfg, jax.random.fold_in(kl, i), i, dtype)
            for i in range(n_scanned)]
    params["tail"] = [
        _layer_params(cfg, jax.random.fold_in(kl, 1000 + i), i, dtype)
        for i in tail]
    return params


# ---------------------------------------------------------------------------
#  caches
# ---------------------------------------------------------------------------
def _attn_cache(cfg: ModelConfig, B: int, max_len: int):
    S = min(max_len, cfg.window) if cfg.window else max_len
    S = -(-S // cfg.attn_chunk) * cfg.attn_chunk
    hk = (B, S, cfg.n_kv_heads, cfg.hd)
    c = {"k": jnp.zeros(hk, jnp.dtype(cfg.dtype)),
         "v": jnp.zeros(hk, jnp.dtype(cfg.dtype))}
    if cfg.window:
        # unfilled ring slots must fail the window mask: far-past sentinel
        c["slot_pos"] = jnp.full((S,), -(1 << 30), jnp.int32)
    return c


def _layer_cache(cfg: ModelConfig, i: int, B: int, max_len: int):
    if cfg.family == "rwkv":
        M = cfg.rwkv_head_dim
        H = cfg.d_model // M
        return {"S": jnp.zeros((B, H, M, M), jnp.float32),
                "last": jnp.zeros((B, cfg.d_model), jnp.dtype(cfg.dtype)),
                "last_c": jnp.zeros((B, cfg.d_model), jnp.dtype(cfg.dtype))}
    if cfg._is_attn_layer(i):
        return _attn_cache(cfg, B, max_len)
    return {"h": jnp.zeros((B, cfg.lru_d), jnp.float32),
            "conv": jnp.zeros((B, RGLRU.CONV_W - 1, cfg.lru_d),
                              jnp.dtype(cfg.dtype))}


def init_cache(cfg: ModelConfig, batch: int, max_len: int | None = None):
    max_len = max_len or cfg.max_target_len
    n_scanned, tail = _layer_plan(cfg)
    period = cfg.attn_every or 1
    n_steps = n_scanned // period

    def one_step(_):
        if period == 1:
            return _layer_cache(cfg, 0, batch, max_len)
        return [_layer_cache(cfg, j, batch, max_len) for j in range(period)]

    if not cfg.scan_layers:
        return {
            "layers": [_layer_cache(cfg, i % period, batch, max_len)
                       for i in range(n_scanned)],
            "tail": [_layer_cache(cfg, i, batch, max_len) for i in tail],
            "length": jnp.zeros((), jnp.int32),
        }

    cache = {
        "layers": jax.vmap(one_step)(jnp.arange(n_steps)),
        "tail": [_layer_cache(cfg, i, batch, max_len) for i in tail],
        "length": jnp.zeros((), jnp.int32),
    }
    return cache


# ---------------------------------------------------------------------------
#  blocks
# ---------------------------------------------------------------------------
def _attn_with_ring(cfg, p, x, positions, cache, length):
    """Windowed ring-buffer attention for decode (cache is (B,W,...))."""
    q = jnp.einsum("btd,dnh->btnh", x, p["wq"])
    k = jnp.einsum("btd,dnh->btnh", x, p["wk"])
    v = jnp.einsum("btd,dnh->btnh", x, p["wv"])
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    W = cache["k"].shape[1]
    slot = length % W
    kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    sp = jax.lax.dynamic_update_slice(cache["slot_pos"], positions, (slot,))
    out = L.attention(q, kc, vc, positions, sp, window=cfg.window,
                      chunk=cfg.attn_chunk)
    y = jnp.einsum("btnh,nhd->btd", out, p["wo"])
    return y, {"k": kc, "v": vc, "slot_pos": sp}


def _block(cfg: ModelConfig, p, x, positions, cache, length, layer_idx,
           mesh=None):
    """One layer.  cache=None during training."""
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if cfg.family == "rwkv":
        y, st = RWKV.time_mix(cfg, p, h, cache)
        x = x + y
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        y2, st2 = RWKV.channel_mix(cfg, p, h2, cache)
        new_cache = {**st, **st2} if cache is not None else None
        return x + y2, new_cache, 0.0

    if "attn" in p:
        if cache is not None and cfg.window:
            y, new_c = _attn_with_ring(cfg, p["attn"], h, positions, cache,
                                       length)
        elif (cache is not None and cfg.decode_shard_s
              and (mesh or current_mesh()) is not None):
            from .decode_sharded import attn_decode_sharded
            y, new_c = attn_decode_sharded(cfg, mesh or current_mesh(),
                                           p["attn"], h, positions, cache,
                                           length)
        else:
            c = None if cache is None else {**cache, "length": length}
            y, new_c = L.attn_block(cfg, p["attn"], h, positions, cache=c,
                                    window=cfg.window)
            if new_c is not None:
                new_c = {"k": new_c["k"], "v": new_c["v"]}
    else:
        y, new_c = RGLRU.rglru_block(cfg, p["rec"], h,
                                     cache if cache is not None else None)
    x = x + y
    x = shard_act(x)

    h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    aux = 0.0
    if cfg.n_experts:
        mesh = mesh or current_mesh()
        if mesh is not None:
            y2, aux = MOE.moe_shardmap(cfg, mesh, p["moe"], h2)
        else:
            y2, aux = MOE.moe_block(cfg, p["moe"], h2)
        if cfg.dense_residual:
            y2 = y2 + L.mlp_block(cfg, p["mlp"], h2)
    else:
        y2 = L.mlp_block(cfg, p["mlp"], h2)
    x = x + y2
    x = shard_act(x)
    return x, (new_c if cache is not None else None), aux


# ---------------------------------------------------------------------------
#  forward / decode
# ---------------------------------------------------------------------------
def forward(cfg: ModelConfig, params, batch, mesh=None):
    """Training/prefill forward.  batch: tokens (B,T) [+ prefix_embeds
    (B,P,D) for VLM/audio stubs].  Returns (logits, aux_loss)."""
    x, aux_total = _forward_body(cfg, params, batch, mesh=mesh)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head)
    return logits, aux_total


def forward_hidden(cfg: ModelConfig, params, batch, mesh=None):
    """Forward up to the final norm (no logits) — used by the chunked-CE
    loss so the (B,T,V) f32 logits never materialize."""
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return _forward_body(cfg, params, batch, mesh=mesh), head


def _forward_body(cfg: ModelConfig, params, batch, mesh=None):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.prefix_len and "prefix_embeds" in batch:
        x = jnp.concatenate(
            [batch["prefix_embeds"].astype(x.dtype), x], axis=1)
    T = x.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    x = shard_act(x)
    period = cfg.attn_every or 1
    aux_total = 0.0

    def block_fn(x, p_step):
        aux = 0.0
        if period == 1:
            x, _, aux = _block(cfg, p_step, x, positions, None, None, 0,
                               mesh=mesh)
        else:
            for j in range(period):
                x, _, a = _block(cfg, p_step[j], x, positions, None, None, j,
                                 mesh=mesh)
                aux = aux + a
        return x, aux

    if cfg.remat:
        block_fn = jax.checkpoint(block_fn,
                                  policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(lambda c, p: block_fn(c, p), x,
                               params["layers"])
        aux_total = aux_total + jnp.sum(auxs)
    else:
        # unrolled: layers is a flat per-layer list (heterogeneous for
        # hybrids), not period-grouped — apply _block directly
        def one(x, p_layer):
            x, _, aux = _block(cfg, p_layer, x, positions, None, None, 0,
                               mesh=mesh)
            return x, aux
        if cfg.remat:
            one = jax.checkpoint(
                one, policy=jax.checkpoint_policies.nothing_saveable)
        for p_layer in params["layers"]:
            x, aux = one(x, p_layer)
            aux_total = aux_total + aux
    for i, p_layer in enumerate(params["tail"]):
        x, _, aux = _block(cfg, p_layer, x, positions, None, None, i,
                           mesh=mesh)
        aux_total = aux_total + aux
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def decode_step(cfg: ModelConfig, params, cache, tokens, mesh=None):
    """One decode step.  tokens: (B,1).  Returns (logits (B,1,V), cache)."""
    length = cache["length"]
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.full((tokens.shape[1],), length, jnp.int32) \
        + jnp.arange(tokens.shape[1], dtype=jnp.int32)

    period = cfg.attn_every or 1

    def scan_step(x, pc):
        p_step, c_step = pc
        new_cs = []
        if period == 1:
            x, nc, _ = _block(cfg, p_step, x, positions, c_step, length, 0,
                              mesh=mesh)
            return x, nc
        for j in range(period):
            x, nc, _ = _block(cfg, p_step[j], x, positions, c_step[j],
                              length, j, mesh=mesh)
            new_cs.append(nc)
        return x, new_cs

    if cfg.scan_layers:
        x, new_layer_cache = jax.lax.scan(
            scan_step, x, (params["layers"], cache["layers"]))
    else:
        new_layer_cache = []
        for i, (p_layer, c_layer) in enumerate(
                zip(params["layers"], cache["layers"])):
            x, nc, _ = _block(cfg, p_layer, x, positions, c_layer, length,
                              i, mesh=mesh)
            new_layer_cache.append(nc)

    new_tail = []
    for p_layer, c_layer in zip(params["tail"], cache["tail"]):
        x, nc, _ = _block(cfg, p_layer, x, positions, c_layer, length, 0,
                          mesh=mesh)
        new_tail.append(nc)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", x, head)
    new_cache = {"layers": new_layer_cache, "tail": new_tail,
                 "length": length + tokens.shape[1]}
    return logits, new_cache
