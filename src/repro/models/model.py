"""Public model API: init / forward / loss / cache / decode + batch specs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import transformer as T
from .transformer import forward_hidden  # noqa: F401  (re-export)

init_params = T.init_params
init_cache = T.init_cache
forward = T.forward
decode_step = T.decode_step


def loss_fn(cfg: ModelConfig, params, batch, mesh=None):
    """Causal-LM cross entropy (+ MoE load-balance aux).

    With ``cfg.chunked_ce = n`` the head matmul + CE run per sequence-chunk
    inside a scan, so the (B,T,V) logits (bf16 *and* the f32 cast) never
    materialize — the §Perf memory-term optimization."""
    labels = batch["labels"]
    if cfg.chunked_ce:
        (x, aux), head = T.forward_hidden(cfg, params, batch, mesh=mesh)
        if cfg.prefix_len and "prefix_embeds" in batch:
            x = x[:, -labels.shape[1]:, :]
        B, Tlen, D = x.shape
        n = cfg.chunked_ce
        C = Tlen // n

        def chunk(carry, xs):
            xc, lc = xs                                  # (B,C,D), (B,C)
            logits = jnp.einsum("bcd,dv->bcv", xc, head).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            true = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            return carry + jnp.sum(logz - true), None

        xs = (x.reshape(B, n, C, D).swapaxes(0, 1),
              labels.reshape(B, n, C).swapaxes(0, 1))
        total, _ = jax.lax.scan(chunk, jnp.zeros((), jnp.float32), xs)
        nll = total / (B * Tlen)
        return nll + 0.01 * aux

    logits, aux = forward(cfg, params, batch, mesh=mesh)
    if cfg.prefix_len and "prefix_embeds" in batch:
        logits = logits[:, -labels.shape[1]:, :]       # loss on text positions
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(
        logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - true_logit).mean()
    return nll + 0.01 * aux


def build_batch_spec(cfg: ModelConfig, global_batch: int, seq_len: int,
                     mode: str = "train"):
    """ShapeDtypeStructs for every model input (dry-run stand-ins)."""
    if mode in ("train", "prefill"):
        spec = {
            "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        }
        if mode == "train":
            spec["labels"] = jax.ShapeDtypeStruct((global_batch, seq_len),
                                                  jnp.int32)
        if cfg.prefix_len:
            spec["prefix_embeds"] = jax.ShapeDtypeStruct(
                (global_batch, cfg.prefix_len, cfg.d_model),
                jnp.dtype(cfg.dtype))
        return spec
    # decode: one new token against a cache of length seq_len
    return {"tokens": jax.ShapeDtypeStruct((global_batch, 1), jnp.int32)}
