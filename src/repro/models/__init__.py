"""Model zoo: the 10 assigned architectures as composable pure-JAX modules.

Families: dense decoder (GQA/MQA/qk-norm/GeGLU variants), MoE (top-k, with
optional dense residual), RWKV6 (attention-free SSM), RG-LRU hybrid
(recurrent + local attention), and VLM/audio backbones with stub frontends.
"""

from .config import ModelConfig
from .model import (build_batch_spec, decode_step, forward, init_cache,
                    init_params, loss_fn)

__all__ = ["ModelConfig", "build_batch_spec", "decode_step", "forward",
           "init_cache", "init_params", "loss_fn"]
