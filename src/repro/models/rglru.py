"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

    r_t = sigmoid(W_a x_t)            (recurrence gate)
    i_t = sigmoid(W_x x_t)            (input gate)
    a_t = exp(-c · softplus(Lambda) · r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t)

The diagonal linear recurrence is evaluated with an associative scan
(log-depth, numerically safe — no explicit cumprod).  The block follows the
Griffin layout: input/gate linear pair, short causal depthwise conv on the
input branch, RG-LRU, GeLU-gated output projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig

C_CONST = 8.0
CONV_W = 4


def rglru_params(cfg: ModelConfig, key, dtype):
    d, dl = cfg.d_model, cfg.lru_d
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_in": jax.random.normal(ks[0], (d, dl), dtype) * s,
        "w_gate": jax.random.normal(ks[1], (d, dl), dtype) * s,
        "conv": jax.random.normal(ks[2], (CONV_W, dl), dtype) * 0.3,
        "wa": jax.random.normal(ks[3], (dl, dl), dtype) * dl ** -0.5,
        "wx": jax.random.normal(ks[4], (dl, dl), dtype) * dl ** -0.5,
        "lam": jax.random.normal(jax.random.fold_in(key, 7), (dl,),
                                 jnp.float32) * 0.5 + 2.0,
        "w_out": jax.random.normal(ks[5], (dl, d), dtype) * dl ** -0.5,
    }


def _causal_conv(x, w, state):
    """Depthwise causal conv, width CONV_W.  state: (B, CONV_W-1, dl)."""
    hist = jnp.concatenate([state, x], axis=1) if state is not None else \
        jnp.pad(x, [(0, 0), (CONV_W - 1, 0), (0, 0)])
    out = sum(hist[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(CONV_W))
    new_state = hist[:, -(CONV_W - 1):, :]
    return out, new_state


def rglru_block(cfg: ModelConfig, p, x, state=None):
    """x: (B,T,D).  state: {"h": (B,dl), "conv": (B,3,dl)} or None."""
    B, T, D = x.shape
    u = x @ p["w_in"]                                      # (B,T,dl)
    gate = jax.nn.gelu(x @ p["w_gate"])
    u, conv_state = _causal_conv(u, p["conv"],
                                 None if state is None else state["conv"])

    r = jax.nn.sigmoid(u @ p["wa"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ p["wx"]).astype(jnp.float32)
    log_a = -C_CONST * jax.nn.softplus(p["lam"])[None, None, :] * r  # ≤ 0
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * i * u.astype(jnp.float32)

    h0 = state["h"].astype(jnp.float32) if state is not None else \
        jnp.zeros((B, p["w_in"].shape[1]), jnp.float32)
    if T == 1:                                             # decode fast path
        h = a[:, 0] * h0 + b[:, 0]
        hs = h[:, None, :]
    else:
        # fold h0 into the first step, then associative scan over T
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
        h = hs[:, -1, :]

    y = (hs.astype(x.dtype) * gate) @ p["w_out"]
    new_state = {"h": h, "conv": conv_state}
    return y, new_state
