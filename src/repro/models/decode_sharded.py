"""Sharded-sequence decode attention (§Perf optimization).

Baseline decode lets XLA's partitioner handle attention over the
sequence-sharded KV cache; it gives up and all-gathers the cache
(~GB/token of ICI traffic).  This shard_map keeps every cache shard
local: each model rank computes a *partial* online-softmax over its
S/16 slice and the ranks combine (pmax + two psums of (B,H)-sized
stats) — bytes on the wire drop from the cache size to ~B*H*hd.

The cache append also stays local: exactly one rank owns the slot at
`length`; everyone else's update is masked out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from . import layers as L
from .config import ModelConfig


def attn_decode_sharded(cfg: ModelConfig, mesh, p, x, positions, cache,
                        length):
    """x: (B,1,D); cache k/v: (B,S,Hkv,hd) sharded (dp, model, -, -).
    Returns (y (B,1,D), new {k,v})."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_model = mesh.shape["model"]
    B, _, D = x.shape
    S = cache["k"].shape[1]
    if S % n_model or B % max(1, _size(mesh, dp)):
        # fall back to the XLA path when the cache/batch don't divide
        c = {**cache, "length": length}
        y, nc = L.attn_block(cfg, p, x, positions, cache=c,
                             window=cfg.window)
        return y, {"k": nc["k"], "v": nc["v"]}
    S_loc = S // n_model
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // Hkv

    q = jnp.einsum("btd,dnh->btnh", x, p["wq"])
    k = jnp.einsum("btd,dnh->btnh", x, p["wk"])
    v = jnp.einsum("btd,dnh->btnh", x, p["wv"])
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)

    def body(q, k_new, v_new, kc, vc, length):
        b = q.shape[0]                    # local batch (B / dp)
        r = jax.lax.axis_index("model")
        base = r * S_loc
        idx = jnp.clip(length - base, 0, S_loc - 1)
        in_range = jnp.logical_and(length >= base, length < base + S_loc)
        # masked write touching only the slot (no full-cache copy): ranks
        # that don't own the slot re-write the existing value
        k_old = jax.lax.dynamic_slice(kc, (0, idx, 0, 0), k_new.shape)
        v_old = jax.lax.dynamic_slice(vc, (0, idx, 0, 0), v_new.shape)
        kc2 = jax.lax.dynamic_update_slice(
            kc, jnp.where(in_range, k_new, k_old), (0, idx, 0, 0))
        vc2 = jax.lax.dynamic_update_slice(
            vc, jnp.where(in_range, v_new, v_old), (0, idx, 0, 0))

        qg = q.reshape(b, 1, Hkv, G, hd).astype(jnp.float32) * hd ** -0.5
        s = jnp.einsum("bokgh,bskh->bkgs", qg,
                       kc2.astype(jnp.float32))           # (b,Hkv,G,S_loc)
        k_pos = base + jnp.arange(S_loc)
        s = jnp.where((k_pos <= length)[None, None, None, :], s, -1e30)
        m_loc = s.max(axis=-1)
        pexp = jnp.exp(s - m_loc[..., None])
        l_loc = pexp.sum(axis=-1)
        acc_loc = jnp.einsum("bkgs,bskh->bkgh", pexp,
                             vc2.astype(jnp.float32))
        m = jax.lax.pmax(m_loc, "model")
        corr = jnp.exp(m_loc - m)
        l = jax.lax.psum(l_loc * corr, "model")
        acc = jax.lax.psum(acc_loc * corr[..., None], "model")
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).reshape(b, 1, H, hd)
        return out.astype(q.dtype), kc2, vc2

    rep4 = P(dp, None, None, None)
    shard4 = P(dp, "model", None, None)
    out, kc, vc = shard_map(
        body, mesh=mesh,
        in_specs=(rep4, rep4, rep4, shard4, shard4, P()),
        out_specs=(rep4, shard4, shard4),
        check_rep=False,
    )(q, k, v, cache["k"], cache["v"], length)
    y = jnp.einsum("btnh,nhd->btd", out, p["wo"])
    return y, {"k": kc, "v": vc}


def _size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
