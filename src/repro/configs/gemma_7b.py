"""gemma-7b [arXiv:2403.08295]: 28L, d=3072, 16H MHA (kv=16), head_dim=256,
GeGLU d_ff=24576, vocab=256000, tied embeddings."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000, act="geglu", tie_embeddings=True,
    rope_theta=10000.0,
)
