"""rwkv6-3b "Finch" [arXiv:2404.05892]: 32L, d=2560, attention-free
(data-dependent decay WKV), d_ff=8960, vocab=65536."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536, rwkv_head_dim=64,
)
