"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-235B-A22B]: 94L, d=4096, 64H GQA(kv=4),
expert d_ff=1536, vocab=151936, MoE 128 experts top-8, qk-norm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936, qk_norm=True,
    n_experts=128, top_k=8, moe_d_ff=1536,
)
