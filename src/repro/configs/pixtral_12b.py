"""pixtral-12b [hf:mistralai/Pixtral-12B-2409]: mistral-nemo decoder backbone,
40L, d=5120, 32H GQA(kv=8), d_ff=14336, vocab=131072; ViT patch frontend is a
STUB (input_specs supplies precomputed patch embeddings)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=160,
    d_ff=14336, vocab=131072, prefix_len=256,
)
