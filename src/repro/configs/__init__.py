"""Assigned architecture configs (``--arch <id>``).

Every entry is the exact published configuration; ``SHAPES`` are the
assigned input-shape cells.  ``get(name)`` returns the ModelConfig;
``SMOKE(name)`` its reduced same-family variant for CPU tests.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "qwen3_moe_235b", "arctic_480b", "rwkv6_3b", "pixtral_12b", "gemma_7b",
    "qwen3_0_6b", "granite_34b", "starcoder2_3b", "musicgen_medium",
    "recurrentgemma_9b",
]

ALIASES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "arctic-480b": "arctic_480b",
    "rwkv6-3b": "rwkv6_3b",
    "pixtral-12b": "pixtral_12b",
    "gemma-7b": "gemma_7b",
    "qwen3-0.6b": "qwen3_0_6b",
    "granite-34b": "granite_34b",
    "starcoder2-3b": "starcoder2_3b",
    "musicgen-medium": "musicgen_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
}

# (name, seq_len, global_batch, mode)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: only these archs run it
LONG_OK = {"rwkv6_3b", "recurrentgemma_9b"}


def get(name: str):
    key = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{key}")
    return mod.CONFIG


def smoke(name: str):
    return get(name).smoke()


def cells():
    """All runnable (arch, shape) dry-run cells."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            if s == "long_500k" and a not in LONG_OK:
                continue
            out.append((a, s))
    return out
