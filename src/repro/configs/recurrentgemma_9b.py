"""recurrentgemma-9b (Griffin) [arXiv:2402.19427]: 38L, d=4096, RG-LRU
recurrent blocks + local attention (window 2048) in a 2:1 pattern,
16H MQA(kv=1) head_dim=256 on attention layers, d_ff=12288, vocab=256000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="rglru",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab=256000, act="geglu",
    attn_every=3, window=2048, lru_width=4096, rope_theta=10000.0,
)
