"""granite-34b-code [arXiv:2405.04324]: 88L, d=6144, 48H MQA(kv=1),
d_ff=24576, vocab=49152."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab=49152,
)
