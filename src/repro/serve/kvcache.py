"""Ownership-paged KV cache: DRust's protocol applied to serving state.

Pages are heap objects under the ownership model, and — when the cache is
constructed over a ``Cluster`` — real DSM objects behind the scoped-guard
surface of ``ProtocolBackend``:

  * The request that *appends* to a page holds the scoped mutable borrow
    (``with page.box.write(th) as w:``) — the append is a local write and
    the color bump rides the DropMutRef write-back (Algorithm 6).  No
    other request can read a page mid-append, by construction.
  * Shared prefix pages are immutably borrowed by many requests: each
    decode step reads its page set through ``backend.read_many`` inside
    the engine's region, so cold remote pages coalesce into per-source
    doorbells and warm ones are zero-communication cache hits.
  * A request's *generation* pages form a TBox chain (each tail page is
    ``tie_to``-tied to its predecessor): the chain is co-located with its
    single writer, fetched as one doorbell by any remote reader, and
    freed as one coalesced drop (B.4) when the request completes.
  * Refcounts drive lazy reclamation under memory pressure (§4.2.1):
    pages with zero refs are evictable, LRU-ordered; evicting a
    DSM-backed page drops its box, which invalidates every cached copy.

The host-side page table below is the control plane; the device-side cache
is the model's slot-contiguous KV buffer (``dist.sharding`` shards its
sequence dim over ``model``).  Page size = ``attn_chunk`` so page
boundaries align with kernel blocks.  Without a cluster the cache runs
exactly as the seed local-only control plane (no boxes, no costs).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any

from repro.core.jaxstate import ColoredAddr
from repro.core.ownership import BorrowError


@dataclass
class Page:
    addr: ColoredAddr
    tokens: tuple[int, ...]            # token ids covered by this page
    page_size: int = 0                 # capacity; 0 = unbounded
    refcount: int = 0
    mut_borrowed: bool = False
    sealed: bool = False               # immutable from here on
    last_use: int = 0
    box: Any = None                    # DSM handle when cluster-backed

    @property
    def full(self) -> bool:
        return self.page_size > 0 and len(self.tokens) >= self.page_size


class PagedKVCache:
    """Page table + prefix-sharing index for a serving cluster.

    ``cluster``/``th`` switch on the DSM plane: pages get protocol-backed
    boxes, shared prefix pages stripe across the cluster's servers, and
    every append / read / evict charges the simulator through the guard
    API.  ``bytes_per_token`` sizes a page's wire footprint.
    """

    _uid = itertools.count()

    def __init__(self, page_size: int = 1024, capacity_pages: int = 4096,
                 cluster=None, th=None, bytes_per_token: int = 256,
                 stripe: bool = True):
        self.page_size = page_size
        self.capacity = capacity_pages
        self.cluster = cluster
        self.th = th
        self.bytes_per_token = bytes_per_token
        self.stripe = stripe
        self.pages: dict[str, Page] = {}          # addr.name -> Page
        self.prefix_index: dict[tuple, str] = {}  # token tuple -> addr.name
        self.clock = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self._stripe_rr = 0

    def _th(self, th):
        return th if th is not None else self.th

    # -- allocation / append (mutable path) --------------------------------
    def alloc_page(self, tokens: tuple[int, ...], th=None,
                   tie_to: Page | None = None, local: bool = False) -> Page:
        """Allocate a page frame (evicting under pressure).

        DSM plane: ``tie_to`` chains the page into its predecessor's TBox
        group (co-located, group-fetched, group-dropped); ``local`` pins
        the frame to the allocating thread's server (single-writer append
        pages live with their writer), otherwise shared prefix frames
        stripe round-robin across servers.
        """
        tokens = tuple(tokens)
        if self.page_size and len(tokens) > self.page_size:
            raise ValueError(
                f"page overflow: {len(tokens)} tokens > page_size "
                f"{self.page_size}")
        if len(self.pages) >= self.capacity:
            freed = self.evict(1, th=th)
            if not freed:
                raise MemoryError("KV cache full and no evictable pages")
        addr = ColoredAddr(f"page#{next(self._uid)}", 0)
        page = Page(addr, tokens, page_size=self.page_size)
        if self.cluster is not None:
            t = self._th(th)
            nbytes = max(1, self.page_size or len(tokens)) \
                * self.bytes_per_token
            if tie_to is not None and tie_to.box is not None:
                page.box = self.cluster.backend.alloc(
                    t, nbytes, tokens, tie_to=tie_to.box)
            else:
                if local or not self.stripe:
                    server = t.server
                else:
                    server = self._stripe_rr % self.cluster.sim.n
                    self._stripe_rr += 1
                page.box = self.cluster.backend.alloc(
                    t, nbytes, tokens, server=server)
        self.pages[addr.name] = page
        self.touch(page)
        return page

    def append(self, page: Page, token: int, th=None) -> Page:
        """Scoped mutable borrow: exclusive append; color bump on exit."""
        if page.sealed:
            raise BorrowError("append to a sealed (immutable) page")
        if page.full:
            raise BorrowError("append to a full page: seal it and chain a "
                              "new page (tie_to=) instead")
        if page.refcount > 1:
            raise BorrowError("append to a shared page requires copy-on-write")
        if page.mut_borrowed:
            raise BorrowError("page already mutably borrowed")
        page.mut_borrowed = True
        try:
            new_tokens = page.tokens + (token,)
            if page.box is not None:
                # The write guard IS the append epoch: enter = exclusive
                # borrow, w.set = the local store, exit = DropMutRef (the
                # colored-address write-back — the on-wire color bump).
                with page.box.write(self._th(th)) as w:
                    w.set(new_tokens)
            page.tokens = new_tokens
            page.addr = page.addr.bumped()         # the invalidation
        finally:
            page.mut_borrowed = False
        self.touch(page)
        return page

    def seal(self, page: Page) -> None:
        """The page becomes immutable and enters the prefix index (shared
        prefixes are looked up by their full token tuple)."""
        page.sealed = True
        self.prefix_index[page.tokens] = page.addr.name

    def freeze(self, page: Page) -> None:
        """Immutability without prefix-index entry — generation pages are
        request-private, so they must never be handed to other requests
        (their chain is freed as one closure at completion)."""
        page.sealed = True

    def fork(self, page: Page, th=None) -> Page:
        """Copy-on-write: a shared page that must diverge is *moved* to a
        new address for the writer (Algorithm 6 move-on-write).  The
        writer's reference migrates to its private copy: the shared page
        loses one ref, the fork is born with ``refcount == 1``."""
        new = self.alloc_page(page.tokens, th=th, local=True)
        new.refcount = 1
        self.release(page)
        return new

    # -- prefix sharing (immutable path) -------------------------------------
    def lookup_prefix(self, tokens: tuple[int, ...]) -> Page | None:
        name = self.prefix_index.get(tuple(tokens))
        if name is None:
            self.misses += 1
            return None
        page = self.pages.get(name)
        if page is None or page.tokens != tuple(tokens):
            # Stale entry: the page was evicted, or an append bumped its
            # color past this prefix — the colored address the index
            # recorded no longer names these bytes (Stale-Value-
            # Elimination, Appendix C.4).  Scrub and miss.
            self.misses += 1
            del self.prefix_index[tuple(tokens)]
            return None
        self.hits += 1
        return page

    def peek_prefix(self, tokens: tuple[int, ...]) -> Page | None:
        """Side-effect-free ``lookup_prefix`` (no hit/miss accounting, no
        scrub) — used for prefetch-window hints, which must not perturb
        the cache statistics the SLO gate pins."""
        name = self.prefix_index.get(tuple(tokens))
        if name is None:
            return None
        page = self.pages.get(name)
        if page is None or page.tokens != tuple(tokens):
            return None
        return page

    def retain(self, page: Page, th=None) -> Page:
        """A request takes a shared reference on a page for its lifetime.
        The host refcount pins the frame against eviction; the protocol
        borrows are scoped per decode step (``read_many`` inside the
        engine's region), so this never holds a wire-level borrow open."""
        if page.mut_borrowed:
            raise BorrowError("read during append epoch")
        page.refcount += 1
        self.touch(page)
        return page

    def release(self, page: Page, th=None) -> None:
        page.refcount = max(0, page.refcount - 1)

    # Seed-compat aliases (the guard-era spellings above are canonical).
    borrow = retain
    drop = release

    def touch(self, page: Page) -> None:
        self.clock += 1
        page.last_use = self.clock

    # -- reclamation ----------------------------------------------------------
    def _free_box(self, page: Page, th=None) -> None:
        if page.box is not None and not page.box.dropped:
            # Drop of the owner: coalesced dealloc + async B.4 invalidation
            # of every server's cached copy of the page.
            self.cluster.backend.free(self._th(th), page.box)
            page.box = None

    def evict(self, n: int = 1, th=None) -> int:
        """Lazy zero-refcount reclamation, LRU first (§4.2.1)."""
        victims = sorted(
            (p for p in self.pages.values() if p.refcount == 0
             and not p.mut_borrowed),
            key=lambda p: p.last_use)[:n]
        for p in victims:
            self.pages.pop(p.addr.name, None)
            self.prefix_index.pop(p.tokens, None)
            self._free_box(p, th=th)
            self.evictions += 1
        return len(victims)

    def reclaim_chain(self, pages: list[Page], th=None) -> None:
        """Free a request's private generation chain: one owner drop on the
        chain root frees the whole TBox closure (coalesced dealloc, one
        async message per remote server), then the host frames go."""
        for p in pages:
            p.refcount = 0
        if pages and pages[0].box is not None:
            # The chain is tied root->...->tail: dropping the root's box
            # walks the tie closure and frees every member's slot.
            self._free_box(pages[0], th=th)
            for p in pages[1:]:
                p.box = None
        for p in pages:
            self.pages.pop(p.addr.name, None)
            self.prefix_index.pop(p.tokens, None)

    @property
    def bytes_estimate(self) -> int:
        return len(self.pages) * self.page_size * self.bytes_per_token

    def stats(self) -> dict:
        return {"pages": len(self.pages), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "shared": sum(1 for p in self.pages.values()
                              if p.refcount > 1)}
