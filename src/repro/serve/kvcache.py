"""Ownership-paged KV cache: DRust's protocol applied to serving state.

Pages are heap objects under the ownership model:

  * The request that *appends* to a page holds the mutable borrow — local
    write, color bump on drop (Algorithm 6).  No other request can read a
    page mid-append, by construction.
  * Shared prefix pages are immutably borrowed by many requests; the cache
    hashmap (token-hash -> page) is keyed by *colored* page addresses, so a
    recomputed/edited prefix never aliases a stale page (Stale-Value-
    Elimination, Appendix C.4).
  * Refcounts drive lazy reclamation under memory pressure (§4.2.1): pages
    with zero refs are evictable, LRU-ordered.

This is the host-side control plane; the device-side cache is the model's
slot-contiguous KV buffer (dist.sharding shards its sequence dim over
`model`).  Page size = attn_chunk so page boundaries align with kernel
blocks.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.core.jaxstate import ColoredAddr
from repro.core.ownership import BorrowError


@dataclass
class Page:
    addr: ColoredAddr
    tokens: tuple[int, ...]            # token ids covered by this page
    refcount: int = 0
    mut_borrowed: bool = False
    last_use: int = 0

    @property
    def full(self) -> bool:
        return False                    # set by owner cache (page_size)


class PagedKVCache:
    """Page table + prefix-sharing index for one model replica."""

    _uid = itertools.count()

    def __init__(self, page_size: int = 1024, capacity_pages: int = 4096):
        self.page_size = page_size
        self.capacity = capacity_pages
        self.pages: dict[str, Page] = {}          # addr.name -> Page
        self.prefix_index: dict[tuple, str] = {}  # token tuple -> addr.name
        self.clock = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # -- allocation / append (mutable path) --------------------------------
    def alloc_page(self, tokens: tuple[int, ...]) -> Page:
        if len(self.pages) >= self.capacity:
            freed = self.evict(1)
            if not freed:
                raise MemoryError("KV cache full and no evictable pages")
        addr = ColoredAddr(f"page#{next(self._uid)}", 0)
        page = Page(addr, tuple(tokens))
        self.pages[addr.name] = page
        return page

    def append(self, page: Page, token: int) -> Page:
        """Mutable borrow: exclusive append; color bump on drop."""
        if page.refcount > 1:
            raise BorrowError("append to a shared page requires copy-on-write")
        if page.mut_borrowed:
            raise BorrowError("page already mutably borrowed")
        page.mut_borrowed = True
        page.tokens = page.tokens + (token,)
        page.addr = page.addr.bumped()             # the invalidation
        page.mut_borrowed = False
        self.touch(page)
        return page

    def seal(self, page: Page) -> None:
        """A full page becomes immutable and enters the prefix index."""
        self.prefix_index[page.tokens] = page.addr.name

    def fork(self, page: Page) -> Page:
        """Copy-on-write: a shared page that must diverge is *moved* to a new
        address for the writer (Algorithm 6 move-on-write)."""
        new = self.alloc_page(page.tokens)
        return new

    # -- prefix sharing (immutable path) -------------------------------------
    def lookup_prefix(self, tokens: tuple[int, ...]) -> Page | None:
        name = self.prefix_index.get(tuple(tokens))
        if name is None:
            self.misses += 1
            return None
        page = self.pages.get(name)
        if page is None:
            self.misses += 1
            del self.prefix_index[tuple(tokens)]
            return None
        self.hits += 1
        return page

    def borrow(self, page: Page) -> Page:
        if page.mut_borrowed:
            raise BorrowError("read during append epoch")
        page.refcount += 1
        self.touch(page)
        return page

    def drop(self, page: Page) -> None:
        page.refcount = max(0, page.refcount - 1)

    def touch(self, page: Page) -> None:
        self.clock += 1
        page.last_use = self.clock

    # -- reclamation ----------------------------------------------------------
    def evict(self, n: int = 1) -> int:
        """Lazy zero-refcount reclamation, LRU first (§4.2.1)."""
        victims = sorted(
            (p for p in self.pages.values() if p.refcount == 0
             and not p.mut_borrowed),
            key=lambda p: p.last_use)[:n]
        for p in victims:
            self.pages.pop(p.addr.name, None)
            self.prefix_index.pop(p.tokens, None)
            self.evictions += 1
        return len(victims)

    @property
    def bytes_estimate(self) -> int:
        return len(self.pages) * self.page_size

    def stats(self) -> dict:
        return {"pages": len(self.pages), "hits": self.hits,
                "misses": self.misses, "evictions": self.evictions,
                "shared": sum(1 for p in self.pages.values()
                              if p.refcount > 1)}
