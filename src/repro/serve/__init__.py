from .kvcache import PagedKVCache, Page
from .serve_step import make_serve_step, make_prefill
from .engine import ServeEngine, Request

__all__ = ["Page", "PagedKVCache", "Request", "ServeEngine",
           "make_prefill", "make_serve_step"]
