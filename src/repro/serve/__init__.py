"""The DSM-backed serving plane (see ``docs/serving.md``).

Import note: the jitted decode path (``serve_step`` and the model stack
behind it) loads lazily — a ``step_fn``-stubbed engine, as used by the SLO
benches and the simulator-only tests, never traces or jits a model.
"""

from .engine import Request, ServeEngine, ServeFleet
from .kvcache import Page, PagedKVCache
from .loadgen import (LoadResult, OpenLoopDriver, bursty_trace,
                      poisson_trace, synth_prompts)

__all__ = ["LoadResult", "OpenLoopDriver", "Page", "PagedKVCache",
           "Request", "ServeEngine", "ServeFleet", "bursty_trace",
           "make_prefill", "make_serve_step", "poisson_trace",
           "synth_prompts"]


def __getattr__(name):
    # serve_step imports jax at module scope; keep it out of the package's
    # import path so cluster-only users never pay (or need) it.
    if name in ("make_serve_step", "make_prefill"):
        from . import serve_step
        return getattr(serve_step, name)
    raise AttributeError(name)
