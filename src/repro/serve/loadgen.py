"""Open-loop load generation for the serving plane.

A *closed-loop* driver (submit, wait, submit) can never observe queueing
collapse: the offered load adapts to the engine.  Production traffic does
not — arrivals keep coming whether or not the server kept up, which is
what makes tail latency (p99) the honest SLO.  This module generates
open-loop arrival *traces* in virtual microseconds and replays them
against a ``ServeEngine``/``ServeFleet`` on the simulator's clock:

  * ``poisson_trace`` — memoryless arrivals at a target rate
    (exponential inter-arrival gaps), the standard serving-bench model;
  * ``bursty_trace`` — an on/off modulated Poisson process: ``duty`` of
    the time the instantaneous rate is ``burst_factor`` times the
    off-phase rate, mean rate preserved.  Bursts are where open-loop and
    closed-loop measurements diverge most.

Everything is seeded and replayed on virtual clocks, so a trace is
byte-reproducible across runs and cluster sizes — which is what lets
``BENCH_protocol.json`` pin the resulting p50/p99/goodput trajectory and
``check_regression.py`` gate it.

``OpenLoopDriver`` owns the replay loop: submit every arrival whose
timestamp has passed, step the engine, and — when the engine goes idle
with arrivals still pending — advance the virtual clock to the next
arrival (an open-loop server really does sit idle between bursts).
Request latency is ``t_done - t_arrive`` and therefore *includes queue
wait*, the component closed-loop numbers hide.
"""

from __future__ import annotations

import random
from dataclasses import dataclass


def poisson_trace(rate_per_s: float, n: int, seed: int = 0,
                  t0_us: float = 0.0) -> list[float]:
    """``n`` arrival times (virtual us) of a Poisson process at
    ``rate_per_s`` requests per virtual second."""
    rng = random.Random(seed)
    t, out = t0_us, []
    gap_mean_us = 1e6 / rate_per_s
    for _ in range(n):
        t += rng.expovariate(1.0) * gap_mean_us
        out.append(t)
    return out


def bursty_trace(rate_per_s: float, n: int, seed: int = 0,
                 burst_factor: float = 4.0, duty: float = 0.25,
                 period_us: float = 200_000.0,
                 t0_us: float = 0.0) -> list[float]:
    """On/off modulated Poisson arrivals with the same *mean* rate as
    ``poisson_trace(rate_per_s)``.

    Each ``period_us`` window spends ``duty`` of its length in the *on*
    phase at ``burst_factor`` times the off-phase rate.  Solving
    ``duty*hi + (1-duty)*lo == rate`` with ``hi = burst_factor*lo`` gives
    the two phase rates; arrivals are thinned-Poisson within each phase.
    """
    lo = rate_per_s / (duty * burst_factor + (1.0 - duty))
    hi = burst_factor * lo
    rng = random.Random(seed)
    t, out = t0_us, []
    on_us = duty * period_us
    while len(out) < n:
        phase_off = (t - t0_us) % period_us
        rate = hi if phase_off < on_us else lo
        t += rng.expovariate(1.0) * (1e6 / rate)
        out.append(t)
    return out[:n]


def synth_prompts(n: int, seed: int = 0, vocab: int = 256,
                  shared_prefix: int = 8, unique_len: int = 4,
                  n_personas: int = 4) -> list[list[int]]:
    """Deterministic prompts with real prefix structure: each request
    picks one of ``n_personas`` shared system prefixes (the page-aligned
    part the KV cache deduplicates) and appends a unique user suffix."""
    rng = random.Random(seed)
    personas = [[rng.randrange(vocab) for _ in range(shared_prefix)]
                for _ in range(n_personas)]
    return [personas[rng.randrange(n_personas)]
            + [rng.randrange(vocab) for _ in range(unique_len)]
            for _ in range(n)]


@dataclass
class LoadResult:
    completed: int
    p50_us: float
    p99_us: float
    mean_us: float
    makespan_us: float
    goodput_tok_s: float       # SLO-met generated tokens per virtual second
    slo_met: int
    steps: int


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile — no interpolation, so the gated value
    is an actual observed latency."""
    if not sorted_vals:
        return 0.0
    k = min(len(sorted_vals) - 1, max(0, int(q * len(sorted_vals) + 0.5) - 1))
    return sorted_vals[k]


class OpenLoopDriver:
    """Replay an arrival trace against an engine on virtual time.

    ``weight_push_every`` emulates the trainer publishing a new weight
    epoch every N engine steps (``weights.write`` — a color bump), so the
    replicas' colored caches actually miss and refresh mid-load instead
    of hitting forever on epoch 0.
    """

    def __init__(self, engine, trace: list[float],
                 prompts: list[list[int]], max_new: int = 8,
                 weight_push_every: int = 0):
        assert len(trace) == len(prompts)
        self.engine = engine
        self.trace = trace
        self.prompts = prompts
        self.max_new = max_new
        self.weight_push_every = weight_push_every
        self.steps = 0

    def _submit_due(self, idx: int) -> int:
        now = self.engine.now_us()
        while idx < len(self.trace) and self.trace[idx] <= now:
            self.engine.submit(self.prompts[idx], self.max_new,
                               t_arrive=self.trace[idx])
            idx += 1
        return idx

    def run(self, max_steps: int = 100_000) -> list:
        idx = 0
        eng = self.engine
        for _ in range(max_steps):
            idx = self._submit_due(idx)
            if not eng.queue and not eng.active:
                if idx >= len(self.trace):
                    break                          # trace drained, all done
                eng.advance_to(self.trace[idx])    # idle until next arrival
                continue
            eng.step()
            self.steps += 1
            if (self.weight_push_every and eng.weights is not None
                    and self.steps % self.weight_push_every == 0):
                # Trainer publishes an epoch: color bump, replicas refetch.
                eng.weights.write(eng.weights.read())
        return eng.finished

    def result(self, slo_us: float) -> LoadResult:
        done = self.engine.finished
        lats = sorted(r.latency_us for r in done)
        t_end = max((r.t_done for r in done), default=0.0)
        t_start = min((r.t_arrive for r in done), default=0.0)
        span = max(1e-9, t_end - t_start)
        met = [r for r in done if r.latency_us <= slo_us]
        good_toks = sum(len(r.generated) for r in met)
        return LoadResult(
            completed=len(done),
            p50_us=round(_percentile(lats, 0.50), 3),
            p99_us=round(_percentile(lats, 0.99), 3),
            mean_us=round(sum(lats) / len(lats), 3) if lats else 0.0,
            makespan_us=round(span, 3),
            goodput_tok_s=round(good_toks / (span / 1e6), 3),
            slo_met=len(met),
            steps=self.steps)
