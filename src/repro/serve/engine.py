"""Batched serving engine over the DSM runtime: continuous batching over a
fixed slot set, the ownership-paged KV cache for prefix sharing, and
zero-invalidation weight refresh — optionally compressed to int8 on the
wire (``repro.dist.compression``).

Two planes, one token path:

  * **Local (seed) plane** — ``ServeEngine(cfg, weights)`` with no
    ``cluster``: pure host bookkeeping, exactly the seed engine.
  * **DSM plane** — ``ServeEngine(..., cluster=cl)``: every decode tick
    runs inside ``with cluster.region(th, prefetch=next_window)`` — the
    region scope is the tick's borrow lifetime, the prefetch hint posts
    speculative read doorbells for the *next* decode window (the kvstore
    ``prefetch_window`` pattern generalized to serving), and region exit
    is the settle point.  Page reads go through ``backend.read_many``
    (per-source doorbells, warm hits free), appends through scoped write
    guards (local write + color-bump write-back), and weight refreshes
    ride the colored ``StateCache`` — zero communication when the color
    matches, int8 over the wire when it doesn't.

The DSM plane never touches token *values*: admission order, slot
assignment, truncation, and the decode function are identical on both
planes, so ``digest()`` is byte-identical at every cluster size — the
protocol layer moves costs, not results (the equivalence gate in
``tests/test_serve_dsm.py`` pins this at 1/2/4/8 servers).

``step_fn`` swaps the jitted model step for any
``(params, cache, tokens[B,1]) -> (next[B,1], cache)`` callable — the
load benches use a deterministic stub so the SLO trajectory in
``BENCH_protocol.json`` is virtual-clock-only.  ``ServeFleet`` runs one
engine replica per server over a shared page table: prefix pages are
fetched remotely once and then serve from each replica's local cache —
the read-mostly sharing the protocol optimizes.
"""

from __future__ import annotations

import hashlib
import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.core.jaxstate import OwnedState, StateCache
from .kvcache import Page, PagedKVCache


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    generated: list[int] = field(default_factory=list)
    done: bool = False
    pages: list = field(default_factory=list)   # shared prefix pages (retained)
    tail_pages: list = field(default_factory=list)  # private generation chain
    t_arrive: float = 0.0                       # virtual us (open-loop traces)
    t_done: float = 0.0

    @property
    def latency_us(self) -> float:
        return self.t_done - self.t_arrive


class ServeEngine:
    """One model replica: continuous batching over ``slots`` decode lanes.

    ``cluster``/``server`` place the replica's thread; ``wire`` selects the
    weight-refresh encoding (``"int8"`` quantizes each refresh via
    ``repro.dist.compression`` — 4x fewer bytes, documented-lossy;
    ``"raw"`` ships exact bytes); ``weights_server`` is where the trainer
    publishes (refreshes are remote reads unless it matches ``server``);
    ``decode_cycles`` is the per-tick compute charged to the virtual
    clock; ``prefetch_window`` is how many queued requests ahead the
    region entry hint covers; ``kv`` shares a fleet-wide page table.
    """

    def __init__(self, cfg=None, weights: OwnedState | None = None,
                 slots: int = 4, max_len: int | None = None, mesh=None,
                 cluster=None, server: int = 0, wire: str = "raw",
                 weights_server: int = 0, step_fn=None,
                 decode_cycles: float = 4000.0, prefetch_window: int = 1,
                 page_size: int | None = None, vocab: int | None = None,
                 kv: PagedKVCache | None = None):
        if cfg is None and (step_fn is None or page_size is None):
            raise ValueError("cfg-less engines need step_fn and page_size")
        self.cfg = cfg
        self.weights = weights
        self.slots = slots
        self.max_len = max_len or (cfg.max_target_len if cfg else 1 << 30)
        self.mesh = mesh
        self.cluster = cluster
        self.wire = wire
        self.weights_server = weights_server
        self.decode_cycles = decode_cycles
        self.prefetch_window = prefetch_window
        self.vocab = vocab or (cfg.vocab if cfg else 0)
        self.th = cluster.main_thread(server) if cluster is not None else None
        self.wire_bytes = 0
        self.weight_cache = StateCache(transfer=self._wire_transfer)
        ps = page_size or cfg.attn_chunk
        self.kv = kv if kv is not None else PagedKVCache(
            page_size=ps, cluster=cluster, th=self.th)
        if step_fn is not None:
            self._step = step_fn
            self.cache = None
        else:
            import jax
            from repro.models import init_cache
            from .serve_step import make_serve_step
            self._step = jax.jit(make_serve_step(cfg, mesh=mesh),
                                 donate_argnums=(1,))
            self.cache = init_cache(cfg, slots, self.max_len)
        self.active: dict[int, Request] = {}        # slot -> request
        self._t_us = 0.0                            # local-plane clock
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.steps = 0
        self._rid = itertools.count()

    # -- virtual clock ------------------------------------------------------
    def now_us(self) -> float:
        return self.th.t_us if self.th is not None else self._t_us

    def advance_to(self, t_us: float) -> None:
        """Idle until ``t_us`` (open-loop driver: next arrival is in the
        future and no work is in flight).  The local plane keeps its own
        arrival-driven clock — decode there is costless, but time must
        still move or an open-loop replay would never drain its trace."""
        if self.th is not None:
            self.th.t_us = max(self.th.t_us, t_us)
        else:
            self._t_us = max(self._t_us, t_us)

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int = 16,
               t_arrive: float | None = None,
               rid: int | None = None) -> Request:
        """Queue a request (continuous batching admits it when a slot
        frees).  Prompts that cannot fit ``max_len`` alongside their
        ``max_new`` budget are head-truncated — deterministically, and
        identically on both planes."""
        prompt = list(prompt)
        budget = self.max_len - max_new
        if budget <= 0:
            raise ValueError(f"max_new {max_new} exceeds max_len "
                             f"{self.max_len}")
        if len(prompt) > budget:
            prompt = prompt[-budget:]              # keep the recent context
        req = Request(next(self._rid) if rid is None else rid,
                      prompt, max_new,
                      t_arrive=self.now_us() if t_arrive is None
                      else t_arrive)
        self.queue.append(req)
        return req

    # -- admission (continuous batching) ------------------------------------
    def _prefix_spans(self, prompt: list[int]):
        ps = self.kv.page_size
        for i in range(0, max(0, len(prompt) - ps + 1), ps):
            yield tuple(prompt[i:i + ps])

    def _admit(self):
        for slot in range(self.slots):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.pop(0)
            # prefix sharing: reuse sealed pages for the prompt's full pages
            for span in self._prefix_spans(req.prompt):
                page = self.kv.lookup_prefix(span)
                if page is None:
                    page = self.kv.alloc_page(span, th=self.th)
                    self.kv.seal(page)
                req.pages.append(self.kv.retain(page, th=self.th))
            # private single-writer tail chain for the generated tokens
            tail = self.kv.alloc_page((), th=self.th, local=True)
            req.tail_pages.append(self.kv.retain(tail, th=self.th))
            self.active[slot] = req

    # -- weight refresh ------------------------------------------------------
    def _wire_transfer(self, tree):
        """StateCache miss: the refresh crosses the wire.  ``int8`` packs
        every large float leaf (|err| <= scale/2) and ships 4x fewer
        bytes; the cost lands on the replica thread as one remote read
        from the trainer's server."""
        if self.cluster is None:
            return tree
        if self.wire == "int8":
            from repro.dist.compression import (dequantize_tree,
                                                quantize_tree, wire_bytes)
            packed = quantize_tree(tree)
            nbytes = wire_bytes(packed)
            out = dequantize_tree(packed)
        else:
            from repro.dist.compression import wire_bytes
            nbytes = wire_bytes(tree)
            out = tree
        self.wire_bytes += int(nbytes)
        if self.weights_server != self.th.server:
            self.cluster.sim.rdma_read(self.th, self.weights_server,
                                       int(nbytes))
        else:
            self.cluster.sim.local_access(self.th, int(nbytes))
        return out

    # -- prefetch window -----------------------------------------------------
    def _next_window(self):
        """DSM boxes the *next* decode tick will read: the active
        requests' page sets plus the existing prefix pages of the next
        ``prefetch_window`` queued requests (their admission is
        imminent).  Posted as the region's entry hint, so the fetch
        overlaps this tick's compute."""
        boxes = []
        seen = set()

        def add(page: Page):
            if page.box is not None and id(page.box) not in seen:
                seen.add(id(page.box))
                boxes.append(page.box)

        for req in self.active.values():
            for p in req.pages:
                add(p)
            for p in req.tail_pages:
                add(p)
        free = self.slots - len(self.active)
        for req in self.queue[:min(self.prefetch_window, free)]:
            for span in self._prefix_spans(req.prompt):
                page = self.kv.peek_prefix(span)
                if page is not None:
                    add(page)
        return boxes

    # -- one decode tick across all active slots ------------------------------
    def step(self) -> int:
        self._admit()
        if not self.active:
            return 0
        if self.cluster is None:
            return self._tick()
        window = self._next_window()
        with self.cluster.region(self.th, prefetch=window):
            return self._tick()

    def _read_pages(self):
        """Attention reads every page of every active sequence: one
        ``read_many`` per tick coalesces the cold misses into per-source
        doorbells; warm pages are local hashmap hits."""
        boxes, seen = [], set()
        for req in self.active.values():
            for p in req.pages + req.tail_pages:
                if p.box is not None and id(p.box) not in seen:
                    seen.add(id(p.box))
                    boxes.append(p.box)
        if boxes:
            self.cluster.backend.read_many(self.th, boxes)

    def _tick(self) -> int:
        params = (self.weight_cache.fetch(self.weights)   # color-keyed
                  if self.weights is not None else None)  # refresh
        if self.cluster is not None:
            self._read_pages()
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, req in self.active.items():
            seq = req.prompt + req.generated
            tokens[slot, 0] = seq[-1]
        nxt, self.cache = self._step(params, self.cache, tokens)
        nxt = np.asarray(nxt)
        if self.cluster is not None:
            self.cluster.sim.compute(self.th, self.decode_cycles)
        finished = []
        for slot, req in self.active.items():
            tok = int(nxt[slot, 0])
            req.generated.append(tok)
            tail = req.tail_pages[-1]
            if tail.full:
                self.kv.freeze(tail)               # immutable, never indexed
                tail = self.kv.alloc_page((), th=self.th, tie_to=tail,
                                          local=True)
                req.tail_pages.append(self.kv.retain(tail, th=self.th))
            self.kv.append(tail, tok, th=self.th)  # write guard: color bump
            if len(req.generated) >= req.max_new:
                req.done = True
                req.t_done = self.now_us()
                finished.append(slot)
        for slot in finished:
            req = self.active.pop(slot)            # slot freed for reuse
            for page in req.pages:
                self.kv.release(page, th=self.th)
            self.kv.reclaim_chain(req.tail_pages, th=self.th)
            self.finished.append(req)
        self.steps += 1
        return len(self.active) + len(finished)

    def run(self, max_steps: int = 256) -> list[Request]:
        for _ in range(max_steps):
            if not self.queue and not self.active:
                break
            self.step()
        return self.finished

    # -- results -------------------------------------------------------------
    def digest(self) -> str:
        """Order-independent hash of every finished request's tokens: the
        DSM plane must reproduce the local plane's digest byte-for-byte
        at any cluster size."""
        items = sorted((r.rid, tuple(r.generated)) for r in self.finished)
        return hashlib.sha256(repr(items).encode()).hexdigest()

    def stats(self) -> dict:
        out = {"steps": self.steps, "kv": self.kv.stats(),
               "weight_refreshes": self.weight_cache.refreshes,
               "weight_hits": self.weight_cache.hits,
               "wire_bytes": self.wire_bytes,
               "completed": len(self.finished)}
        if self.cluster is not None:
            out["guard_stats"] = dict(
                getattr(self.cluster.backend, "guard_stats", {}) or {})
        return out


class ServeFleet:
    """One engine replica per server over a shared page table.

    The fleet is the read-mostly-sharing shape the protocol optimizes:
    shared prefix pages are fetched remotely once per replica and then
    serve from that replica's local cache; each replica appends only to
    its own requests' private chains.  Arrivals route round-robin;
    ``step()`` advances the replica with the earliest virtual clock, so
    the fleet's makespan is honest under open-loop load.
    """

    def __init__(self, cluster, n_replicas: int | None = None, **engine_kw):
        self.cluster = cluster
        n = n_replicas or cluster.sim.n
        shared_kv = None
        self.engines: list[ServeEngine] = []
        for r in range(n):
            eng = ServeEngine(cluster=cluster, server=r % cluster.sim.n,
                              kv=shared_kv, **engine_kw)
            if shared_kv is None:
                shared_kv = eng.kv                 # fleet-wide page table
            self.engines.append(eng)
        self.kv = shared_kv
        self._rr = 0
        self._rid = itertools.count()   # fleet-global: digests stay
        # comparable with a single engine fed the same submission order

    def submit(self, prompt, max_new: int = 16,
               t_arrive: float | None = None) -> Request:
        eng = self.engines[self._rr % len(self.engines)]
        self._rr += 1
        return eng.submit(prompt, max_new, t_arrive=t_arrive,
                          rid=next(self._rid))

    @property
    def queue(self):
        return [r for e in self.engines for r in e.queue]

    @property
    def active(self):
        return {(i, s): r for i, e in enumerate(self.engines)
                for s, r in e.active.items()}

    @property
    def finished(self):
        return [r for e in self.engines for r in e.finished]

    @property
    def weights(self):
        return self.engines[0].weights

    def now_us(self) -> float:
        return min(e.now_us() for e in self.engines)

    def advance_to(self, t_us: float) -> None:
        for e in self.engines:
            if not e.queue and not e.active:
                e.advance_to(t_us)

    def step(self) -> int:
        """Advance the replica with work and the earliest clock — the
        deterministic analogue of 'whichever replica is free next'."""
        ready = [e for e in self.engines if e.queue or e.active]
        if not ready:
            return 0
        eng = min(ready, key=lambda e: (e.now_us(), self.engines.index(e)))
        return eng.step()

    def digest(self) -> str:
        items = sorted((r.rid, tuple(r.generated)) for r in self.finished)
        return hashlib.sha256(repr(items).encode()).hexdigest()

    def stats(self) -> dict:
        return {"completed": len(self.finished),
                "kv": self.kv.stats(),
                "wire_bytes": sum(e.wire_bytes for e in self.engines),
                "weight_refreshes": sum(e.weight_cache.refreshes
                                        for e in self.engines),
                "weight_hits": sum(e.weight_cache.hits
                                   for e in self.engines),
                "steps": sum(e.steps for e in self.engines)}
