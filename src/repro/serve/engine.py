"""Batched serving engine: continuous batching over a fixed slot set, with
the ownership-paged host cache for prefix sharing and weight refresh through
the colored StateCache (zero-communication when the color matches).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jaxstate import OwnedState, StateCache
from repro.models import init_cache
from repro.models.config import ModelConfig
from .kvcache import PagedKVCache
from .serve_step import make_serve_step


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    generated: list[int] = field(default_factory=list)
    done: bool = False
    pages: list = field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, weights: OwnedState, slots: int = 4,
                 max_len: int | None = None, mesh=None):
        self.cfg = cfg
        self.weights = weights
        self.slots = slots
        self.max_len = max_len or cfg.max_target_len
        self.mesh = mesh
        self.weight_cache = StateCache()            # colored read cache
        self.kv = PagedKVCache(page_size=cfg.attn_chunk)
        self._step = jax.jit(make_serve_step(cfg, mesh=mesh),
                             donate_argnums=(1,))
        self.cache = init_cache(cfg, slots, self.max_len)
        self.active: dict[int, Request] = {}        # slot -> request
        self.queue: list[Request] = []
        self.steps = 0
        self._rid = itertools.count()

    # -- client API ---------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int = 16) -> Request:
        req = Request(next(self._rid), list(prompt), max_new)
        self.queue.append(req)
        return req

    def _admit(self):
        for slot in range(self.slots):
            if slot in self.active or not self.queue:
                continue
            req = self.queue.pop(0)
            # prefix sharing: reuse sealed pages for the prompt's full pages
            ps = self.kv.page_size
            for i in range(0, max(0, len(req.prompt) - ps + 1), ps):
                page = self.kv.lookup_prefix(tuple(req.prompt[i:i + ps]))
                if page is None:
                    page = self.kv.alloc_page(tuple(req.prompt[i:i + ps]))
                    self.kv.seal(page)
                req.pages.append(self.kv.borrow(page))
            self.active[slot] = req

    # -- one decode tick across all active slots ------------------------------
    def step(self) -> int:
        self._admit()
        if not self.active:
            return 0
        params = self.weight_cache.fetch(self.weights)  # color-keyed refresh
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, req in self.active.items():
            seq = req.prompt + req.generated
            tokens[slot, 0] = seq[-1]
        nxt, self.cache = self._step(params, self.cache, jnp.asarray(tokens))
        nxt = np.asarray(nxt)
        finished = []
        for slot, req in self.active.items():
            req.generated.append(int(nxt[slot, 0]))
            if len(req.generated) >= req.max_new:
                req.done = True
                finished.append(slot)
        for slot in finished:
            req = self.active.pop(slot)
            for page in req.pages:
                self.kv.drop(page)
        self.steps += 1
        return len(self.active) + len(finished)

    def run(self, max_steps: int = 256) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_steps):
            if not self.queue and not self.active:
                break
            self.step()
        return done

    def stats(self) -> dict:
        return {"steps": self.steps, "kv": self.kv.stats(),
                "weight_refreshes": self.weight_cache.refreshes,
                "weight_hits": self.weight_cache.hits}
