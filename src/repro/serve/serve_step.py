"""Compiled serving steps: batched greedy decode + prefill.

These are the *device-side* kernels under the engine's guard surface: the
engine runs each tick inside ``cluster.region(th, prefetch=...)``, fetches
weights through the colored ``StateCache`` (a scoped immutable borrow of
the published ``OwnedState``), and only then calls the jitted step.  The
decode cache is donated by the engine's jit wrapper, so the in-place
append is the device analogue of a ``WriteGuard``: an exclusive borrow of
the owner's buffer, local write + color bump at drop, no invalidation
traffic to any replica."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward
from repro.models.config import ModelConfig


def make_serve_step(cfg: ModelConfig, mesh=None):
    """serve_step(params, cache, tokens (B,1)) -> (next_tokens, cache).

    The cache is donated by the engine's jit wrapper: the decode append is a
    mutable borrow of the owner's buffer (local write + color bump — no
    invalidation of any replica)."""

    def serve_step(params, cache, tokens):
        logits, cache = decode_step(cfg, params, cache, tokens, mesh=mesh)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step


def make_prefill(cfg: ModelConfig, mesh=None):
    """prefill(params, batch) -> (last_logits, per-position logits)."""

    def prefill(params, batch):
        logits, _ = forward(cfg, params, batch, mesh=mesh)
        return logits[:, -1, :], logits

    return prefill
