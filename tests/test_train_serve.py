"""Training + serving substrate tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import BorrowError
from repro.core.jaxstate import OwnedState, StateCache
from repro.models import init_params
from repro.train import (OptConfig, TrainState, init_opt_state,
                         make_train_step, synthetic_batches)

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen3_0_6b", **opt_kw):
    cfg = configs.smoke(arch)
    params = init_params(cfg, KEY)
    opt = OptConfig(lr=3e-3, warmup=2, decay_steps=50, **opt_kw)
    return cfg, params, opt


def test_loss_decreases():
    cfg, params, opt = _setup()
    ts = TrainState(cfg, opt, params)
    data = synthetic_batches(cfg.vocab, 8, 64)
    losses = [float(ts.step(jax.tree.map(jnp.asarray, next(data)))["loss"])
              for _ in range(12)]
    assert losses[-1] < losses[0], f"no improvement: {losses}"
    assert ts.color == 12               # one epoch per step


def test_microbatch_grads_match_full_batch():
    import dataclasses
    cfg, _, opt = _setup()
    cfg = dataclasses.replace(cfg, dtype="float32")   # bf16 hides equality
    params = init_params(cfg, KEY)
    data = synthetic_batches(cfg.vocab, 8, 32)
    batch = jax.tree.map(jnp.asarray, next(data))
    s1 = make_train_step(cfg, opt, microbatches=1)
    s4 = make_train_step(cfg, opt, microbatches=4)
    o1 = init_opt_state(opt, params)
    o4 = init_opt_state(opt, params)
    p1, _, m1 = jax.jit(s1)(params, o1, batch)
    p4, _, m4 = jax.jit(s4)(params, o4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-3)


def test_adafactor_runs_and_improves():
    cfg, params, opt = _setup(name="adafactor")
    ts = TrainState(cfg, opt, params)
    data = synthetic_batches(cfg.vocab, 8, 64)
    losses = [float(ts.step(jax.tree.map(jnp.asarray, next(data)))["loss"])
              for _ in range(10)]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_adafactor_memory_factored():
    cfg, params, _ = _setup()
    fac = init_opt_state(OptConfig(name="adafactor"), params)
    adam = init_opt_state(OptConfig(name="adamw"), params)
    bytes_fac = sum(l.size * l.dtype.itemsize
                    for l in jax.tree.leaves(fac))
    bytes_adam = sum(l.size * l.dtype.itemsize
                     for l in jax.tree.leaves(adam))
    assert bytes_fac < bytes_adam * 0.1     # factored moments are tiny


def test_backup_promotion_restores_epoch():
    cfg, params, opt = _setup()
    ts = TrainState(cfg, opt, params)
    slot = ts.replicate()
    data = synthetic_batches(cfg.vocab, 4, 32)
    ts.step(jax.tree.map(jnp.asarray, next(data)))
    good = jax.tree.leaves(ts.params())[0].copy()
    color = ts.color
    # corrupt the live buffers OUT-OF-BAND (a crash is not a write epoch —
    # a protocol-level write would legitimately become the newest backup)
    p, o = ts.state._tree
    ts.state._tree = (jax.tree.map(jnp.zeros_like, p), o)
    ts.restore_from_backup()
    restored = jax.tree.leaves(ts.params())[0]
    np.testing.assert_array_equal(np.asarray(restored, np.float32),
                                  np.asarray(good, np.float32))


def test_owned_state_borrow_rules():
    s = OwnedState("t", {"w": jnp.zeros(4)})
    r = s.borrow()
    with pytest.raises(BorrowError):
        s.borrow_mut()
    r.drop()
    with s.borrow_mut() as m:
        m.set({"w": jnp.ones(4)})
        with pytest.raises(BorrowError):
            s.read()
    assert s.color == 1


def test_state_cache_zero_comm_on_color_hit():
    s = OwnedState("t", {"w": jnp.zeros(8)})
    cache = StateCache()
    cache.fetch(s); cache.fetch(s); cache.fetch(s)
    assert cache.refreshes == 1 and cache.hits == 2
    with s.borrow_mut() as m:
        m.set({"w": jnp.ones(8)})
    cache.fetch(s)
    assert cache.refreshes == 2         # refetch only after the color bump


def test_gradient_compression_error_feedback():
    from repro.dist.compression import quantize_int8, dequantize_int8
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1024) * 0.01)
    q, scale = quantize_int8(x)
    err1 = x - dequantize_int8(q, scale)
    assert float(jnp.abs(err1).max()) <= float(scale) / 2 + 1e-9
    # error feedback: quantizing (residual + next grad) keeps bias bounded
    total = dequantize_int8(q, scale)
    q2, s2 = quantize_int8(err1 + x)
    total = total + dequantize_int8(q2, s2)
    np.testing.assert_allclose(np.asarray(total), np.asarray(2 * x),
                               atol=float(s2))


def test_serve_engine_drains_and_shares_prefixes():
    from repro.serve import ServeEngine
    cfg = configs.smoke("qwen3_0_6b")
    params = init_params(cfg, KEY)
    weights = OwnedState("w", params)
    eng = ServeEngine(cfg, weights, slots=2, max_len=128)
    rng = np.random.default_rng(0)
    prefix = list(rng.integers(0, cfg.vocab, cfg.attn_chunk))
    reqs = [eng.submit(prefix + [int(i)], max_new=4) for i in range(4)]
    steps = 0
    while eng.queue or eng.active:
        eng.step()
        steps += 1
        assert steps < 200
    assert all(r.done and len(r.generated) == 4 for r in reqs)
    st = eng.stats()
    assert st["kv"]["hits"] >= 3        # prefix page reused across requests
    assert st["weight_refreshes"] == 1  # weights never changed: one fetch


def test_kvcache_protocol_semantics():
    from repro.serve.kvcache import PagedKVCache
    kv = PagedKVCache(page_size=8, capacity_pages=8)
    p = kv.alloc_page((1, 2, 3))
    assert not p.full                   # capacity 8, 3 tokens
    c0 = p.addr.color
    kv.append(p, 4)
    assert p.addr.color == c0 + 1       # append bumps the color
    kv.seal(p)
    q = kv.lookup_prefix((1, 2, 3, 4))
    assert q is p
    kv.borrow(q); kv.borrow(q)
    with pytest.raises(BorrowError):
        kv.append(q, 5)                 # shared page: copy-on-write required
    forked = kv.fork(q)
    kv.append(forked, 5)
    kv.drop(q); kv.drop(q)
    # eviction only reclaims refcount-0 pages
    for i in range(6):
        kv.seal(kv.alloc_page((9, i)))
    assert kv.evict(10) > 0
