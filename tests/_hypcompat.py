"""Optional-dependency guard for property-based tests.

``hypothesis`` is a dev-only dependency (see requirements.txt); on machines
without it the property tests must *skip cleanly* instead of failing the
whole collection.  Import ``given``/``settings``/``st`` from here:

    from _hypcompat import HAVE_HYPOTHESIS, given, settings, st

When hypothesis is installed these are the real objects; when it is absent,
``given`` decorates the test with ``pytest.mark.skip`` and ``st`` is an
inert strategy stand-in (strategy expressions are built at module import
time, so they must not raise).

CI determinism: a ``ci`` settings profile is registered with a pinned seed
(``derandomize=True`` derives examples from the test body, so every run
generates the same schedules) and no deadline (shared runners are noisy).
The workflow selects it via ``HYPOTHESIS_PROFILE=ci``.
"""

from __future__ import annotations

import os

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True

    settings.register_profile("ci", derandomize=True, deadline=None)
    # Only honor the profile we registered — a foreign HYPOTHESIS_PROFILE
    # value (exported for some other project) must not break collection.
    if os.environ.get("HYPOTHESIS_PROFILE") == "ci":
        settings.load_profile("ci")
except ImportError:                                    # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _InertStrategies:
        """Builds inert placeholders for any strategy expression."""

        def __getattr__(self, name):
            def _make(*_a, **_k):
                return None
            return _make

    st = _InertStrategies()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
