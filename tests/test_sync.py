"""Synchronization-primitive suite: DAtomic/DMutex/DRwLock (docs/sync.md).

Covers the three escalating designs in ``core/sync.py`` — spin locks,
delegation/combining locks, and reader leases — across all three protocol
backends, plus their recovery interplay (broken convoys dispose shipped
closures exactly once; the drust unlock is a real completion-plane verb)
and the transactional kvstore satellites:

  * value semantics: DAtomic RMW ops and DMutex critical sections behave
    identically on drust/gam/grappa (only the verb costs differ);
  * delegation equivalence: ``mode="delegate"`` computes the exact same
    final counter values as ``mode="spin"`` while paying fewer round
    trips, with the makespan gap *widening* in cluster size (8 -> 64
    servers) — the scalable-synchronization acceptance criterion;
  * lease safety: a hypothesis schedule suite plus a seeded deterministic
    twin check that leased reads add zero protocol messages, at most one
    lease exists per server, no lease survives a write (the revocation
    fence), and every read observes the last write;
  * recovery: a dead home breaks its convoy and lease table (reported in
    ``RecoveryReport.broken_leases``), the orphaned closure/unlock cids
    are disposed exactly once with kind-labeled ledger entries, and the
    section a broken convoy shipped never ran;
  * kvstore: non-divisor ``nodes_per_bucket`` shapes run (the floor-
    division IndexError regression), and the multi-key transactional mix
    produces byte-identical digests across backends, completion planes,
    and lock modes;
  * the bench gate: ``check_regression.compare`` trips on lock_sweep
    makespan regressions and on exact-pin counter drift in BOTH
    directions, and stays green on an identical run.
"""

from __future__ import annotations

import copy
import random

import pytest

from _hypcompat import given, settings, st

from benchmarks import check_regression
from benchmarks.protocol_micro import _lock_run
from repro.apps.kvstore import run_kvstore
from repro.core import (Cluster, DAtomic, DMutex, DRwLock, ServerLostError,
                        addr as A)

BACKENDS = ["drust", "gam", "grappa"]


def _raw(h) -> int:
    return A.clear_color(h.g) if hasattr(h, "g") else h.raw


def _pair(backend: str, n: int = 2, **kw):
    cl = Cluster(n, backend=backend, **kw)
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0)
    t1.server = 1
    return cl, t0, t1


def _bump(obj):
    obj.data += 1
    return obj.data


def _values(cl, prims) -> list:
    return [cl.heap.get(_raw(p.h)).data for p in prims]


# --------------------------------------------------------------------------
#  Cross-backend semantics
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_datomic_semantics(backend):
    cl, t0, t1 = _pair(backend)
    a = DAtomic(cl, t0, init=5)
    assert a.fetch_add(t1, 3) == 5
    assert a.load(t0) == 8
    assert a.cas(t1, 8, 11) and not a.cas(t1, 8, 0)
    a.store(t0, 2)
    assert a.load(t1) == 2


def test_datomic_drust_uses_one_sided_atomics():
    cl, t0, t1 = _pair("drust")
    a = DAtomic(cl, t0, init=0)
    at0 = cl.sim.net.atomics
    a.fetch_add(t1)                          # remote: one-sided FAA
    assert cl.sim.net.atomics == at0 + 1
    at0 = cl.sim.net.atomics
    a.fetch_add(t0 if a.home == 0 else t1)   # home-local: no verb
    assert cl.sim.net.atomics == at0


@pytest.mark.parametrize("backend", BACKENDS)
def test_mutex_sections_all_backends(backend):
    cl, t0, t1 = _pair(backend)
    m = DMutex(cl, t0, value=0, server=0)
    assert m.with_lock(t0, _bump) == 1
    assert m.with_lock(t1, _bump) == 2
    assert cl.heap.get(_raw(m.h)).data == 2
    assert m.acquisitions == 2 and m._holder is None


@pytest.mark.parametrize("backend", BACKENDS)
def test_mutex_explicit_lock_unlock(backend):
    cl, t0, t1 = _pair(backend)
    m = DMutex(cl, t0, value=10, server=0)
    obj = m.lock(t0)
    obj.data += 1
    cl.sim.busy(t0, 50.0)                    # a long critical section
    m.unlock(t0)
    t_before = t1.t_us
    assert m.with_lock(t1, lambda o: o.data) == 11
    # the second acquirer serialized behind the first section's release
    assert m.contended == 1 and t1.t_us >= 50.0 > t_before


def test_mutex_registered_for_recovery():
    cl, t0, _ = _pair("drust")
    m = DMutex(cl, t0, value=0)
    rw = DRwLock(cl, t0, value=0)
    assert m in cl.mutexes and rw in cl.mutexes


# --------------------------------------------------------------------------
#  Delegation / combining locks
# --------------------------------------------------------------------------
def test_delegate_drust_ships_on_completion_plane():
    cl, t0, t1 = _pair("drust")
    m = DMutex(cl, t0, value=0, mode="delegate", server=0)
    net = cl.sim.net
    assert m.with_lock(t1, _bump, reads=2) == 1
    assert net.closure_ships == 1 and net.delegated_sections == 1
    assert net.convoy_completions == 1       # convoy head: one round trip
    assert m.delegated == 1 and m.convoys == 1
    assert not m._inflight                   # convoy drained
    # the home-local caller never ships — plain section
    assert m.with_lock(t0, _bump) == 2
    assert net.closure_ships == 1 and m.delegated == 1
    cl.sim.wb.fence_all(t1)
    assert not cl.sim.wb._pending


@pytest.mark.parametrize("backend", ["gam", "grappa"])
def test_delegate_two_sided_transport(backend):
    cl, t0, t1 = _pair(backend)
    m = DMutex(cl, t0, value=0, mode="delegate", server=0)
    net = cl.sim.net
    two0 = net.two_sided_msgs
    assert m.with_lock(t1, _bump, reads=1) == 1
    # request half (the ship) + response half (the convoy completion)
    assert net.two_sided_msgs == two0 + 2
    assert net.closure_ships == 1 and net.delegated_sections == 1
    assert cl.heap.get(_raw(m.h)).data == 1


def test_delegate_raising_section_propagates_and_lock_survives():
    cl, t0, t1 = _pair("drust")
    m = DMutex(cl, t0, value=0, mode="delegate", server=0)

    def boom(_obj):
        raise RuntimeError("section failed")

    with pytest.raises(RuntimeError):
        m.with_lock(t1, boom)
    assert m.with_lock(t1, _bump) == 1       # next convoy runs normally
    cl.sim.wb.fence_all(t1)


def test_delegation_equivalent_to_spin_and_gap_widens():
    """The acceptance criterion: identical critical-section results, fewer
    round trips, smaller makespan at 8+ servers — and the spin/delegate
    makespan gap WIDENS from 8 to 64 servers under zipf(0.99) skew."""
    gap = {}
    for n in (8, 64):
        cl_s, p_s = _lock_run(n, "spin")
        cl_d, p_d = _lock_run(n, "delegate")
        assert _values(cl_s, p_s) == _values(cl_d, p_d), \
            "delegation changed critical-section results"
        assert cl_d.sim.net.round_trips < cl_s.sim.net.round_trips
        assert cl_d.makespan_us() < cl_s.makespan_us()
        gap[n] = cl_s.makespan_us() / cl_d.makespan_us()
    assert gap[64] > gap[8] > 1.0, f"gap did not widen: {gap}"


def test_convoy_amortizes_round_trips():
    """N contended waiters on one delegated lock pay ~1 amortized convoy
    round trip; the same N spin waiters each pay serialized home RTs."""
    for mode in ("spin", "delegate"):
        cl = Cluster(8, backend="drust")
        boot = cl.main_thread(0)
        m = DMutex(cl, boot, value=0, mode=mode, server=0)
        boot.t_us = 0.0
        ths = []
        for s in range(1, 8):
            th = cl.main_thread(0)
            th.server = s
            ths.append(th)
        rt0 = cl.sim.net.round_trips
        for th in ths:
            m.with_lock(th, _bump, reads=2)
        if mode == "spin":
            spin_rt = cl.sim.net.round_trips - rt0
        else:
            deleg_rt = cl.sim.net.round_trips - rt0
            assert cl.heap.get(_raw(m.h)).data == 7
    assert deleg_rt < spin_rt


# --------------------------------------------------------------------------
#  Recovery interplay (satellite 2 + broken convoys)
# --------------------------------------------------------------------------
def test_drust_unlock_is_a_real_plane_verb():
    """Satellite-2 regression: the drust unlock posts a cid-bearing async
    WRITE (fire-and-forget — issue cost only), retired by a fence; it is
    no longer a bare counter bump invisible to the completion plane."""
    cl, t0, t1 = _pair("drust", batch_io=True)
    m = DMutex(cl, t0, value=0, server=0)
    aw0 = cl.sim.net.async_writebacks
    t_before = t1.t_us
    m.lock(t1)
    m.unlock(t1)
    assert cl.sim.net.async_writebacks == aw0 + 1
    assert cl.sim.wb._pending, "unlock did not ride the completion plane"
    # fire-and-forget: the release charged issue cost, not a round trip
    assert t1.t_us - t_before < cl.sim.cost.one_sided_base_us * 2
    cl.sim.wb.fence_all(t1)
    assert not cl.sim.wb._pending


def test_orphaned_unlock_disposed_exactly_once():
    """An unlock WRITE in flight to a home that then dies is disposed by
    the recovery quiesce exactly once, labeled with its verb kind."""
    cl, t0, t1 = _pair("drust", n=2, replicate=True, batch_io=True)
    m = DMutex(cl, t0, value=0, server=0)
    m.lock(t1)
    m.unlock(t1)
    cid = cl.sim.wb._max_cid                 # the unlock's completion id
    assert cid in cl.sim.wb._pending
    cl.recovery.crash(0)
    cl.recovery.fail_over(0, t1)
    assert cl.recovery.disposed[cid] == "orphaned-write"
    assert cid not in cl.sim.wb._pending
    with pytest.raises(RuntimeError):        # the exactly-once ledger
        cl.recovery._dispose(cid, "orphaned-write")


def test_broken_convoy_disposes_closure_exactly_once():
    """A closure shipped to an unresponsive home never runs (no partial
    state), its cid is disposed exactly once as ``orphaned-closure``, and
    recovery clears the convoy's cid references and breaks the lock."""
    cl, t0, t1 = _pair("drust", n=2, replicate=True, batch_io=True)
    m = DMutex(cl, t0, value=0, mode="delegate", server=0)
    cl.replicator.flush_epoch()
    cl.sim.mark_failing(0)                   # unresponsive, not yet declared
    with pytest.raises(ServerLostError):
        m.with_lock(t1, _bump, reads=1)      # retry ladder burns, then raises
    assert len(m._inflight) == 1, "ship should be pending, section aborted"
    cid = m._inflight[0]
    cl.recovery.crash(0)
    cl.recovery.fail_over(0, t1)
    assert cl.recovery.disposed[cid] == "orphaned-closure"
    assert not m._inflight and m.broken == 1
    assert cl.heap.get(_raw(m.h)).data == 0, "aborted section mutated state"
    with pytest.raises(RuntimeError):
        cl.recovery._dispose(cid, "orphaned-closure")


def test_crashed_holder_breaks_lock_and_survivor_reacquires():
    cl = Cluster(3, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0)
    t1.server = 1
    m = DMutex(cl, t0, value=0, server=0)
    cl.replicator.flush_epoch()
    m.lock(t1)                               # holder on server 1 ...
    cl.recovery.crash(1)                     # ... dies mid-section
    rep = cl.recovery.fail_over(1, t0)
    assert rep.broken_locks >= 1 and m.broken == 1 and m._holder is None
    assert m.with_lock(t0, _bump) == 1       # survivor proceeds


# --------------------------------------------------------------------------
#  Reader leases (DRwLock)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_lease_grant_once_then_zero_verbs(backend):
    cl, t0, t1 = _pair(backend)
    rw = DRwLock(cl, t0, value=("v", 0), server=0)
    net = cl.sim.net
    assert rw.get(t1) == ("v", 0)            # cold: the grant's fetch
    assert t1.server in rw._leases and net.lease_grants == 1
    rt0, m0 = net.round_trips, net.critical_path_msgs()
    for _ in range(8):                       # warm: pure local chases
        assert rw.get(t1) == ("v", 0)
    assert net.round_trips == rt0 and net.critical_path_msgs() == m0, \
        "leased reads must add zero protocol messages"
    assert net.lease_grants == 1             # still the one lease


@pytest.mark.parametrize("backend", BACKENDS)
def test_write_revokes_fences_and_regrants(backend):
    cl, t0, t1 = _pair(backend)
    rw = DRwLock(cl, t0, value=("v", 0), server=0)
    rw.get(t1)
    net = cl.sim.net
    rw.write(t0, ("v", 1))
    assert not rw._leases, "a lease survived the write"
    assert net.lease_revokes == 1 and rw.writes == 1
    assert rw.get(t1) == ("v", 1), "reader observed pre-revocation state"
    assert net.lease_grants == 2             # re-granted after the write


def test_drust_revocation_rides_the_fence():
    cl = Cluster(3, backend="drust", batch_io=True)
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0)
    t1.server = 1
    t2 = cl.main_thread(0)
    t2.server = 2
    rw = DRwLock(cl, t0, value=0, server=0)
    rw.get(t1)
    rw.get(t2)
    net = cl.sim.net
    f0, rt0 = net.fences, net.round_trips
    rw.write(t0, 1)
    assert net.fences == f0 + 1, "revocation skipped the cid fence"
    assert net.round_trips == rt0 + 1        # one completion poll, not N
    assert net.lease_revokes == 2


def test_scoped_read_and_region_lease_hint():
    cl, t0, t1 = _pair("drust")
    rw = DRwLock(cl, t0, value=("v", 7), server=0)
    with rw.read(t1) as v:
        assert v == ("v", 7)
    assert t1.server in rw._leases           # the lease outlives the scope
    rw2 = DRwLock(cl, t0, value=("w", 1), server=0)
    with cl.region(t1, lease=(rw2,)):
        assert t1.server in rw2._leases      # granted eagerly at entry
        rt0 = cl.sim.net.round_trips
        assert rw2.get(t1) == ("w", 1)
        assert cl.sim.net.round_trips == rt0
    assert t1.server in rw2._leases          # and persists past the region


def test_rwlock_home_follows_a_moving_write():
    """A remote writer's WriteGuard MOVES the value under drust — the
    lease table's home must follow the handle, not the birth partition."""
    cl, t0, t1 = _pair("drust")
    rw = DRwLock(cl, t0, value=0, server=0)
    assert rw.home == 0
    rw.write(t1, 1)
    assert rw.home == t1.server
    assert rw.get(t0) == 1


# ---- lease schedule property + seeded twin -------------------------------
def _run_lease_schedule(ops) -> None:
    """Oracle: every read observes the LAST write.  Invariants: at most one
    lease per server, no lease survives a write, leased reads add zero
    protocol messages."""
    cl = Cluster(4, backend="drust")
    ths = []
    for s in range(4):
        th = cl.main_thread(0)
        th.server = s
        ths.append(th)
    rw = DRwLock(cl, ths[0], value=("w", -1), server=3)
    net = cl.sim.net
    last = ("w", -1)
    for kind, t, p in ops:
        th = ths[t % 4]
        if kind == "write":
            last = ("w", p)
            rw.write(th, last)
            assert not rw._leases, "lease survived a write"
        else:
            leased = th.server in rw._leases
            rt0, m0 = net.round_trips, net.critical_path_msgs()
            assert rw.get(th) == last, "stale read"
            if leased:
                assert (net.round_trips, net.critical_path_msgs()) == (rt0, m0)
        assert len(rw._leases) <= 4
        assert len(set(rw._leases)) == len(rw._leases)
    for th in ths:                           # final audit from every server
        assert rw.get(th) == last
    cl.sim.wb.fence_all(ths[0])
    assert not cl.sim.wb._pending


lease_ops = st.lists(
    st.tuples(st.sampled_from(["read", "read", "read", "write"]),
              st.integers(0, 3), st.integers(0, 99)),
    min_size=0, max_size=12)


@settings(max_examples=200, deadline=None)
@given(lease_ops)
def test_lease_schedule_property(ops):
    _run_lease_schedule(ops)


def test_lease_schedules_200_seeded():
    rng = random.Random(13)
    for _ in range(200):
        ops = [(rng.choice(["read", "read", "read", "write"]),
                rng.randrange(4), rng.randrange(100))
               for _ in range(rng.randint(0, 12))]
        _run_lease_schedule(ops)


def test_rwlock_recovery_breaks_leases():
    """A dead home breaks its whole lease table; a dead leased cache
    breaks only its own lease.  Both surface in ``broken_leases`` and
    survivors re-grant against the restored value."""
    cl = Cluster(4, backend="drust", replicate=True)
    ths = []
    for s in range(4):
        th = cl.main_thread(0)
        th.server = s
        ths.append(th)
    rw_home = DRwLock(cl, ths[1], value=("a", 0), server=1)   # home dies
    rw_cache = DRwLock(cl, ths[0], value=("b", 0), server=0)  # a lease dies
    cl.replicator.flush_epoch()
    for th in (ths[0], ths[2], ths[3]):
        rw_home.get(th)
    rw_cache.get(ths[1])
    rw_cache.get(ths[2])
    cl.recovery.crash(1)
    rep = cl.recovery.fail_over(1, ths[0])
    assert rep.broken_leases == 4            # 3 home-death + 1 cache-death
    assert rw_home.broken == 1 and rw_home.broken_leases == 3
    assert not rw_home._leases
    assert 1 not in rw_cache._leases and 2 in rw_cache._leases
    assert rw_home.get(ths[0]) == ("a", 0)   # re-grant vs restored value
    assert rw_cache.get(ths[3]) == ("b", 0)


# --------------------------------------------------------------------------
#  kvstore satellites
# --------------------------------------------------------------------------
@pytest.mark.parametrize("npb", [3, 5, 7])
def test_kvstore_non_divisor_bucket_shapes(npb):
    """Regression for the floor-division bucket-count bug: any key's
    bucket must exist even when nodes_per_bucket does not divide n_keys
    (the old math raised IndexError on the tail keys)."""
    r = run_kvstore(3, "drust", n_keys=64, n_ops=150, nodes_per_bucket=npb)
    assert r.ops == 150 and r.makespan_us > 0


def test_kvstore_tail_key_lands_in_last_bucket():
    r = run_kvstore(2, "drust", n_keys=7, n_ops=80, nodes_per_bucket=3)
    assert r.ops == 80


def test_kvstore_txn_digest_identical_everywhere():
    """The transactional oracle: multi-key atomic updates produce a byte-
    identical store digest across all three backends, both completion
    planes, and both lock modes."""
    kw = dict(n_keys=96, value_bytes=64, n_ops=240, nodes_per_bucket=3,
              txn_frac=0.3)
    digests = set()
    runs = 0
    for backend in BACKENDS:
        for ooo in (False, True):
            r = run_kvstore(2, backend, ooo=ooo, **kw)
            assert r.extra["txn_ops"] > 0
            digests.add(r.extra["digest"])
            runs += 1
    r = run_kvstore(2, "drust", lock_mode="delegate", **kw)
    digests.add(r.extra["digest"])
    assert len(digests) == 1, f"{runs + 1} runs produced {len(digests)} digests"


def test_kvstore_txn_frac_zero_replays_legacy_stream():
    a = run_kvstore(2, "drust", n_keys=64, n_ops=150)
    b = run_kvstore(2, "drust", n_keys=64, n_ops=150, txn_frac=0.0)
    assert a.extra["digest"] == b.extra["digest"]
    assert a.net["round_trips"] == b.net["round_trips"]
    assert b.extra["txn_ops"] == 0


# --------------------------------------------------------------------------
#  The lock_sweep bench gate trips in both directions
# --------------------------------------------------------------------------
_LOCK_BASE = {
    "lock_sweep": {
        "spin_8srv": {"makespan_us": 100.0, "round_trips": 50, "atomics": 10},
        "delegate_8srv": {"makespan_us": 60.0, "round_trips": 20,
                          "atomics": 0, "delegated_sections": 30,
                          "convoy_completions": 5, "closure_ships": 30,
                          "spin_over_delegate": 1.67},
    }
}


def test_lock_gate_green_on_identical_run():
    cur = copy.deepcopy(_LOCK_BASE)
    assert check_regression.compare(_LOCK_BASE, cur, 0.10) == []
    # derived ratios are visible but not gated
    cur["lock_sweep"]["delegate_8srv"]["spin_over_delegate"] = 9.99
    assert check_regression.compare(_LOCK_BASE, cur, 0.10) == []


def test_lock_gate_trips_on_makespan_regression():
    cur = copy.deepcopy(_LOCK_BASE)
    cur["lock_sweep"]["delegate_8srv"]["makespan_us"] = 72.0   # +20%
    fails = check_regression.compare(_LOCK_BASE, cur, 0.10)
    assert any("lock_sweep/delegate_8srv/makespan_us" in f for f in fails)


@pytest.mark.parametrize("delta", [-1, +1])
def test_lock_gate_trips_on_counter_drift_both_directions(delta):
    cur = copy.deepcopy(_LOCK_BASE)
    cur["lock_sweep"]["delegate_8srv"]["delegated_sections"] += delta
    cur["lock_sweep"]["spin_8srv"]["round_trips"] += delta
    fails = check_regression.compare(_LOCK_BASE, cur, 0.10)
    assert any("delegated_sections" in f for f in fails)
    assert any("spin_8srv/round_trips" in f for f in fails)


def test_lock_gate_trips_on_missing_row():
    cur = copy.deepcopy(_LOCK_BASE)
    del cur["lock_sweep"]["delegate_8srv"]
    fails = check_regression.compare(_LOCK_BASE, cur, 0.10)
    assert any("delegate_8srv: missing" in f for f in fails)
