"""Runtime borrow/cid sanitizer (`Cluster(sanitize=True)`, docs/analysis.md).

Two contracts, tested in both directions:

* **Observation-only** — with the sanitizer installed, the simulated
  trajectory (makespan, net counters, payload digests) is byte-identical
  to a sanitize-off run for every app x backend.
* **It actually trips** — each violation class (payload use-after-close,
  mutation under a read borrow, guard leaks at retire, lock-order
  inversion, spec-cid double/phantom disposition) raises a structured
  ``SanitizerError`` with event provenance.
"""

from __future__ import annotations

import warnings

import pytest

from repro.analysis.sanitizer import Sanitizer, SanitizerError
from repro.core import Cluster, DMutex
from repro.apps.dataframe import run_dataframe
from repro.apps.kvstore import run_kvstore
from repro.apps.socialnet import run_socialnet

BACKENDS = ("drust", "gam", "grappa")
APPS = {
    "socialnet": (run_socialnet, dict(n_requests=40)),
    "dataframe": (run_dataframe, dict(n_ops=2)),
    "kvstore": (run_kvstore, dict(n_keys=128, n_ops=200, txn_frac=0.3)),
}


# --------------------------------------------------------------------------
#  Observation-only: byte-identical trajectories, every app x backend
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("app", sorted(APPS))
def test_apps_clean_and_byte_identical_under_sanitize(
        app, backend, monkeypatch):
    fn, kw = APPS[app]
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    r_on = fn(4, backend=backend, **kw)       # raises on any violation
    trace_len = len(Sanitizer.last.trace)
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    r_off = fn(4, backend=backend, **kw)
    assert r_on.makespan_us == r_off.makespan_us
    assert r_on.net == r_off.net
    assert r_on.extra.get("payload_digest") == r_off.extra.get(
        "payload_digest")
    if backend == "drust":
        # drust's guard surface is what the trace records; baselines in
        # socialnet route through read_many RPC (empty trace is by design).
        assert trace_len > 0


@pytest.mark.parametrize("app", sorted(APPS))
def test_apps_clean_on_the_ooo_plane_under_sanitize(app, monkeypatch):
    fn, kw = APPS[app]
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    r = fn(4, backend="drust", qps_per_thread=4, ooo=True, **kw)
    assert r.makespan_us > 0
    assert len(Sanitizer.last.trace) > 0


def test_kvstore_prefetch_spec_ledger_clean(monkeypatch):
    # Speculative prefetch under sanitize: every spec cid the runtime mints
    # must be disposed exactly once (used / wasted / dropped) — checked
    # against DrustRuntime.spec_log at makespan.
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    r = run_kvstore(4, "drust", n_keys=128, n_ops=300, prefetch_window=8,
                    sanitize=True)
    assert r.net["speculative_fetches"] > 0


# --------------------------------------------------------------------------
#  Open-guard accounting (always on, sanitize or not)
# --------------------------------------------------------------------------
def test_guard_stats_track_open_guards():
    cl = Cluster(2, backend="drust", sanitize=False)
    t0 = cl.main_thread(0)
    h = cl.backend.alloc(t0, 64, 1)
    g = h.read(t0)
    g.__enter__()
    assert cl.backend.guard_stats["open_read_guards"] == 1
    assert cl.backend.open_by_tid[t0.tid] == 1
    g.close()
    assert cl.backend.guard_stats["open_read_guards"] == 0
    assert cl.backend.open_by_tid == {}
    with h.write(t0) as w:
        assert cl.backend.guard_stats["open_write_guards"] == 1
        w.set(2)
    assert cl.backend.guard_stats["open_write_guards"] == 0


def test_retire_with_open_guard_warns_without_sanitize():
    cl = Cluster(2, backend="drust", sanitize=False)
    th = cl.scheduler.spawn(lambda t: None, server=0)
    h = cl.backend.alloc(th, 64, 1)
    g = h.read(th)
    g.__enter__()
    with pytest.warns(RuntimeWarning, match="open guard"):
        cl.scheduler.retire(th)


def test_retire_with_open_guard_raises_under_sanitize():
    cl = Cluster(2, backend="drust", sanitize=True)
    th = cl.scheduler.spawn(lambda t: None, server=0)
    h = cl.backend.alloc(th, 64, 1)
    g = h.read(th)
    g.__enter__()
    with pytest.raises(SanitizerError, match="retired with 1 live guard"):
        cl.scheduler.retire(th)


def test_clean_retire_neither_warns_nor_raises():
    cl = Cluster(2, backend="drust", sanitize=True)
    th = cl.scheduler.spawn(lambda t: None, server=0)
    h = cl.backend.alloc(th, 64, 1)
    with h.read(th):
        pass
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cl.scheduler.retire(th)


# --------------------------------------------------------------------------
#  Tombstoned payload snapshots
# --------------------------------------------------------------------------
def test_payload_use_after_close_trips():
    cl = Cluster(2, backend="drust", sanitize=True)
    t0 = cl.main_thread(0)
    h = cl.backend.alloc(t0, 64, [1, 2, 3])
    with h.read(t0) as v:
        assert v[0] == 1                      # fine while the guard is open
    with pytest.raises(SanitizerError, match="after its guard closed"):
        v[0]


def test_mutation_under_read_borrow_trips_at_close():
    cl = Cluster(2, backend="drust", sanitize=True)
    t0 = cl.main_thread(0)
    h = cl.backend.alloc(t0, 64, [1, 2, 3])
    with pytest.raises(SanitizerError, match="immutable read borrow"):
        with h.read(t0) as v:
            v.append(9)


def test_publishing_a_snapshot_through_a_write_guard_is_adopted():
    # `w.set(v)` while v's read guard is open is publication, not
    # use-after-close: the sanitizer adopts a plain copy.
    cl = Cluster(2, backend="drust", sanitize=True)
    t0 = cl.main_thread(0)
    a = cl.backend.alloc(t0, 64, [1, 2, 3])
    b = cl.backend.alloc(t0, 64, [0])
    with a.read(t0) as v:
        with b.write(t0) as w:
            w.set(v)
    assert cl.backend.read(t0, b) == [1, 2, 3]   # usable after both closed


# --------------------------------------------------------------------------
#  Lock order (lockdep) and the spec-cid ledger
# --------------------------------------------------------------------------
def test_lock_order_inversion_trips():
    cl = Cluster(2, backend="drust", sanitize=True)
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(1)
    a = DMutex(cl, t0, value=0)
    b = DMutex(cl, t0, value=0)
    a.lock(t0); b.lock(t0); b.unlock(t0); a.unlock(t0)   # order A -> B
    b.lock(t1)
    with pytest.raises(SanitizerError, match="order inverted"):
        a.lock(t1)                                        # order B -> A


def test_kvstore_txn_sorted_buckets_lockdep_clean():
    # The kvstore transactional path locks its buckets in sorted order —
    # the discipline lockdep certifies (already covered by the matrix test,
    # pinned here explicitly with locks contended across threads).
    r = run_kvstore(4, "drust", n_keys=128, n_ops=200, txn_frac=0.5,
                    sanitize=True)
    assert r.makespan_us > 0


def test_spec_cid_double_and_phantom_disposition_trip():
    cl = Cluster(2, backend="drust", sanitize=True)
    t0 = cl.main_thread(0)
    san = cl.sanitizer
    san.note_spec(t0, 42)
    san.note_spec_dispose(42, "used", True)
    with pytest.raises(SanitizerError, match="disposed twice"):
        san.note_spec_dispose(42, "used", True)
    with pytest.raises(SanitizerError, match="phantom"):
        san.note_spec_dispose(99, "wasted", True)


# --------------------------------------------------------------------------
#  Fail-over reconciliation
# --------------------------------------------------------------------------
def test_failover_reconciles_dead_threads_guards():
    # A thread that dies with its server holds an open read guard; recovery
    # force-releases the borrow and the sanitizer must agree (no leak at
    # final_check, no phantom open guard afterwards).
    cl = Cluster(3, backend="drust", replicate=True, sanitize=True)
    t0 = cl.main_thread(0)
    t2 = cl.main_thread(0)
    t2.server = 2
    box = cl.backend.alloc(t0, 64, b"x", server=0)
    cl.replicator.flush_epoch()
    g = box.read(t2)
    g.__enter__()                         # dies open with server 2
    rep = cl.recovery.fail_and_recover(2, t0)
    assert rep.released_borrows == 1
    cl.makespan_us()                      # final_check: must not raise
    assert any(e.kind == "failover" for e in cl.sanitizer.trace)
