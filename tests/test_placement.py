"""Placement subsystem: telemetry-driven live owner migration
(``Cluster(placement="auto")``), the affinity-spawn / balancing /
straggler-drain fixes it rides on, and cross-thread quantum alignment.

The three regression tests at the top fail on the pre-fix code:

  * ``spawn_to`` resolved the allocation-time home, so after an ownership
    transfer the affinity spawn landed on the *old* owner;
  * ``Thread.remote_accesses`` survived ``Scheduler.migrate`` untouched,
    so the balancer read the pre-move neighborhood and bounced the thread
    right back;
  * ``mitigate_stragglers`` re-read the (barely moving) live CPU snapshot
    per victim and herded every drained thread onto one fastest peer.
"""

from __future__ import annotations

from collections import Counter

from repro.core import Cluster, addr as A
from repro.core.runtime import PlacementPolicy


# --------------------------------------------------------------------------
#  Satellite regressions (fail on pre-fix code)
# --------------------------------------------------------------------------
def test_spawn_to_follows_ownership_transfer():
    """Affinity spawn must resolve the box's CURRENT owner location, not
    the allocation-time home partition."""
    cl = Cluster(4, backend="drust")
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"x", server=2)
    cl.backend.transfer(t0, box, 1)
    th = cl.scheduler.spawn_to(box, lambda th: th.server, parent=t0)
    assert th.server == 1, "spawn_to landed on the stale allocation home"
    assert cl.scheduler.join(th) == 1


def test_migrate_resets_stale_remote_telemetry():
    """``remote_accesses`` describes the OLD neighborhood: the destination
    entry clears (those accesses are local now) and the rest decay."""
    cl = Cluster(3, backend="drust")
    t0 = cl.main_thread(0)
    t0.remote_accesses.update({1: 500, 2: 100})
    cl.scheduler.migrate(t0, 1)
    assert 1 not in t0.remote_accesses, \
        "destination entry survived the move (thread looks remote-heavy " \
        "on the server it just moved to)"
    assert t0.remote_accesses == {2: 50}


def test_balance_does_not_bounce_migrated_thread_back():
    """Two balancing rounds: the first moves a remote-heavy thread to its
    hot server; the second (with the destination now busy) must not read
    the stale pre-move telemetry and bounce it back."""
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t0.remote_accesses[1] = 500
    cl.sim.servers[0].cpu_busy_us = 1e6
    assert cl.controller.balance(horizon_us=1e4) == 1
    assert t0.server == 1
    cl.sim.servers[0].cpu_busy_us = 0.0
    cl.sim.servers[1].cpu_busy_us = 1e6          # round 2: dst is the hot one
    cl.controller.balance(horizon_us=1e4)
    assert t0.server == 1, "stale telemetry ping-ponged the thread back"


def test_straggler_drain_spreads_across_peers():
    """Draining N threads off a straggler with M healthy peers must spread
    them by projected load, not herd all N onto the single fastest peer."""
    cl = Cluster(6, backend="drust")
    ths = []
    for _ in range(4):
        th = cl.main_thread(0)
        th.server = 5
        ths.append(th)
    # Distinct standing loads: a per-victim re-read of the live snapshot
    # keeps electing server 0 (migration itself barely moves cpu_busy_us);
    # only projected-load accounting spreads the drain.
    for s, busy in enumerate((10.0, 50.0, 100.0, 150.0, 200.0, 800.0)):
        cl.sim.servers[s].cpu_busy_us = busy
    cl.sim.degrade(5, 8.0)
    assert cl.controller.detect_stragglers() == [5]
    assert cl.controller.mitigate_stragglers() == 4
    dsts = Counter(t.server for t in ths)
    assert 5 not in dsts
    assert max(dsts.values()) == 1, \
        f"drained threads herded onto one peer: {dict(dsts)}"


# --------------------------------------------------------------------------
#  locate / site semantics
# --------------------------------------------------------------------------
def test_locate_tracks_transfer_then_write_move():
    cl = Cluster(4, backend="drust")
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"v", server=0)
    assert cl.backend.locate(box) == 0
    cl.backend.transfer(t0, box, 2)
    assert cl.backend.locate(box) == 2
    t1 = cl.main_thread(0)
    t1.server = 1
    cl.backend.write(t1, box, b"w")              # write-move relocates
    assert A.server_of(box.g) == 1
    assert box.site is None, "payload relocation must drop the site override"
    assert cl.backend.locate(box) == 1


def test_protocol_backends_locate_by_home():
    """Non-ownership backends have no transfers: locate is the home."""
    cl = Cluster(4, backend="gam")
    t0 = cl.main_thread(0)
    h = cl.backend.alloc(t0, 64, b"v", server=3)
    assert cl.backend.locate(h) == 3


# --------------------------------------------------------------------------
#  Live owner migration (DrustRuntime.migrate_here)
# --------------------------------------------------------------------------
def test_migrate_here_moves_tbox_group_and_respects_borrows():
    cl = Cluster(4, backend="drust")
    t0 = cl.main_thread(0)
    root = cl.backend.alloc(t0, 64, b"r", server=0)
    child = cl.backend.alloc(t0, 256, b"c", tie_to=root)
    t1 = cl.main_thread(0)
    t1.server = 1
    w = root.write(t0)
    w.__enter__()
    assert cl.drust.migrate_here(t1, root) is False, \
        "migration ran under a live mutable borrow"
    w.set(b"r2")
    w.__exit__(None, None, None)
    assert cl.drust.migrate_here(t1, root) is True
    assert A.server_of(root.g) == 1
    assert A.server_of(child.g) == 1, "tied child left behind by the move"
    assert cl.sim.net.owner_migrations == 1
    assert cl.sim.net.migration_round_trips >= 1
    assert cl.backend.read(t1, root) == b"r2"
    assert cl.backend.read(t1, child) == b"c"


def test_migrate_here_noop_when_already_local():
    cl = Cluster(4, backend="drust")
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"v", server=0)
    cl.backend.transfer(t0, box, 2)              # stale site override
    assert cl.drust.migrate_here(t0, box) is False
    assert box.site is None and cl.backend.locate(box) == 0
    assert cl.sim.net.owner_migrations == 0


def test_auto_placement_dominant_reader_pulls_ownership():
    cl = Cluster(4, backend="drust", placement="auto")
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"v", server=0)
    t1 = cl.main_thread(0)
    t1.server = 1
    for _ in range(3):                           # min_weight=3 reads
        with box.read(t1):
            pass
    assert cl.sim.net.owner_migrations == 1
    assert cl.backend.locate(box) == 1
    assert A.server_of(box.g) == 1


def test_auto_placement_cooldown_hysteresis():
    """A box rests ``cooldown`` epochs after a move; the next dominant
    accessor only pulls it after a quantum boundary."""
    cl = Cluster(4, backend="drust", placement="auto")
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"v", server=0)
    t1 = cl.main_thread(0)
    t1.server = 1
    t2 = cl.main_thread(0)
    t2.server = 2
    for _ in range(3):
        with box.read(t1):
            pass
    assert cl.sim.net.owner_migrations == 1
    for _ in range(4):                           # same epoch: cooldown holds
        with box.read(t2):
            pass
    assert cl.sim.net.owner_migrations == 1, "box ping-ponged inside cooldown"
    cl.close_quanta()                            # epoch boundary
    for _ in range(4):
        with box.read(t2):
            pass
    assert cl.sim.net.owner_migrations == 2
    assert cl.backend.locate(box) == 2


def test_auto_placement_suppressed_during_recovery_quiesce():
    cl = Cluster(4, backend="drust", replicate=True, placement="auto")
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"v", server=0)
    t1 = cl.main_thread(0)
    t1.server = 1
    cl.recovery.quiescing = True
    for _ in range(6):
        with box.read(t1):
            pass
    assert cl.sim.net.owner_migrations == 0, "placement churn mid fail-over"
    cl.recovery.quiescing = False
    for _ in range(3):
        with box.read(t1):
            pass
    assert cl.sim.net.owner_migrations == 1


def test_auto_placement_requires_dominance_not_presence():
    """Two comparably hot servers: neither dominates 2x, nobody moves."""
    cl = Cluster(4, backend="drust",
                 placement_policy=PlacementPolicy(), placement="auto")
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"v", server=0)
    t1 = cl.main_thread(0)
    t1.server = 1
    t2 = cl.main_thread(0)
    t2.server = 2
    for _ in range(5):                           # interleaved: no 2x winner
        with box.read(t1):
            pass
        with box.read(t2):
            pass
    assert cl.sim.net.owner_migrations == 0
    assert A.server_of(box.g) == 0


def test_placement_rejected_on_non_ownership_backend():
    import pytest
    with pytest.raises(RuntimeError):
        Cluster(2, backend="gam", placement="auto")
    with pytest.raises(ValueError):
        Cluster(2, backend="drust", placement="wat")


# --------------------------------------------------------------------------
#  Cross-thread quantum alignment
# --------------------------------------------------------------------------
def test_sibling_same_destination_derefs_merge_at_flush():
    cl = Cluster(4, backend="drust", coalesce="auto", placement="auto")
    boot = cl.main_thread(0)
    a = cl.backend.alloc(boot, 256, b"a", server=2)
    b = cl.backend.alloc(boot, 256, b"b", server=2)
    c = cl.backend.alloc(boot, 256, b"c", server=3)
    t1 = cl.main_thread(0)
    t1.server = 1
    t2 = cl.main_thread(0)
    t2.server = 1
    assert cl.backend.read(t1, a) == b"a"        # registered, pending
    assert cl.backend.read(t2, b) == b"b"        # sibling, same destination
    assert cl.backend.read(t2, c) == b"c"        # sibling, other destination
    co = cl.drust.coalescer
    assert co.align and len(co.pending) == 2
    co.flush(t1)
    assert cl.sim.net.quantum_merges == 1, \
        "sibling same-destination deref did not join the doorbell"
    # t2's quantum kept only the unmergeable destination
    assert len(co.pending) == 1
    (_, items), = co.pending.values()
    assert [bx for bx, _ in items] == [c]
    co.flush(t2)
    assert cl.sim.net.quantum_merges == 1
    # end state identical to independent flushes: both payloads warm
    assert a.g in cl.drust.caches[1].entries
    assert b.g in cl.drust.caches[1].entries


def test_quantum_merge_off_by_default():
    cl = Cluster(4, backend="drust", coalesce="auto")
    boot = cl.main_thread(0)
    a = cl.backend.alloc(boot, 256, b"a", server=2)
    b = cl.backend.alloc(boot, 256, b"b", server=2)
    t1 = cl.main_thread(0)
    t1.server = 1
    t2 = cl.main_thread(0)
    t2.server = 1
    cl.backend.read(t1, a)
    cl.backend.read(t2, b)
    cl.drust.coalescer.flush(t1)
    assert cl.sim.net.quantum_merges == 0
    assert len(cl.drust.coalescer.pending) == 1  # t2 flushes on its own


# --------------------------------------------------------------------------
#  Placement-guided spawn
# --------------------------------------------------------------------------
def test_spawn_near_weighted_plurality():
    cl = Cluster(4, backend="drust", placement="auto")
    t0 = cl.main_thread(0)
    hs = [cl.backend.alloc(t0, 64, i, server=s)
          for i, s in enumerate((2, 2, 3))]
    th = cl.scheduler.spawn_near(hs, lambda th: th.server, parent=t0)
    assert th.server == 2
    assert cl.placement.spawn_hint(hs) == 2
    assert cl.placement.spawn_hint([]) is None


# --------------------------------------------------------------------------
#  The placement_sweep bench gate trips in both directions
# --------------------------------------------------------------------------
import copy

import pytest

from benchmarks import check_regression

_PLACEMENT_BASE = {
    "placement_sweep": {
        "socialnet_spread_8srv": {
            "makespan_us": 128.0, "round_trips": 619,
            "owner_migrations": 0, "migration_round_trips": 0,
            "quantum_merges": 0, "digest": 12345},
        "socialnet_auto_8srv": {
            "makespan_us": 102.0, "round_trips": 369,
            "owner_migrations": 41, "migration_round_trips": 75,
            "quantum_merges": 223, "digest": 12345,
            "best_static_makespan_us": 120.6,
            "best_static_round_trips": 484,
            "auto_beats_static": True},
    }
}


def test_placement_gate_green_on_identical_run():
    cur = copy.deepcopy(_PLACEMENT_BASE)
    assert check_regression.compare(_PLACEMENT_BASE, cur, 0.10) == []
    # derived best-static columns are visible but not gated
    cur["placement_sweep"]["socialnet_auto_8srv"][
        "best_static_makespan_us"] = 999.0
    assert check_regression.compare(_PLACEMENT_BASE, cur, 0.10) == []


def test_placement_gate_trips_on_makespan_regression():
    cur = copy.deepcopy(_PLACEMENT_BASE)
    cur["placement_sweep"]["socialnet_auto_8srv"]["makespan_us"] = 122.4
    fails = check_regression.compare(_PLACEMENT_BASE, cur, 0.10)
    assert any("placement_sweep/socialnet_auto_8srv/makespan_us" in f
               for f in fails)


@pytest.mark.parametrize("delta", [-1, +1])
def test_placement_gate_trips_on_migration_drift_both_directions(delta):
    """The migration counters are pinned EXACTLY: migrating more than the
    baseline (churn) fails just like migrating less (a dead trigger)."""
    cur = copy.deepcopy(_PLACEMENT_BASE)
    cur["placement_sweep"]["socialnet_auto_8srv"]["owner_migrations"] += delta
    cur["placement_sweep"]["socialnet_auto_8srv"][
        "migration_round_trips"] += delta
    cur["placement_sweep"]["socialnet_auto_8srv"]["quantum_merges"] += delta
    cur["placement_sweep"]["socialnet_spread_8srv"]["round_trips"] += delta
    fails = check_regression.compare(_PLACEMENT_BASE, cur, 0.10)
    assert any("socialnet_auto_8srv/owner_migrations" in f for f in fails)
    assert any("socialnet_auto_8srv/migration_round_trips" in f
               for f in fails)
    assert any("socialnet_auto_8srv/quantum_merges" in f for f in fails)
    assert any("socialnet_spread_8srv/round_trips" in f for f in fails)


def test_placement_gate_trips_when_auto_stops_beating_static():
    cur = copy.deepcopy(_PLACEMENT_BASE)
    cur["placement_sweep"]["socialnet_auto_8srv"][
        "auto_beats_static"] = False
    fails = check_regression.compare(_PLACEMENT_BASE, cur, 0.10)
    assert any("auto_beats_static flipped false" in f for f in fails)


def test_placement_gate_trips_on_missing_row():
    cur = copy.deepcopy(_PLACEMENT_BASE)
    del cur["placement_sweep"]["socialnet_auto_8srv"]
    fails = check_regression.compare(_PLACEMENT_BASE, cur, 0.10)
    assert any("socialnet_auto_8srv: missing" in f for f in fails)
