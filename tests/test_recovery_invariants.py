"""Crash-consistency property suite: server failure at ANY schedule point.

Random schedules interleave reads, writes (local and moving), epoch
flushes, int8 checkpoints, speculative prefetch, ownership transfer,
drops, and synchronization ops (spin locks with fire-and-forget unlock
verbs, delegated lock convoys, reader-lease reads/writes) over a small
box population spread across 4 servers; then a server is crashed at an
arbitrary step and failed over.  After recovery the invariants below
must hold:

  * Epoch-Revert, Never-Resurrect: a box homed on the dead server reads
    back exactly its last *flushed* version (falling back to the last
    int8 checkpoint, else it is ``lost`` and raises ``ServerLostError``)
    — never a dirty pre-crash version served from a warm cache, and never
    a stale replica at a moved-away address.  Boxes homed on survivors
    read their current version.
  * Exactly-Once Disposition: every completion id orphaned by the crash
    is disposed exactly once — the ``RecoveryManager`` ledger raises on a
    double disposition, disposed cids are gone from the completion plane,
    and every speculative cid in ``spec_log`` is ``fenced`` or
    ``invalidated`` (the PR-4 discipline survives fail-over).
  * No Leaked State: after recovery no box carries a live borrow (dead
    threads' borrows were force-released through the per-tid ledger), the
    surviving boxes accept fresh writes and drops, and the completion
    plane fully drains.
  * Lock-State Reconstruction: no mutex is left held by a dead thread,
    a dead home's delegated convoy drops its closure-cid references (the
    quiesce disposed them — exactly once, like the in-flight unlock
    write-backs), leases never outlive their server or their home, and
    survivors can keep locking/leasing after fail-over.

Each property runs twice: hypothesis-generated (200 examples, crash point
drawn per schedule, derandomized under the CI profile) and a seeded
deterministic twin that crashes EVERY schedule at EVERY step (200
schedules x every prefix), so the full crash lattice is exercised even
without hypothesis.
"""

from __future__ import annotations

import random

import pytest

from _hypcompat import given, settings, st

from repro.core import Cluster, DMutex, DRwLock, ServerLostError, addr as A

N_SERVERS = 4
N_BOXES = 6

KINDS = ["read", "read", "write", "write", "flush", "checkpoint",
         "prefetch", "transfer", "drop",
         "lock", "dlock", "rwread", "rwwrite"]

LOST = object()          # oracle marker: no replica, no checkpoint


def _make(qps: int = 2, ooo: bool = True):
    cl = Cluster(N_SERVERS, backend="drust", replicate=True,
                 qps_per_thread=qps, ooo=ooo)
    ths = []
    for s in range(N_SERVERS):
        th = cl.main_thread(0)
        th.server = s
        ths.append(th)
    return cl, ths


def run_crash_schedule(ops, dead: int, crash_at: int,
                       qps: int = 2, ooo: bool = True) -> None:
    """Apply ``ops[:crash_at]``, crash ``dead``, fail over, and audit every
    crash-consistency invariant (module docstring)."""
    cl, ths = _make(qps, ooo)
    rt = cl.drust
    boxes = [cl.backend.alloc(ths[i % N_SERVERS], 256, ("v", i, 0),
                              server=i % N_SERVERS)
             for i in range(N_BOXES)]
    cur = [0] * N_BOXES               # latest version
    flushed = [None] * N_BOXES        # last version in the replica map
    ckpt = [None] * N_BOXES           # last version in the int8 checkpoint
    # Synchronization plane: homes spread so a random crash exercises
    # holder-death, home-death, and leased-cache-death cases.
    mspin = DMutex(cl, ths[1], value=0, mode="spin", server=1)
    mdel = DMutex(cl, ths[2], value=0, mode="delegate", server=2)
    rw = DRwLock(cl, ths[3], value=("rw", -1), server=3)

    for kind, t, o, p in ops[:crash_at]:
        th, i = ths[t % N_SERVERS], o % N_BOXES
        box = boxes[i]
        if kind == "lock":
            # spin section; the drust unlock is a fire-and-forget WRITE
            # on the completion plane (a cid recovery must dispose)
            mspin.with_lock(th, lambda obj: obj)
            continue
        if kind == "dlock":
            mdel.with_lock(th, lambda obj: obj, reads=1)
            continue
        if kind == "rwread":
            rw.get(th)
            continue
        if kind == "rwwrite":
            rw.write(th, ("rw", p))
            continue
        if box.dropped:
            continue
        if kind == "read":
            assert cl.backend.read(th, box) == ("v", i, cur[i])
        elif kind == "write":
            raw_before = A.clear_color(box.g)
            cur[i] += 1
            cl.backend.write(th, box, ("v", i, cur[i]))
            if A.clear_color(box.g) != raw_before:
                # remote write moved the object: the replica followed
                # (flushed version still restorable) but the checkpoint
                # entry stays behind in the old partition's image
                ckpt[i] = None
        elif kind == "flush":
            cl.replicator.flush_epoch()
            for j, b in enumerate(boxes):
                if not b.dropped:
                    flushed[j] = cur[j]
        elif kind == "checkpoint":
            cl.replicator.checkpoint_epoch()
            for j, b in enumerate(boxes):
                if not b.dropped:
                    ckpt[j] = cur[j]
        elif kind == "prefetch":
            rt.prefetch(th, [box])
        elif kind == "transfer":
            rt.transfer(th, box, p % N_SERVERS)   # visibility point: flushes
            flushed[i] = cur[i]
        elif kind == "drop":
            rt.drop_box(th, box)

    # ---- the crash, at this exact schedule point ------------------------
    driver = ths[(dead + 1) % N_SERVERS]
    cl.recovery.crash(dead)
    report = cl.recovery.fail_over(dead, driver)
    assert report.server == dead and report.makespan_us >= 0.0

    # ---- epoch-revert / never-resurrect ---------------------------------
    for i, box in enumerate(boxes):
        if box.dropped:
            continue
        home = A.server_of(A.clear_color(box.g))
        if home == dead:
            expect = (flushed[i] if flushed[i] is not None
                      else ckpt[i] if ckpt[i] is not None else LOST)
        else:
            expect = cur[i]
        if expect is LOST:
            assert box.lost
            with pytest.raises(ServerLostError):
                cl.backend.read(driver, box)
        else:
            assert cl.backend.read(driver, box) == ("v", i, expect), \
                f"box {i} (home {home}, dead {dead}): wrong epoch served"

    # ---- exactly-once disposition ---------------------------------------
    # (a double disposition raises inside fail_over; audit the residue)
    assert not (set(cl.recovery.disposed) & set(cl.sim.wb._pending)), \
        "a disposed cid is still on the completion plane"
    assert len(rt.spec_cids) == len(set(rt.spec_cids))
    for how in rt.spec_log.values():
        assert how in ("fenced", "invalidated")

    # ---- no leaked borrows / locks; survivors stay fully usable ---------
    for i, box in enumerate(boxes):
        if box.dropped:
            continue
        assert box.live_refs == 0 and not box.ref_tids, "leaked read borrow"
        assert not box.live_mut and box.mut_tid is None, "leaked write borrow"
        if not box.lost:
            cur[i] += 1
            cl.backend.write(driver, box, ("v", i, cur[i]))
            assert cl.backend.read(driver, box) == ("v", i, cur[i])
            rt.drop_box(driver, box)
            assert box.dropped

    # ---- lock-state reconstruction --------------------------------------
    for m in (mspin, mdel):
        h = m._holder
        assert h is None or (not h.done and h.server != dead), \
            "lock left held by a dead thread"
    if A.server_of(A.clear_color(mdel.h.g)) == dead:
        assert not mdel._inflight, "orphaned convoy kept closure cids"
    for s in rw._leases:
        assert s != dead, "lease outlived its server"
    for m in (mspin, mdel):           # survivors keep locking
        if cl.heap.contains(A.clear_color(m.h.g)):
            m.with_lock(driver, lambda obj: obj)
            assert m._holder is None
    if not rw.h.lost and cl.heap.contains(A.clear_color(rw.h.g)):
        rw.write(driver, ("rw", "post"))
        assert rw.get(driver) == ("rw", "post")
    cl.sim.wb.fence_all(driver)
    assert not cl.sim.wb._pending, "completion plane leaked pending verbs"


crash_ops = st.lists(
    st.tuples(st.sampled_from(KINDS),
              st.integers(0, N_SERVERS - 1),
              st.integers(0, N_BOXES - 1),
              st.integers(0, N_SERVERS - 1)),
    min_size=0, max_size=10)


@settings(max_examples=200, deadline=None)
@given(crash_ops, st.integers(0, N_SERVERS - 1), st.integers(0, 10),
       st.sampled_from([1, 2]), st.booleans())
def test_crash_at_any_point_property(ops, dead, crash_at, qps, ooo):
    run_crash_schedule(ops, dead, min(crash_at, len(ops)), qps, ooo)


def test_crash_at_every_point_200_seeded_schedules():
    """Deterministic twin: 200 seeded schedules, each crashed at EVERY
    prefix (including before the first op), so the whole crash lattice is
    covered even without hypothesis."""
    rng = random.Random(11)
    for _ in range(200):
        qps = rng.choice([1, 2])
        ooo = rng.random() < 0.5
        dead = rng.randrange(N_SERVERS)
        ops = [(rng.choice(KINDS), rng.randrange(N_SERVERS),
                rng.randrange(N_BOXES), rng.randrange(N_SERVERS))
               for _ in range(rng.randint(0, 10))]
        for k in range(len(ops) + 1):
            run_crash_schedule(ops, dead, k, qps, ooo)


def test_no_failure_path_is_undisturbed():
    """Control: the same machinery with zero failures — every box reads its
    current version, no recovery counters move, the plane drains."""
    rng = random.Random(7)
    cl, ths = _make()
    rt = cl.drust
    boxes = [cl.backend.alloc(ths[i % N_SERVERS], 256, ("v", i, 0),
                              server=i % N_SERVERS) for i in range(N_BOXES)]
    cur = [0] * N_BOXES
    for _ in range(60):
        i = rng.randrange(N_BOXES)
        th = ths[rng.randrange(N_SERVERS)]
        if rng.random() < 0.5:
            cur[i] += 1
            cl.backend.write(th, boxes[i], ("v", i, cur[i]))
        else:
            assert cl.backend.read(th, boxes[i]) == ("v", i, cur[i])
        if rng.random() < 0.2:
            cl.replicator.flush_epoch()
    net = cl.sim.net
    assert net.orphaned_cids == 0 and net.rehomed_boxes == 0
    assert net.lost_writes == 0 and net.broken_locks == 0
    assert net.suspect_invalidations == 0 and net.degraded_retries == 0
    assert net.recovery_makespan_us == 0.0
    assert not cl.recovery.disposed and not cl.recovery.reports
    cl.sim.wb.fence_all(ths[0])
    assert not cl.sim.wb._pending


def test_double_disposition_raises():
    """The recovery ledger is the exactly-once authority: feeding it the
    same cid twice is a protocol bug and must raise, not double-count."""
    cl, _ = _make()
    cl.recovery._dispose(42, "orphaned-write")
    with pytest.raises(RuntimeError):
        cl.recovery._dispose(42, "orphaned-read")


def test_makespan_scales_with_working_set_not_cluster_size():
    """The recovery SLO: fail-over cost is dominated by streaming the dead
    server's working set — growing the CLUSTER at fixed working set moves
    the makespan far less than growing the WORKING SET at fixed cluster."""
    def makespan(n_servers: int, n_boxes: int, size: int = 4096) -> float:
        cl = Cluster(n_servers, backend="drust", replicate=True)
        th0 = cl.main_thread(0)
        t1 = cl.main_thread(0); t1.server = 1
        for _ in range(n_boxes):
            cl.backend.alloc(t1, size, b"x" * size, server=1)
        cl.replicator.flush_epoch()
        rep = cl.recovery.fail_and_recover(1, th0)
        assert rep.restored_bytes == n_boxes * size
        return rep.makespan_us

    base = makespan(4, 16)
    wide = makespan(16, 16)          # 4x the servers, same working set
    heavy = makespan(4, 256)         # same servers, 16x the working set
    assert heavy > 4 * base          # working set dominates ...
    assert wide < 4 * base           # ... cluster size barely registers
