"""Property-based fence-correctness suite for the multi-QP completion plane.

Random schedules of post/fence/drain across threads and QPs drive the
``WritebackQueue`` completion-id machinery; after every operation the
invariants below must hold:

  * Fence-Correctness: ``fence(th, upto)`` retires *exactly* the pending
    verbs with ``cid <= upto`` and blocks ``th`` until the latest of their
    completion times; verbs posted later stay in flight.
  * Transfer-Dependency: an ownership transfer never observes a write-back
    whose completion id it depends on as incomplete (the box's recorded
    cids are retired and the fencing thread's clock covers them).
  * Makespan-Monotonicity: ``makespan_us`` is monotone in write-back depth
    — posting more verbs can only extend the completion floor.
  * In-Order-CQ: with ``qps_per_thread=1`` completions are strictly ordered
    (``ooo_completions == 0``); inversions require sibling QPs.

Each property runs twice: hypothesis-generated (200 schedules for the
fence suite, derandomized under the CI profile — see ``_hypcompat``) and a
seeded deterministic sweep that executes on machines without hypothesis.

The suite also pins the *degenerate-config equivalence*: with
``qps_per_thread=1`` and reordering disabled the new completion plane must
reproduce PR-1's round-trip/makespan numbers exactly on the socialnet and
dataframe traces (all three backends, both I/O planes) — golden values in
``tests/data/net_golden_pr1.json`` were captured from the PR-1 plane.
"""

from __future__ import annotations

import json
import os
import random

import pytest

from _hypcompat import given, settings, st

from repro.core import Cluster

N_SERVERS = 4
N_THREADS = 4
NEW_COUNTERS = ("fences", "fenced_verbs", "ooo_completions", "qp_switches")


def make(qps: int, ooo: bool = True, n_servers: int = N_SERVERS):
    cl = Cluster(n_servers, backend="drust", qps_per_thread=qps, ooo=ooo)
    ths = []
    for i in range(N_THREADS):
        th = cl.main_thread(0)
        th.server = i % n_servers
        ths.append(th)
    return cl, ths


# --------------------------------------------------------------------------
#  Fence correctness over raw post/fence/drain schedules
# --------------------------------------------------------------------------
def run_fence_schedule(ops, qps: int, ooo: bool = True) -> None:
    """Execute a schedule and check the fence invariants after every op.

    ``ops`` is a list of (kind, thread_idx, param): posts pick a destination
    from ``param``; fences pick which pending cid to fence up to."""
    cl, ths = make(qps, ooo)
    wb = cl.sim.wb
    live: dict[int, float] = {}          # shadow: pending cid -> done_us
    for kind, t, p in ops:
        th = ths[t % N_THREADS]
        if kind in ("post", "post_big"):
            nbytes = 8 if kind == "post" else 16384
            cid = wb.post(th, 1 + p % (N_SERVERS - 1), nbytes)
            live[cid] = wb._pending[cid].done_us
        elif kind == "fence":
            cids = sorted(wb._pending)
            upto = cids[p % len(cids)] if cids else 0
            expected = [c for c in cids if c <= upto]
            exp_t = max((live[c] for c in expected), default=0.0)
            t_before = th.t_us
            wb.fence(th, upto)
            for c in expected:           # retired: exactly the <= upto set
                assert c not in wb._pending
                live.pop(c, None)
            assert set(wb._pending) == set(live), "fence retired a later cid"
            assert th.t_us >= max(t_before, exp_t) - 1e-9
        elif kind == "fence_all":
            exp_t = max(live.values(), default=0.0)
            t_before = th.t_us
            wb.fence_all(th)
            assert not wb._pending
            assert th.t_us >= max(t_before, exp_t) - 1e-9
            live.clear()
        assert wb.pending_completion_us == max(live.values(), default=0.0)
        assert cl.makespan_us() >= wb.pending_completion_us - 1e-9
    if qps == 1:
        assert cl.sim.net.ooo_completions == 0, "single QP completes in order"
    wb.fence_all(ths[0])                 # every verb is eventually retired
    assert not wb._pending


FENCE_KINDS = ["post", "post", "post_big", "fence", "fence", "fence_all"]

fence_ops = st.lists(
    st.tuples(st.sampled_from(FENCE_KINDS),
              st.integers(0, N_THREADS - 1),
              st.integers(0, 7)),
    min_size=1, max_size=40)


@settings(max_examples=200, deadline=None)
@given(fence_ops, st.sampled_from([1, 2, 4]))
def test_fence_correctness_property(ops, qps):
    run_fence_schedule(ops, qps)


def test_fence_correctness_200_seeded_schedules():
    """Deterministic twin of the hypothesis suite: 200 seeded random
    schedules, so the property is exercised even without hypothesis."""
    rng = random.Random(0)
    for _ in range(200):
        qps = rng.choice([1, 2, 4])
        ooo = rng.random() < 0.8
        ops = [(rng.choice(FENCE_KINDS), rng.randrange(N_THREADS),
                rng.randrange(8))
               for _ in range(rng.randint(1, 40))]
        run_fence_schedule(ops, qps, ooo)


# --------------------------------------------------------------------------
#  Transfer-dependency: ownership transfers fence their own cids
# --------------------------------------------------------------------------
def run_ownership_schedule(ops, qps: int, ooo: bool) -> None:
    cl, ths = make(qps, ooo)
    wb = cl.sim.wb
    boxes = [cl.backend.alloc(ths[i % N_THREADS], 64, ("init", i))
             for i in range(3)]
    dep_cids: dict[int, list[tuple[int, float]]] = {0: [], 1: [], 2: []}
    for kind, s, o in ops:
        th, box = ths[s % N_THREADS], boxes[o % 3]
        if kind == "write":
            before = set(wb._pending)
            cl.backend.write(th, box, (s, o))
            for c in set(wb._pending) - before:
                dep_cids[o % 3].append((c, wb._pending[c].done_us))
        elif kind == "read":
            cl.backend.read(th, box)
        elif kind == "transfer":
            # every dep cid ever attached to the box must be covered — also
            # the ones another thread's fence already swept (their retired
            # completion times still gate this transfer)
            deps = list(dep_cids[o % 3])
            cl.drust.transfer(th, box, (s + 1) % N_SERVERS)
            for c, d in deps:
                assert c not in wb._pending, \
                    "transfer observed a dependent write-back as incomplete"
                assert th.t_us >= d - 1e-9, \
                    "transfer did not wait for a dependent completion"
            assert box.wb_cids == []
            dep_cids[o % 3] = []
    wb.fence_all(ths[0])
    assert not wb._pending


ownership_ops = st.lists(
    st.tuples(st.sampled_from(["write", "write", "read", "transfer"]),
              st.integers(0, N_THREADS - 1),
              st.integers(0, 2)),
    min_size=1, max_size=50)


@settings(max_examples=100, deadline=None)
@given(ownership_ops, st.sampled_from([1, 2, 4]), st.booleans())
def test_transfer_dependency_property(ops, qps, ooo):
    run_ownership_schedule(ops, qps, ooo)


def test_transfer_dependency_seeded_schedules():
    rng = random.Random(1)
    kinds = ["write", "write", "read", "transfer"]
    for _ in range(100):
        qps = rng.choice([1, 2, 4])
        ooo = rng.random() < 0.5
        ops = [(rng.choice(kinds), rng.randrange(N_THREADS), rng.randrange(3))
               for _ in range(rng.randint(1, 50))]
        run_ownership_schedule(ops, qps, ooo)


def test_transfer_leaves_unrelated_later_verbs_in_flight():
    """The fence is scoped: verbs posted after the transferred box's last
    dependent cid survive the transfer."""
    cl, ths = make(qps=2)
    t0 = ths[0]
    box = cl.backend.alloc(ths[1], 64, 0)        # home: server 1
    cl.backend.write(t0, box, 1)                 # dep cid on box
    unrelated = cl.sim.wb.post(t0, 2, 4096)      # posted later, no dep
    cl.drust.transfer(t0, box, 1)
    assert unrelated in cl.sim.wb._pending       # still in flight
    assert box.wb_cids == []
    cl.sim.wb.fence_all(t0)


# --------------------------------------------------------------------------
#  Makespan monotone in write-back depth
# --------------------------------------------------------------------------
def check_makespan_monotone(posts, qps: int, ooo: bool) -> None:
    cl = Cluster(N_SERVERS, backend="drust", qps_per_thread=qps, ooo=ooo)
    th = cl.main_thread(0)
    prev = cl.makespan_us()
    for dst, nbytes in posts:
        cl.sim.wb.post(th, 1 + dst % (N_SERVERS - 1), nbytes)
        span = cl.makespan_us()
        assert span >= prev - 1e-9, "makespan shrank with write-back depth"
        prev = span


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, N_SERVERS - 2),
                          st.sampled_from([8, 512, 16384])),
                min_size=1, max_size=40),
       st.sampled_from([1, 2, 4]), st.booleans())
def test_makespan_monotone_property(posts, qps, ooo):
    check_makespan_monotone(posts, qps, ooo)


def test_makespan_monotone_seeded():
    rng = random.Random(2)
    for _ in range(100):
        posts = [(rng.randrange(N_SERVERS - 1),
                  rng.choice([8, 512, 16384]))
                 for _ in range(rng.randint(1, 40))]
        check_makespan_monotone(posts, rng.choice([1, 2, 4]),
                                rng.random() < 0.5)


# --------------------------------------------------------------------------
#  QP-sweep acceptance at 8 servers
# --------------------------------------------------------------------------
def test_multiqp_improves_makespan_at_8_servers():
    """Acceptance: at 8 servers with ``qps_per_thread=4`` and out-of-order
    completions enabled, makespan improves over the single-QP plane while
    round-trip counts are unchanged.  Uses the exact trace the benchmark
    sweep measures (``protocol_micro._qp_wb_run``) so the acceptance test
    can never desynchronize from the benchmarked workload."""
    from benchmarks.protocol_micro import _qp_wb_run
    single, _ = _qp_wb_run(qps=1, depth=56)
    multi, _ = _qp_wb_run(qps=4, depth=56)
    assert multi.makespan_us() < single.makespan_us()
    assert (multi.sim.net.round_trips == single.sim.net.round_trips)
    assert (multi.sim.net.async_writebacks
            == single.sim.net.async_writebacks == 56)


def test_single_qp_completions_in_order_even_with_mixed_sizes():
    cl = Cluster(4, backend="drust", ooo=True, qps_per_thread=1)
    t0 = cl.main_thread(0)
    for i in range(20):
        cl.sim.wb.post(t0, 1 + i % 3, 16384 if i % 3 == 0 else 8)
    assert cl.sim.net.ooo_completions == 0
    dones = [v.done_us for v in cl.sim.wb._pending.values()]
    assert dones == sorted(dones)        # strictly CQ-ordered


def test_mixed_sizes_reorder_across_sibling_qps():
    cl = Cluster(4, backend="drust", ooo=True, qps_per_thread=2)
    t0 = cl.main_thread(0)
    for i in range(20):
        cl.sim.wb.post(t0, 1 + i % 3, 16384 if i % 2 == 0 else 8)
    assert cl.sim.net.ooo_completions > 0
    assert cl.sim.net.qp_switches > 0


# --------------------------------------------------------------------------
#  Degenerate-config equivalence vs the PR-1 plane (golden fixture)
# --------------------------------------------------------------------------
GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "net_golden_pr1.json")
with open(GOLDEN_PATH) as f:
    GOLDEN = json.load(f)

APP_KW = {
    "socialnet": dict(n_requests=120),
    "dataframe": dict(n_columns=4, chunks_per_column=8, n_ops=4,
                      use_tbox=True),
}


@pytest.mark.parametrize("key", sorted(GOLDEN))
def test_degenerate_plane_reproduces_pr1(key):
    # coalesce="manual" pins the PR-1 choreography the goldens were
    # captured from; the runtime coalescer (coalesce="auto") is covered by
    # the equivalence tests in test_apps.py.
    from repro.apps.dataframe import run_dataframe
    from repro.apps.socialnet import run_socialnet
    app, backend, mode = key.split("/")
    fn = run_socialnet if app == "socialnet" else run_dataframe
    r = fn(4, backend, batch_io=(mode == "batched"), coalesce="manual",
           qps_per_thread=1, ooo=False, **APP_KW[app])
    g = GOLDEN[key]
    assert r.makespan_us == pytest.approx(g["makespan_us"], rel=1e-9), \
        f"{key}: makespan drifted from the PR-1 plane"
    for k, v in g["net"].items():        # byte-identical NetStats traffic
        assert r.net[k] == v, f"{key}: NetStats[{k}] {r.net[k]} != {v}"
    for k in NEW_COUNTERS:               # new machinery is inert when off
        assert r.net[k] == 0, f"{key}: {k} nonzero in degenerate config"
