"""Batched I/O plane tests: doorbell coalescing, async write-back
pipelining, cache raw-index/bytes-counter consistency, CLOCK eviction, and
batched-vs-unbatched equivalence of protocol state."""

import copy

import pytest

from repro.core import Cluster, addr as A
from repro.core.ownership import _clone


def make(n=4, **kw):
    cl = Cluster(n, backend="drust", **kw)
    ths = []
    for s in range(n):
        th = cl.main_thread(0)
        th.server = s
        ths.append(th)
    return cl, ths


# --------------------------------------------------------------------------
#  IOBatch doorbell semantics
# --------------------------------------------------------------------------
def test_iobatch_one_doorbell_per_server_direction():
    cl, (t0, *_) = make(4)
    batch = cl.sim.batch()
    for _ in range(5):
        batch.add_read(1, 100)
    for _ in range(3):
        batch.add_read(2, 100)
    batch.add_write(3, 64)
    net0 = copy.deepcopy(cl.sim.net)
    batch.commit(t0)
    net = cl.sim.net
    assert net.one_sided_reads - net0.one_sided_reads == 2     # 2 read doorbells
    assert net.one_sided_writes - net0.one_sided_writes == 1
    assert net.round_trips - net0.round_trips == 3
    assert net.doorbell_batches - net0.doorbell_batches == 3
    assert net.batched_verbs - net0.batched_verbs == 9
    assert net.bytes_moved - net0.bytes_moved == 5 * 100 + 3 * 100 + 64


def test_iobatch_latency_overlaps_across_servers():
    """Doorbells to distinct servers fly concurrently: latency is close to
    one base latency, far below the sum of N sequential verbs."""
    cl, (t0, *_) = make(4)
    base = cl.sim.cost.one_sided_base_us
    batch = cl.sim.batch()
    for s in (1, 2, 3):
        batch.add_read(s, 0)
    lat = batch.commit(t0)
    assert lat < 2 * base                       # ~1 base + issue costs
    assert lat >= base


def test_batched_group_fetch_one_round_trip():
    """Acceptance: a TBox group of N children costs 1 coalesced READ in
    round_trips under the batched plane, N under the naive plane."""
    for batch_io, expect in ((True, 1), (False, 8)):
        cl, (t0, t1, *_) = make(2, batch_io=batch_io)
        prev, head = None, None
        for _ in range(8):
            prev = cl.backend.alloc(t0, 64, b"c", tie_to=prev)
            head = head or prev
        rt0 = cl.sim.net.round_trips
        cl.backend.read(t1, head)
        assert cl.sim.net.round_trips - rt0 == expect


# --------------------------------------------------------------------------
#  read_many equivalence
# --------------------------------------------------------------------------
def _cache_state(cl):
    """Comparable snapshot of every cache: colored key -> (refcount, payload)."""
    out = []
    for H in cl.drust.caches:
        part = cl.drust.heap.partitions[H.server]
        out.append({g: (e.refcount,
                        part.get(e.local).data if part.contains(e.local) else None)
                    for g, e in H.entries.items()})
    return out


def test_read_many_matches_sequential_reads():
    def build(batch_io):
        cl, ths = make(4, batch_io=batch_io)
        boxes = [cl.backend.alloc(ths[i % 3], 64, ("v", i)) for i in range(12)]
        return cl, ths, boxes

    cl_b, ths_b, boxes_b = build(True)
    vals_b = cl_b.backend.read_many(ths_b[3], boxes_b)
    cl_u, ths_u, boxes_u = build(False)
    vals_u = [cl_u.backend.read(ths_u[3], b) for b in boxes_u]

    assert vals_b == vals_u
    assert _cache_state(cl_b) == _cache_state(cl_u)
    # same verbs coalesced: one doorbell per source server, fewer round trips
    assert cl_b.sim.net.round_trips < cl_u.sim.net.round_trips
    assert cl_b.sim.net.batched_verbs >= 12 - 4      # all cold misses coalesced


def test_read_many_mixed_hits_and_duplicates():
    cl, ths = make(3)
    b0 = cl.backend.alloc(ths[0], 64, "a")
    b1 = cl.backend.alloc(ths[1], 64, "b")
    local = cl.backend.alloc(ths[2], 64, "c")
    cl.backend.read(ths[2], b0)                      # warm one of them
    vals = cl.backend.read_many(ths[2], [b0, b1, local, b1])
    assert vals == ["a", "b", "c", "b"]
    for H in cl.drust.caches:                        # all pins released
        for g, e in H.entries.items():
            assert e.refcount == 0


def test_read_many_on_baselines_matches_sequential():
    for backend in ("gam", "grappa"):
        cl = Cluster(3, backend=backend)
        t0 = cl.main_thread(0)
        t2 = cl.main_thread(0); t2.server = 2
        hs = [cl.backend.alloc(t0, 128, bytes([i]) * 8) for i in range(6)]
        vals = cl.backend.read_many(t2, hs)
        assert vals == [bytes([i]) * 8 for i in range(6)]
        cl2 = Cluster(3, backend=backend, batch_io=False)
        t0 = cl2.main_thread(0)
        t2 = cl2.main_thread(0); t2.server = 2
        hs2 = [cl2.backend.alloc(t0, 128, bytes([i]) * 8) for i in range(6)]
        vals2 = cl2.backend.read_many(t2, hs2)
        assert vals2 == vals
        assert cl.sim.net.round_trips < cl2.sim.net.round_trips


# --------------------------------------------------------------------------
#  Async write-back pipeline
# --------------------------------------------------------------------------
def test_writeback_off_critical_path_but_in_makespan():
    cl, (t0, t1, *_) = make(2)
    box = cl.backend.alloc(t1, 64, 0, server=1)
    cl.backend.write(t0, box, 1)                     # move to server 0
    rt0 = cl.sim.net.round_trips
    t_before = t0.t_us
    cl.backend.write(t0, box, 2)                     # local write + async wb
    assert cl.sim.net.round_trips == rt0             # nothing synchronous
    assert cl.sim.net.async_writebacks >= 1
    # issue cost is tiny compared to a full verb latency
    assert t0.t_us - t_before < cl.sim.cost.one_sided_base_us
    # ...but the completion still bounds the makespan
    assert cl.makespan_us() >= cl.sim.wb.pending_completion_us > 0


def test_transfer_fences_writeback_queue():
    cl, (t0, t1, *_) = make(2)
    box = cl.backend.alloc(t1, 64, 0, server=1)
    cl.backend.write(t0, box, 1)
    cl.backend.write(t0, box, 2)
    assert cl.sim.wb.pending_completion_us > 0
    cl.drust.transfer(t0, box, 1)
    assert cl.sim.wb.pending_completion_us == 0.0    # drained at the fence
    assert cl.sim.net.wb_drains >= 1
    assert t0.t_us > 0


def test_writeback_correctness_after_drop():
    """Owner sees the written value regardless of wb completion timing."""
    cl, (t0, t1, *_) = make(2)
    box = cl.backend.alloc(t0, 64, 10)
    m = box.borrow_mut(t1)
    m.deref_mut(t1)
    cl.drust.heap.get(A.clear_color(m.g)).data = 11
    m.drop(t1)
    assert cl.backend.read(t0, box) == 11


# --------------------------------------------------------------------------
#  Cache index + CLOCK eviction
# --------------------------------------------------------------------------
def test_cache_raw_index_and_bytes_counter_consistent():
    cl, (t0, t1, *_) = make(2)
    boxes = [cl.backend.alloc(t0, 100 + i, bytes(100 + i)) for i in range(6)]
    for b in boxes:
        cl.backend.read(t1, b)
    H = cl.drust.caches[1]
    assert H.bytes_cached == sum(100 + i for i in range(6))
    assert set(H._by_raw) == {A.clear_color(g) for g in H.entries}
    # invalidate one raw address: O(1) removal keeps both structures in sync
    raw = A.clear_color(boxes[0].g)
    assert H.invalidate_raw(raw) == 1
    assert raw not in H._by_raw
    assert H.bytes_cached == sum(100 + i for i in range(1, 6))
    # drop an owner: dealloc-time scrub also maintains the counter
    cl.backend.free(t0, boxes[1])
    assert H.bytes_cached == sum(100 + i for i in range(2, 6))
    # full eviction zeroes the counter and the index
    H.evict_unreferenced()
    assert H.bytes_cached == 0 and not H.entries and not H._by_raw


def test_clock_eviction_second_chance_and_pins():
    cl, (t0, t1, *_) = make(2)
    boxes = [cl.backend.alloc(t0, 128, bytes(128)) for _ in range(8)]
    for b in boxes:
        cl.backend.read(t1, b)
    H = cl.drust.caches[1]
    pin = boxes[0].borrow(t1)
    pin.deref(t1)                                    # refcount 1: unevictable
    # first sweep only clears ref bits for unpinned entries...
    freed = cl.drust.evict_caches(1, target_bytes=3 * 128)
    assert freed >= 3 * 128
    assert A.clear_color(boxes[0].g) in H._by_raw    # pinned entry survived
    # evict everything evictable: pinned entry still survives
    cl.drust.evict_caches(1, target_bytes=1 << 30)
    assert len(H.entries) == 1
    assert H.bytes_cached == 128
    pin.drop(t1)


def test_cache_insert_remove_roundtrip_counter():
    cl, (t0, t1, *_) = make(2)
    b = cl.backend.alloc(t0, 64, b"x")
    cl.backend.read(t1, b)
    H = cl.drust.caches[1]
    g = next(iter(H.entries))
    e = H.remove(g)
    assert e is not None
    assert H.bytes_cached == 0 and not H._by_raw


# --------------------------------------------------------------------------
#  _clone fast path
# --------------------------------------------------------------------------
def test_clone_fast_path_avoids_deepcopy(monkeypatch):
    import repro.core.ownership as O

    def boom(*a, **k):                               # pragma: no cover
        raise AssertionError("deepcopy called on a fast-path payload")

    monkeypatch.setattr(O._copy, "deepcopy", boom)
    data = list(range(100))
    out = _clone(data)
    assert out == data and out is not data
    d = {i: str(i) for i in range(50)}
    out = _clone(d)
    assert out == d and out is not d
    t = (1, 2.5, "x", b"y", None)
    assert _clone(t) == t
    import numpy as np
    arr = np.arange(10.0)
    out = _clone(arr)
    assert (out == arr).all() and out is not arr


def test_clone_falls_back_for_nested():
    nested = [[1, 2], {"a": [3]}]
    out = _clone(nested)
    assert out == nested
    out[0].append(9)
    assert nested[0] == [1, 2]                       # genuine deep copy


# --------------------------------------------------------------------------
#  App-level acceptance: batched round-trip reduction, identical state
# --------------------------------------------------------------------------
def test_socialnet_batched_roundtrips_halved():
    from repro.apps.socialnet import run_socialnet
    on = run_socialnet(4, "drust", n_requests=80, batch_io=True)
    off = run_socialnet(4, "drust", n_requests=80, batch_io=False)
    assert off.net["round_trips"] >= 2 * on.net["round_trips"]
    assert on.net["bytes_moved"] == off.net["bytes_moved"]


def test_dataframe_batched_roundtrips_halved_with_tbox():
    from repro.apps.dataframe import run_dataframe
    kw = dict(n_columns=4, chunks_per_column=8, n_ops=4, use_tbox=True)
    on = run_dataframe(4, "drust", batch_io=True, **kw)
    off = run_dataframe(4, "drust", batch_io=False, **kw)
    assert off.net["round_trips"] >= 2 * on.net["round_trips"]
    assert on.net["bytes_moved"] == off.net["bytes_moved"]
