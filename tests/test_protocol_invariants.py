"""Property-based verification of the coherence lemmas (Appendix C).

Hypothesis generates random *borrow-checker-legal* programs over a small
cluster: interleaved reads/writes/borrows/transfers from threads on
different servers.  Invariants checked after every operation:

  * Data-Value: every read returns the latest written value (sequential
    consistency of the single-owner history).
  * Global-Address-Change-on-Write: the colored address after a write epoch
    differs from every address any reader previously observed.
  * Stale-Value-Elimination: cache lookups never serve a payload older than
    the last write.
  * Refcount sanity: live immutable borrows == cache refcounts, no leaks.
"""

from __future__ import annotations

from _hypcompat import given, settings, st

from repro.core import Cluster, addr as A

N_SERVERS = 4

op_strategy = st.lists(
    st.tuples(
        st.sampled_from(["read", "write", "owner_read", "owner_write",
                         "transfer", "epoch_read", "read_many"]),
        st.integers(0, N_SERVERS - 1),      # acting thread/server
        st.integers(0, 2),                  # which object
    ),
    min_size=1, max_size=60)


@settings(max_examples=60, deadline=None)
@given(op_strategy)
def test_data_value_invariant(ops):
    cl = Cluster(N_SERVERS, backend="drust")
    ths = []
    for s in range(N_SERVERS):
        th = cl.main_thread(0)
        th.server = s
        ths.append(th)
    boxes = [cl.backend.alloc(ths[0], 64, ("init", i)) for i in range(3)]
    latest = [("init", i) for i in range(3)]
    seen_addrs: list[set] = [set() for _ in range(3)]
    version = 0

    for kind, s, o in ops:
        th, box = ths[s], boxes[o]
        if kind in ("read", "epoch_read"):
            val = cl.backend.read(th, box)          # Ref path (Alg. 4)
            assert val == latest[o], "Data-Value invariant violated"
            seen_addrs[o].add(box.g)
        elif kind == "read_many":
            vals = cl.backend.read_many(th, boxes)  # doorbell-batched path
            assert vals == latest, "Data-Value invariant violated (batched)"
            for i, b in enumerate(boxes):
                seen_addrs[i].add(b.g)
        elif kind == "owner_read":
            val = cl.drust.owner_read(th, box)      # owner path (Alg. 7)
            assert val == latest[o], "Data-Value invariant violated"
            seen_addrs[o].add(box.g)
        elif kind in ("write", "owner_write"):
            version += 1
            latest[o] = ("v", version)
            prev_addrs = set(seen_addrs[o])
            if kind == "write":
                cl.backend.write(th, box, latest[o])    # MutRef (Alg. 6)
            else:
                cl.drust.owner_write(th, box, data=latest[o])  # Alg. 8
            # Global-Address-Change-on-Write: a previously observed colored
            # address may only alias the fresh value if every stale cached
            # copy under it has been scrubbed (B.4 invalidation on move:
            # address recycling is safe exactly because of that scrub).
            if box.g in prev_addrs:
                for H in cl.drust.caches:
                    e = H.entries.get(box.g)
                    if e is not None:
                        part = cl.drust.heap.partitions[H.server]
                        assert (not part.contains(e.local)
                                or part.get(e.local).data == latest[o]), \
                            "stale cache copy survived an aliasing write"
        elif kind == "transfer":
            cl.drust.transfer(th, box, (s + 1) % N_SERVERS)

    # final sweep: every thread must observe the latest values
    for o, box in enumerate(boxes):
        for th in ths:
            assert cl.backend.read(th, box) == latest[o]


@settings(max_examples=40, deadline=None)
@given(op_strategy)
def test_refcounts_balanced(ops):
    cl = Cluster(N_SERVERS, backend="drust")
    ths = []
    for s in range(N_SERVERS):
        th = cl.main_thread(0)
        th.server = s
        ths.append(th)
    boxes = [cl.backend.alloc(ths[0], 64, i) for i in range(3)]

    for kind, s, o in ops:
        th, box = ths[s], boxes[o]
        if kind == "read_many":
            cl.backend.read_many(th, boxes)
        elif kind.endswith("read"):
            r = box.borrow(th)
            r.deref(th)
            r.drop(th)
        elif kind.endswith("write"):
            m = box.borrow_mut(th)
            m.deref_mut(th)
            m.drop(th)

    # all borrows returned: every cache entry must have refcount 0
    for H in cl.drust.caches:
        for g, e in H.entries.items():
            assert e.refcount == 0, f"leaked refcount on {g:#x}"
    for box in boxes:
        assert box.live_refs == 0 and not box.live_mut


def test_batched_plane_preserves_coherence_deterministic():
    """Non-property version (runs even without hypothesis): interleaved
    batched group fetches, writes, and pipelined write-backs must keep the
    Data-Value and Stale-Value-Elimination lemmas intact."""
    cl = Cluster(N_SERVERS, backend="drust")
    ths = []
    for s in range(N_SERVERS):
        th = cl.main_thread(0)
        th.server = s
        ths.append(th)
    head = cl.backend.alloc(ths[0], 64, ("h", 0))
    c1 = cl.backend.alloc(ths[0], 64, ("c", 1), tie_to=head)
    c2 = cl.backend.alloc(ths[0], 64, ("c", 2), tie_to=c1)
    # batched group fetch on server 1, then the whole group moves on write
    assert cl.backend.read_many(ths[1], [head, c1, c2]) == \
        [("h", 0), ("c", 1), ("c", 2)]
    cl.backend.write(ths[2], head, ("h", 1))         # move + async write-back
    assert cl.backend.read(ths[1], head) == ("h", 1)  # no stale value
    assert cl.backend.read_many(ths[3], [c2, c1]) == [("c", 2), ("c", 1)]
    cl.backend.write(ths[1], c1, ("c", 9))
    assert cl.backend.read_many(ths[3], [head, c1, c2]) == \
        [("h", 1), ("c", 9), ("c", 2)]
    for H in cl.drust.caches:                        # every pin released
        for g, e in H.entries.items():
            assert e.refcount == 0, f"leaked refcount on {g:#x}"
    cl.backend.free(ths[0], head)                    # drops the tied closure
    for box in (head, c1, c2):
        raw = A.clear_color(box.g)
        assert not cl.drust.heap.contains(raw)
        for H in cl.drust.caches:
            assert raw not in H._by_raw


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, N_SERVERS - 1), min_size=2, max_size=30))
def test_swmr_single_location(writers):
    """After any write sequence the object exists at exactly one address."""
    cl = Cluster(N_SERVERS, backend="drust")
    ths = []
    for s in range(N_SERVERS):
        th = cl.main_thread(0)
        th.server = s
        ths.append(th)
    box = cl.backend.alloc(ths[0], 64, 0)
    for i, s in enumerate(writers):
        cl.backend.write(ths[s], box, i)
    raw = A.clear_color(box.g)
    homes = [p.contains(raw) for p in cl.drust.heap.partitions]
    assert sum(homes) == 1
    assert homes[A.server_of(box.g)]
