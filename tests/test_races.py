"""Trace-based coherence race certifier (`repro.analysis.races`).

Positive direction: real app traces (recorded by ``Cluster(sanitize=True)``)
certify — every conflicting access is ordered by a recorded ownership edge.
Negative direction: the certifier *provably trips* on an injected coherence
bug, both live (``Sanitizer.inject_stale_reads`` forces the runtime to
serve a replica as if from before its epoch bump) and by trace surgery
(rewriting one recorded epoch / interleaving conflicting opens).
"""

from __future__ import annotations

import dataclasses

import pytest

from repro.analysis.races import Certificate, RaceError, certify
from repro.analysis.sanitizer import Event, Sanitizer
from repro.core import Cluster
from repro.apps.dataframe import run_dataframe
from repro.apps.kvstore import run_kvstore
from repro.apps.socialnet import run_socialnet

APPS = {
    "socialnet": (run_socialnet, dict(n_requests=40)),
    "dataframe": (run_dataframe, dict(n_ops=2)),
    "kvstore": (run_kvstore, dict(n_keys=128, n_ops=200, txn_frac=0.3)),
}


def _trace(app, backend, **plane):
    fn, kw = APPS[app]
    fn(4, backend=backend, **kw, **plane)
    return list(Sanitizer.last.trace)


# --------------------------------------------------------------------------
#  Clean traces certify, on every backend and both completion planes
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ("drust", "gam", "grappa"))
@pytest.mark.parametrize("app", sorted(APPS))
def test_apps_certify(app, backend, monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cert = certify(_trace(app, backend))
    assert isinstance(cert, Certificate)
    if backend == "drust":
        assert cert.reads > 0 and cert.edges > 0


@pytest.mark.parametrize("app", sorted(APPS))
def test_apps_certify_on_the_ooo_plane(app, monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cert = certify(_trace(app, "drust", qps_per_thread=4, ooo=True))
    assert cert.reads > 0 and cert.edges > 0


def test_baseline_socialnet_trace_is_empty_by_design(monkeypatch):
    # gam/grappa socialnet pass references through channels and fetch via
    # read_many RPC — no guard surface, so the ownership trace is empty
    # and certification is (correctly) trivial.  The guard machinery the
    # certifier exercises is drust's differentiator in this app.
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    tr = _trace("socialnet", "gam")
    assert tr == []
    assert certify(tr).events == 0


# --------------------------------------------------------------------------
#  Injected coherence bug: replica served after its epoch bump
# --------------------------------------------------------------------------
def test_live_injection_trips():
    cl = Cluster(2, backend="drust", sanitize=True)
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(1)
    h = cl.backend.alloc(t0, 4096, {"n": 0})
    with h.write(t0) as w:
        w.set({"n": 1})                       # epoch bump
    cl.backend.transfer(t0, h, 1)             # the ownership edge
    cl.sanitizer.inject_stale_reads = 1       # next read observes epoch-1
    with h.read(t1):
        pass
    cl.makespan_us()
    with pytest.raises(RaceError, match="stale replica"):
        certify(cl.sanitizer.trace)


def test_without_injection_the_same_run_certifies():
    cl = Cluster(2, backend="drust", sanitize=True)
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(1)
    h = cl.backend.alloc(t0, 4096, {"n": 0})
    with h.write(t0) as w:
        w.set({"n": 1})
    cl.backend.transfer(t0, h, 1)
    with h.read(t1):
        pass
    cl.makespan_us()
    cert = certify(cl.sanitizer.trace)
    assert cert.edges >= 2                    # transfer + epoch acquire


def test_trace_surgery_stale_epoch_trips(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    tr = _trace("dataframe", "drust")
    idx = next(i for i, e in enumerate(tr)
               if e.kind == "read_open" and e.epoch > 0)
    tr[idx] = dataclasses.replace(tr[idx], epoch=tr[idx].epoch - 1)
    with pytest.raises(RaceError, match="stale replica"):
        certify(tr)
    # evidence carries the offending event
    try:
        certify(tr)
    except RaceError as err:
        assert any(e.seq == tr[idx].seq for e in err.events)


# --------------------------------------------------------------------------
#  Synthetic traces: the certifier's conflict rules in isolation
# --------------------------------------------------------------------------
def _ev(seq, kind, tid, key=1, epoch=0, src=None):
    return Event(seq, kind, tid, key, epoch, float(seq), src, "")


def test_synthetic_read_during_open_write_trips():
    tr = [_ev(0, "write_open", 1),
          _ev(1, "read_open", 2)]
    with pytest.raises(RaceError, match="conflicting open guards"):
        certify(tr)


def test_synthetic_write_during_open_read_trips():
    tr = [_ev(0, "read_open", 1),
          _ev(1, "write_open", 2)]
    with pytest.raises(RaceError, match="conflicting open guards"):
        certify(tr)


def test_synthetic_phantom_epoch_trips():
    tr = [_ev(0, "read_open", 1, epoch=3)]
    with pytest.raises(RaceError, match="phantom epoch"):
        certify(tr)


def test_synthetic_ordered_handoff_certifies():
    # writer bumps the epoch and releases; the reader observes the new
    # epoch (the recorded ownership edge) and acquires — certified.
    tr = [_ev(0, "write_open", 1),
          _ev(1, "write_close", 1, epoch=1),
          _ev(2, "read_open", 2, epoch=1),
          _ev(3, "read_close", 2)]
    cert = certify(tr)
    assert cert.writes == 1 and cert.reads == 1 and cert.edges == 1


def test_synthetic_failover_settles_dead_guards():
    tr = [_ev(0, "read_open", 1),
          _ev(1, "failover", -1),
          _ev(2, "write_open", 2),           # dead reader's guard settled
          _ev(3, "write_close", 2, epoch=1)]
    assert certify(tr).writes == 1
