"""DSM-backed serving plane: protocol semantics, admission, open-loop
load, digest equivalence across cluster sizes, and the serving SLO gate.

Everything except the two real-model tests runs with a deterministic stub
decode function, so these tests exercise the protocol + queueing behavior
on virtual clocks only (no jit, no model)."""

import numpy as np
import pytest

from repro.core import BorrowError, Cluster
from repro.core.jaxstate import OwnedState
from repro.serve import (OpenLoopDriver, PagedKVCache, ServeEngine,
                         ServeFleet, bursty_trace, poisson_trace,
                         synth_prompts)


def stub_step(params, cache, tokens):
    return (tokens * 7 + 3) % 256, cache


def make_engine(cluster=None, **kw):
    kw.setdefault("page_size", 4)
    kw.setdefault("slots", 4)
    kw.setdefault("max_len", 64)
    return ServeEngine(step_fn=stub_step, cluster=cluster, **kw)


def run_to_drain(eng, max_steps=5000):
    for _ in range(max_steps):
        if not eng.queue and not eng.active:
            return
        eng.step()
    raise AssertionError("engine did not drain")


# --------------------------------------------------------------------------
#  KV cache edge cases
# --------------------------------------------------------------------------
def test_page_full_is_live():
    kv = PagedKVCache(page_size=3)
    p = kv.alloc_page((1, 2))
    assert not p.full
    kv.append(p, 3)
    assert p.full                       # wired to page_size now
    with pytest.raises(BorrowError):
        kv.append(p, 4)                 # append must respect fullness
    with pytest.raises(ValueError):
        kv.alloc_page((1, 2, 3, 4))     # overflow rejected at alloc too


def test_evict_under_borrow_skips_pinned_pages():
    kv = PagedKVCache(page_size=4, capacity_pages=8)
    pinned = kv.retain(kv.alloc_page((1, 2)))
    free = kv.alloc_page((3, 4))
    assert kv.evict(10) == 1            # only the unreferenced page goes
    assert pinned.addr.name in kv.pages
    assert free.addr.name not in kv.pages
    # a page mid-append (mut borrow) is not evictable either
    pinned.refcount = 0
    pinned.mut_borrowed = True
    assert kv.evict(10) == 0
    pinned.mut_borrowed = False
    assert kv.evict(10) == 1


def test_evict_frees_dsm_box():
    cl = Cluster(2, backend="drust")
    th = cl.main_thread(0)
    kv = PagedKVCache(page_size=4, cluster=cl, th=th)
    p = kv.alloc_page((1, 2))
    box = p.box
    assert box is not None and not box.dropped
    assert kv.evict(1) == 1
    assert box.dropped                  # eviction drops the protocol object


def test_capacity_pressure_with_all_pages_pinned():
    kv = PagedKVCache(page_size=4, capacity_pages=2)
    kv.retain(kv.alloc_page((1,)))
    kv.retain(kv.alloc_page((2,)))
    with pytest.raises(MemoryError):
        kv.alloc_page((3,))


def test_fork_copy_on_write_refcounts():
    kv = PagedKVCache(page_size=8)
    p = kv.alloc_page((1, 2, 3))
    kv.seal(p)
    kv.retain(p); kv.retain(p)          # two requests share the page
    assert p.refcount == 2
    with pytest.raises(BorrowError):
        kv.append(p, 4)                 # shared: copy-on-write required
    forked = kv.fork(p)                 # writer's ref migrates to the fork
    assert forked.refcount == 1
    assert p.refcount == 1              # the other reader keeps its ref
    kv.append(forked, 4)
    assert forked.tokens == (1, 2, 3, 4)
    assert p.tokens == (1, 2, 3)        # original never mutated


def test_stale_prefix_entry_scrubbed_after_color_bump():
    kv = PagedKVCache(page_size=8)
    p = kv.alloc_page((1, 2))
    c0 = p.addr.color
    # An index snapshot taken before a write epoch: the colored address it
    # records stops naming these bytes once an append bumps the color.
    kv.prefix_index[(1, 2)] = p.addr.name
    assert kv.lookup_prefix((1, 2)) is p
    kv.append(p, 3)
    assert p.addr.color == c0 + 1
    misses0 = kv.misses
    assert kv.lookup_prefix((1, 2)) is None   # stale -> miss
    assert kv.misses == misses0 + 1
    assert (1, 2) not in kv.prefix_index      # and scrubbed
    # entry pointing at an evicted page scrubs the same way
    kv.seal(p)
    kv.pages.pop(p.addr.name)
    assert kv.lookup_prefix((1, 2, 3)) is None
    assert (1, 2, 3) not in kv.prefix_index


def test_peek_prefix_has_no_side_effects():
    kv = PagedKVCache(page_size=4)
    p = kv.alloc_page((1, 2))
    kv.seal(p)
    h0, m0 = kv.hits, kv.misses
    assert kv.peek_prefix((1, 2)) is p
    assert kv.peek_prefix((9, 9)) is None
    kv.prefix_index[(7, 7)] = "gone"
    assert kv.peek_prefix((7, 7)) is None
    assert (7, 7) in kv.prefix_index          # no scrub either
    assert (kv.hits, kv.misses) == (h0, m0)


def test_append_is_exclusive_and_guard_scoped():
    cl = Cluster(2, backend="drust")
    th = cl.main_thread(0)
    kv = PagedKVCache(page_size=8, cluster=cl, th=th)
    p = kv.alloc_page((1,))
    kv.append(p, 2)
    with p.box.read(th) as v:
        assert tuple(v) == (1, 2)             # write-back landed
    kv.freeze(p)
    with pytest.raises(BorrowError):
        kv.append(p, 3)


def test_reclaim_chain_frees_tied_closure():
    cl = Cluster(2, backend="drust")
    th = cl.main_thread(0)
    kv = PagedKVCache(page_size=2, cluster=cl, th=th)
    root = kv.alloc_page((1, 2), local=True)
    mid = kv.alloc_page((3, 4), tie_to=root, local=True)
    tail = kv.alloc_page((5,), tie_to=mid, local=True)
    boxes = [root.box, mid.box, tail.box]
    kv.reclaim_chain([root, mid, tail])
    assert all(b.dropped for b in boxes)      # one root drop, whole closure
    assert not kv.pages


# --------------------------------------------------------------------------
#  Engine admission
# --------------------------------------------------------------------------
def test_admission_slot_reuse_and_queue_drain():
    eng = make_engine(slots=2)
    reqs = [eng.submit([i, i + 1, i + 2], max_new=3) for i in range(7)]
    max_active = 0
    for _ in range(500):
        if not eng.queue and not eng.active:
            break
        eng.step()
        max_active = max(max_active, len(eng.active))
    assert max_active == 2                    # never exceeds the slot count
    assert not eng.queue and not eng.active   # queue fully drained
    assert all(r.done and len(r.generated) == 3 for r in reqs)
    assert len(eng.finished) == 7


def test_admission_max_len_truncation():
    eng = make_engine(max_len=16)
    req = eng.submit(list(range(30)), max_new=8)
    assert len(req.prompt) == 8               # head-truncated to fit budget
    assert req.prompt == list(range(22, 30))  # keeps the recent context
    with pytest.raises(ValueError):
        eng.submit([1, 2], max_new=99)        # max_new alone exceeds max_len
    run_to_drain(eng)
    assert len(req.generated) == 8


def test_admission_identical_on_both_planes():
    cl = Cluster(2)
    local, dsm = make_engine(max_len=16), make_engine(cluster=cl, max_len=16)
    for e in (local, dsm):
        e.submit(list(range(30)), max_new=8)
        e.submit([1, 2, 3], max_new=4)
    assert [r.prompt for r in local.queue] == [r.prompt for r in dsm.queue]


def test_prefix_pages_shared_across_requests():
    cl = Cluster(4)
    eng = make_engine(cluster=cl, slots=4, page_size=4)
    base = [7, 7, 7, 7]                       # one full shared prefix page
    for i in range(4):
        eng.submit(base + [i], max_new=2)
    run_to_drain(eng)
    st = eng.stats()
    assert st["kv"]["hits"] >= 3              # page reused by requests 2..4
    assert st["guard_stats"]["regions"] == eng.steps
    assert st["guard_stats"]["write_guards"] > 0


# --------------------------------------------------------------------------
#  Digest equivalence: the protocol moves costs, not results
# --------------------------------------------------------------------------
def _digest_run(engine_or_fleet, prompts, max_new=6):
    for p in prompts:
        engine_or_fleet.submit(p, max_new=max_new)
    for _ in range(5000):
        if not engine_or_fleet.queue and not engine_or_fleet.active:
            break
        engine_or_fleet.step()
    return engine_or_fleet.digest()


def test_digest_identical_across_cluster_sizes():
    prompts = synth_prompts(24, seed=5)
    d_local = _digest_run(make_engine(), prompts)
    for n in (1, 2, 4, 8):
        cl = Cluster(n)
        assert _digest_run(make_engine(cluster=cl), prompts) == d_local, \
            f"digest diverged at {n} servers"


def test_digest_identical_for_fleet():
    prompts = synth_prompts(24, seed=5)
    d_local = _digest_run(make_engine(), prompts)
    for n in (2, 4, 8):
        cl = Cluster(n)
        fleet = ServeFleet(cl, step_fn=stub_step, page_size=4, slots=4,
                           max_len=64)
        assert _digest_run(fleet, prompts) == d_local, \
            f"fleet digest diverged at {n} replicas"


def test_digest_identical_with_real_model_raw_wire():
    import jax

    from repro import configs
    from repro.models import init_params

    cfg = configs.smoke("qwen3_0_6b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, cfg.attn_chunk + 3))
               for _ in range(3)]

    def run(cluster):
        eng = ServeEngine(cfg, OwnedState("w", params), slots=2,
                          max_len=128, cluster=cluster, wire="raw")
        return _digest_run(eng, prompts, max_new=4)

    assert run(None) == run(Cluster(4))


# --------------------------------------------------------------------------
#  Open-loop load + weight refresh
# --------------------------------------------------------------------------
def test_traces_are_seeded_and_shaped():
    a = poisson_trace(1000.0, 200, seed=3)
    assert a == poisson_trace(1000.0, 200, seed=3)
    assert a != poisson_trace(1000.0, 200, seed=4)
    assert all(t1 <= t2 for t1, t2 in zip(a, a[1:]))      # monotone
    gaps = np.diff(a)
    assert 600 < gaps.mean() < 1600                       # ~1000us mean gap
    b = bursty_trace(1000.0, 400, seed=3, burst_factor=4.0, duty=0.25)
    mean_rate = len(b) / ((b[-1] - b[0]) / 1e6)
    assert 600 < mean_rate < 1600                         # mean preserved
    # burstiness: inter-arrival variability well above Poisson's
    assert np.diff(b).std() > gaps.std()


def test_open_loop_latency_includes_queueing():
    cl = Cluster(1)
    eng = make_engine(cluster=cl, slots=1, decode_cycles=260_000.0)
    prompts = [[1, 2, 3], [4, 5, 6]]
    drv = OpenLoopDriver(eng, [0.0, 0.0], prompts, max_new=4)
    drv.run()
    r1, r2 = sorted(eng.finished, key=lambda r: r.rid)
    assert r2.latency_us > r1.latency_us      # second request queued
    res = drv.result(slo_us=r1.latency_us + 0.001)
    assert res.completed == 2 and res.slo_met == 1
    assert res.p99_us >= res.p50_us


def test_weight_refresh_int8_vs_raw_wire_bytes():
    def run(wire):
        cl = Cluster(2)
        w = OwnedState(f"w_{wire}", {"w": np.ones((64, 64), np.float32)})
        eng = make_engine(cluster=cl, weights=w, wire=wire,
                          weights_server=1)
        n = 16
        drv = OpenLoopDriver(eng, poisson_trace(2000.0, n, seed=9),
                             synth_prompts(n, seed=9), max_new=4,
                             weight_push_every=4)
        drv.run()
        return eng

    raw, int8 = run("raw"), run("int8")
    assert raw.digest() == int8.digest()      # stub decode: tokens exact
    assert raw.weight_cache.refreshes == int8.weight_cache.refreshes > 1
    # int8 ships ~4x fewer bytes per refresh (int8 payload + f32 scales)
    ratio = raw.wire_bytes / int8.wire_bytes
    assert 3.5 < ratio < 4.5
    # refresh cost is charged to the wire: remote weight server => rtts
    assert raw.cluster.sim.net.round_trips > 0


def test_weight_color_hit_is_zero_comm():
    cl = Cluster(2)
    w = OwnedState("w_hit", {"w": np.ones((8, 8), np.float32)})
    eng = make_engine(cluster=cl, weights=w, weights_server=1)
    for p in synth_prompts(6, seed=1):
        eng.submit(p, max_new=4)
    run_to_drain(eng)
    # weights never republished: exactly one refresh, rest zero-comm hits
    assert eng.weight_cache.refreshes == 1
    assert eng.weight_cache.hits == eng.steps - 1


def test_region_prefetch_posts_speculative_doorbells():
    cl = Cluster(4)
    # Engine on server 1: the striped shared-prefix page lands on server 0,
    # so the next-window hint has a genuinely cold remote box to speculate
    # on (prefetch correctly skips local/warm/in-flight boxes).
    eng = make_engine(cluster=cl, slots=1, prefetch_window=2, page_size=4,
                      server=1)
    base = [3, 3, 3, 3]
    for i in range(3):
        eng.submit(base + [i], max_new=2)
    run_to_drain(eng)
    assert cl.sim.net.speculative_fetches > 0


# --------------------------------------------------------------------------
#  The SLO gate
# --------------------------------------------------------------------------
def _fake_serve_baseline():
    return {"serve": {"poisson_4srv": {
        "p50_us": 1000.0, "p99_us": 2000.0, "goodput_tok_s": 20000.0,
        "completed": 72, "slo_met": 72, "steps": 500, "round_trips": 150,
        "kv_hits": 68, "kv_misses": 4, "wire_bytes": 3_000_000,
        "weight_refreshes": 200}}}


def test_gate_trips_on_p99_regression():
    import copy

    from benchmarks.check_regression import compare

    base = _fake_serve_baseline()
    ok = copy.deepcopy(base)
    ok["serve"]["poisson_4srv"]["p99_us"] = 2100.0        # +5%: within tol
    assert compare(base, ok, 0.10) == []
    bad = copy.deepcopy(base)
    bad["serve"]["poisson_4srv"]["p99_us"] = 2300.0       # +15%: trips
    fails = compare(base, bad, 0.10)
    assert any("p99_us" in f and "tail latency" in f for f in fails)


def test_gate_trips_on_goodput_drop_and_counter_drift():
    import copy

    from benchmarks.check_regression import compare

    base = _fake_serve_baseline()
    bad = copy.deepcopy(base)
    bad["serve"]["poisson_4srv"]["goodput_tok_s"] = 17000.0   # -15%
    assert any("goodput" in f for f in compare(base, bad, 0.10))
    # goodput going UP is an improvement, never a failure
    up = copy.deepcopy(base)
    up["serve"]["poisson_4srv"]["goodput_tok_s"] = 40000.0
    assert compare(base, up, 0.10) == []
    # deterministic counters are pinned exactly, both directions
    drift = copy.deepcopy(base)
    drift["serve"]["poisson_4srv"]["round_trips"] = 149
    assert any("round_trips" in f for f in compare(base, drift, 0.10))
    missing = {"serve": {}}
    assert any("missing" in f for f in compare(base, missing, 0.10))


def test_committed_baseline_has_serve_section():
    import json
    import pathlib

    root = pathlib.Path(__file__).resolve().parents[1]
    baseline = json.loads((root / "BENCH_protocol.json").read_text())
    assert set(baseline["serve"]) == {"poisson_1srv", "poisson_4srv",
                                      "poisson_8srv", "bursty_4srv"}
    for entry in baseline["serve"].values():
        for col in ("p50_us", "p99_us", "goodput_tok_s", "completed",
                    "round_trips", "kv_hits", "kv_misses", "wire_bytes",
                    "weight_refreshes"):
            assert col in entry
