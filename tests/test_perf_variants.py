"""Semantic equivalence of the §Perf variants vs the baseline paths.

These run on a 1x1 (data, model) mesh so the shard_map/a2a code paths
execute for real (single shard), and must reproduce baseline numerics.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.dist.sharding import set_mesh, set_rule_flags
from repro.launch.mesh import make_mesh
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn)

KEY = jax.random.PRNGKey(0)


def mesh11():
    return make_mesh((1, 1), ("data", "model"))


def teardown_function(_fn=None):
    set_mesh(None)
    set_rule_flags(ulysses=False, dp_only=False, serve_weights=False)


def test_chunked_ce_matches_full():
    cfg = dataclasses.replace(configs.smoke("gemma_7b"), dtype="float32")
    params = init_params(cfg, KEY)
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)}
    b["labels"] = jnp.roll(b["tokens"], -1, 1)
    full = float(loss_fn(cfg, params, b))
    chunked = float(loss_fn(dataclasses.replace(cfg, chunked_ce=4), params, b))
    np.testing.assert_allclose(chunked, full, rtol=1e-5)


def test_moe_a2a_matches_gather_dispatch():
    """a2a dispatch == gather dispatch when capacity admits every token."""
    mesh = mesh11()
    set_mesh(mesh)
    cfg = dataclasses.replace(configs.smoke("qwen3_moe_235b"),
                              dtype="float32", capacity_factor=8.0)
    from repro.models.moe import moe_params, moe_shardmap
    p = moe_params(cfg, KEY, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    y_gather, aux_g = moe_shardmap(cfg, mesh, p, x)
    y_a2a, aux_a = moe_shardmap(dataclasses.replace(cfg, moe_a2a=True),
                                mesh, p, x)
    np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_gather),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(aux_a), float(aux_g), rtol=1e-4)
    set_mesh(None)


def test_decode_shard_s_matches_baseline():
    mesh = mesh11()
    set_mesh(mesh)
    cfg = dataclasses.replace(configs.smoke("granite_34b"), dtype="float32")
    params = init_params(cfg, KEY)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)), jnp.int32)

    def run(c):
        cache = init_cache(c, 2, 64)
        outs = []
        for t in range(6):
            lg, cache = decode_step(c, params, cache, toks[:, t:t + 1],
                                    mesh=mesh)
            outs.append(lg)
        return jnp.concatenate(outs, axis=1)

    base = run(cfg)
    sharded = run(dataclasses.replace(cfg, decode_shard_s=True))
    np.testing.assert_allclose(np.asarray(sharded), np.asarray(base),
                               rtol=2e-3, atol=2e-3)
    set_mesh(None)


def test_dp_only_rules_shard_first_dim():
    import types
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import param_specs
    set_rule_flags(dp_only=True)
    m = types.SimpleNamespace(shape={"data": 16, "model": 16})
    cfg = configs.get("gemma_7b")
    abstract = jax.eval_shape(lambda k: init_params(cfg, k), KEY)
    specs = param_specs(m, abstract)
    wg = specs["layers"]["mlp"]["w_gate"]       # (L, D, F)
    assert "model" in (wg[1] if isinstance(wg[1], tuple) else (wg[1],))
    set_rule_flags(dp_only=False)


def test_ulysses_forward_matches_baseline_numerics():
    mesh = mesh11()
    set_mesh(mesh)
    cfg = dataclasses.replace(configs.smoke("gemma_7b"), dtype="float32")
    params = init_params(cfg, KEY)
    b = {"tokens": jnp.zeros((2, 32), jnp.int32)}
    base, _ = forward(cfg, params, b, mesh=mesh)
    uly, _ = forward(dataclasses.replace(cfg, ulysses=True), params, b,
                     mesh=mesh)
    np.testing.assert_allclose(np.asarray(uly), np.asarray(base),
                               rtol=1e-4, atol=1e-4)
    set_mesh(None)
