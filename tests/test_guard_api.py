"""Scoped-guard API suite: the RAII ownership surface + ProtocolBackend ABC.

Covers, per the guard redesign:

  * **ABC conformance** — all three protocol engines implement
    ``ProtocolBackend``; the registry resolves them by name; capability
    flags replace backend-name special cases.
  * **Guard/legacy equivalence twin** — the seeded 200-schedule
    staleness-safety suite from ``test_prefetch_invariants`` re-driven
    through ``read()`` / ``write()`` / ``region()`` guards must produce
    **identical NetStats** to the legacy call-pair surface (the guards are
    a zero-cost abstraction: enter/exit charge exactly what the call pairs
    charged).
  * **Borrow misuse** — a write guard inside a read guard raises
    ``BorrowError`` on *every* backend; payload accessors raise after the
    guard exits.
  * **Exception safety** — a raising guard body structurally releases the
    borrow and flushes the write-back exactly once; a raising region still
    settles; a raising DMutex critical section still unlocks.
  * **Region semantics** — exit flushes exactly the thread's registered
    derefs and staged channel sends; ``pin`` holds cache copies for the
    region lifetime; ``prefetch`` posts speculative doorbells.
  * **CoalescePolicy(max_expose_us=...)** — the latency-exposure SLO
    force-flushes once the oldest registered deref ages past the budget.
"""

from __future__ import annotations

import random

import pytest

from repro.core import (BorrowError, Cluster, CoalescePolicy, Channel,
                        DMutex, ProtocolBackend, backend_caps, backend_class)

BACKENDS = ("drust", "gam", "grappa")

N_SERVERS = 4
N_THREADS = 4
N_BOXES = 3
KINDS = ["prefetch", "prefetch", "read", "read", "owner_read", "write",
         "transfer", "drop"]


def make(backend="drust", **kw):
    cl = Cluster(N_SERVERS, backend=backend, **kw)
    ths = []
    for i in range(N_THREADS):
        th = cl.main_thread(0)
        th.server = i % N_SERVERS
        ths.append(th)
    return cl, ths


# --------------------------------------------------------------------------
#  ProtocolBackend ABC + registry
# --------------------------------------------------------------------------
def test_all_backends_implement_the_abc():
    for b in BACKENDS:
        cl = Cluster(2, backend=b)
        assert isinstance(cl.backend, ProtocolBackend)
        assert cl.backend.name == b
        assert backend_class(b) is type(cl.backend)


def test_capability_flags_replace_name_special_cases():
    assert backend_caps("drust").supports_ownership
    assert backend_caps("drust").supports_affinity
    assert backend_caps("drust").supports_prefetch
    assert backend_caps("drust").supports_coalescing
    for b in ("gam", "grappa"):
        caps = backend_caps(b)
        assert not caps.supports_ownership
        assert not caps.supports_prefetch
        assert not caps.supports_coalescing


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        backend_class("nope")
    with pytest.raises(ValueError):
        Cluster(2, backend="nope")


@pytest.mark.parametrize("backend", BACKENDS)
def test_verbs_roundtrip_on_every_backend(backend):
    cl, ths = make(backend)
    t0, t1 = ths[0], ths[1]
    h = cl.backend.alloc(t0, 64, b"v1")
    with h.read(t1) as v:
        assert v == b"v1"
    with h.write(t1) as w:
        w.set(b"v2")
    assert cl.backend.read(t1, h) == b"v2"
    assert cl.backend.update(t1, h, lambda x: x + b"!") == b"v2!"
    cl.backend.transfer(t1, h, 2)       # no-op off drust, transfer on drust
    cl.backend.drop(t1, h)


# --------------------------------------------------------------------------
#  Guard/legacy equivalence: the seeded staleness-safety twin
# --------------------------------------------------------------------------
def _drive_schedule(ops, qps, ooo, tied, guarded: bool):
    """Execute one prefetch/read/write/transfer/drop schedule through the
    legacy call-pair verbs (``guarded=False``) or through scoped guards +
    regions (``guarded=True``); returns the cluster for NetStats
    comparison.  Staleness is asserted against a versioned oracle either
    way."""
    cl, ths = make("drust", qps_per_thread=qps, ooo=ooo)
    rt = cl.drust
    version = [0] * N_BOXES
    boxes = [cl.backend.alloc(ths[0], 256, ("v", 0, 0))]
    boxes.append(cl.backend.alloc(ths[1 % N_THREADS], 256, ("v", 1, 0),
                                  tie_to=boxes[0] if tied else None))
    boxes += [cl.backend.alloc(ths[i % N_THREADS], 256, ("v", i, 0))
              for i in range(2, N_BOXES)]
    for kind, t, o, p in ops:
        th, i = ths[t % N_THREADS], o % N_BOXES
        box = boxes[i]
        if box.dropped:
            continue
        if kind == "prefetch":
            if guarded:
                with cl.region(th) as r:
                    r.prefetch([box])
            else:
                rt.prefetch(th, [box])
        elif kind == "read":
            if guarded:
                with box.read(th) as val:
                    assert val == ("v", i, version[i])
            else:
                assert cl.backend.read(th, box) == ("v", i, version[i])
        elif kind == "owner_read":
            assert rt.owner_read(th, box) == ("v", i, version[i])
        elif kind == "write":
            version[i] += 1
            if guarded:
                with box.write(th) as w:
                    w.set(("v", i, version[i]))
            else:
                cl.backend.write(th, box, ("v", i, version[i]))
        elif kind == "transfer":
            cl.backend.transfer(th, box, p % N_SERVERS)
        elif kind == "drop":
            cl.backend.drop(th, box)
    for i in range(N_BOXES):
        if not boxes[i].dropped:
            cl.backend.drop(ths[0], boxes[i])
    cl.sim.wb.fence_all(ths[0])
    assert not cl.sim.wb._pending
    return cl


def test_guard_twin_matches_legacy_netstats_200_seeded_schedules():
    """Satellite acceptance: the SAME 200 seeded schedules driven through
    the guard surface produce NetStats identical to the legacy call-pair
    surface — the guards defer/charge exactly the same costs."""
    rng = random.Random(3)
    for _ in range(200):
        qps = rng.choice([1, 2, 4])
        ooo = rng.random() < 0.5
        tied = rng.random() < 0.5
        ops = [(rng.choice(KINDS), rng.randrange(N_THREADS),
                rng.randrange(N_BOXES), rng.randrange(N_SERVERS))
               for _ in range(rng.randint(1, 40))]
        legacy = _drive_schedule(ops, qps, ooo, tied, guarded=False)
        guard = _drive_schedule(ops, qps, ooo, tied, guarded=True)
        assert (guard.sim.snapshot()["net"]
                == legacy.sim.snapshot()["net"]), \
            f"guard surface diverged from legacy on {ops!r}"


@pytest.mark.parametrize("backend", ("gam", "grappa"))
def test_guard_twin_matches_legacy_netstats_baselines(backend):
    """The generic guard layer is cost-transparent on the baseline
    protocols too (enter defers, ``set`` stages, exit performs the one
    legacy write)."""
    def drive(guarded: bool):
        cl, ths = make(backend)
        t0, t1, t2 = ths[0], ths[1], ths[2]
        hs = [cl.backend.alloc(t0, 256, ("v", k)) for k in range(4)]
        for rep in range(3):
            for k, h in enumerate(hs):
                if guarded:
                    with h.read(t1) as v:
                        assert v == ("v", k) or rep > 0
                    with h.write(t2) as w:
                        w.set(("v", k))
                else:
                    cl.backend.read(t1, h)
                    cl.backend.write(t2, h, ("v", k))
        return cl
    legacy, guard = drive(False), drive(True)
    assert guard.sim.snapshot()["net"] == legacy.sim.snapshot()["net"]


# --------------------------------------------------------------------------
#  Borrow misuse
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_write_guard_inside_read_guard_raises(backend):
    cl, ths = make(backend)
    h = cl.backend.alloc(ths[0], 64, 1)
    with h.read(ths[0]):
        with pytest.raises(BorrowError):
            with h.write(ths[0]) as w:
                w.set(2)
    # ...and the failed write attempt left no stuck borrow behind:
    with h.write(ths[0]) as w:
        w.set(3)
    assert cl.backend.read(ths[0], h) == 3


@pytest.mark.parametrize("backend", BACKENDS)
def test_read_guard_inside_write_guard_raises(backend):
    cl, ths = make(backend)
    h = cl.backend.alloc(ths[0], 64, 1)
    with h.write(ths[0]) as w:
        with pytest.raises(BorrowError):
            with h.read(ths[0]):
                pass
        w.set(2)
    assert cl.backend.read(ths[0], h) == 2


@pytest.mark.parametrize("backend", BACKENDS)
def test_payload_use_after_guard_exit_fails(backend):
    cl, ths = make(backend)
    h = cl.backend.alloc(ths[0], 64, 7)
    g = h.read(ths[1])
    with g as v:
        assert v == 7
        assert g.value == 7
    with pytest.raises(BorrowError):
        g.value
    w = h.write(ths[1])
    with w:
        w.set(8)
    with pytest.raises(BorrowError):
        w.set(9)
    with pytest.raises(BorrowError):
        w.value
    with pytest.raises(BorrowError):
        w.update(lambda x: x)
    assert cl.backend.read(ths[1], h) == 8


@pytest.mark.parametrize("backend", BACKENDS)
def test_guard_reentry_rejected(backend):
    cl, ths = make(backend)
    h = cl.backend.alloc(ths[0], 64, 1)
    g = h.read(ths[0])
    with g:
        pass
    with pytest.raises(BorrowError):
        with g:
            pass


# --------------------------------------------------------------------------
#  Exception safety (the satellite audit's regression tests)
# --------------------------------------------------------------------------
def test_raising_write_guard_releases_and_flushes_exactly_once():
    """A raising guard body must still release the mutable borrow and post
    the DropMutRef write-back exactly once — structurally, not by caller
    discipline."""
    cl, ths = make()
    t0, t1 = ths[0], ths[1]
    box = cl.backend.alloc(t0, 64, 10)            # owner slot home = server 0
    before = cl.sim.net.async_writebacks
    with pytest.raises(ValueError):
        with box.write(t1) as w:                  # t1 is remote
            w.set(99)
            raise ValueError("app bug")
    assert not box.live_mut, "mutable borrow leaked through the exception"
    assert cl.sim.net.async_writebacks == before + 1, \
        "owner-slot write-back not flushed exactly once"
    assert cl.backend.read(t0, box) == 99         # the write landed
    # and the box is immediately borrowable again:
    with box.write(t0) as w:
        w.set(100)
    assert cl.backend.read(t0, box) == 100


@pytest.mark.parametrize("backend", BACKENDS)
def test_raising_read_guard_releases_borrow(backend):
    cl, ths = make(backend)
    h = cl.backend.alloc(ths[0], 64, 1)
    with pytest.raises(RuntimeError):
        with h.read(ths[1]):
            raise RuntimeError("boom")
    with h.write(ths[1]) as w:                    # would raise if ref leaked
        w.set(2)
    assert cl.backend.read(ths[0], h) == 2


def test_raising_region_still_settles():
    cl, ths = make(coalesce="auto")
    t1 = ths[1]
    box = cl.backend.alloc(ths[0], 256, ("v", 0))
    co = cl.drust.coalescer
    with pytest.raises(KeyError):
        with cl.region(t1):
            with box.read(t1) as v:               # registers (cold remote)
                assert v == ("v", 0)
            assert co.pending
            raise KeyError("app bug")
    assert not co.pending, "region exit did not settle on the exception path"
    assert box.live_refs == 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_raising_write_guard_baseline_parity(backend):
    """The exception-safety contract is backend-independent: a raising
    write-guard body releases the mutable borrow and publishes the write on
    gam/grappa exactly as on drust (the drust-only twin above additionally
    pins the owner-slot write-back counter)."""
    cl, ths = make(backend)
    t0, t1 = ths[0], ths[1]
    box = cl.backend.alloc(t0, 64, 10)
    with pytest.raises(ValueError):
        with box.write(t1) as w:
            w.set(99)
            raise ValueError("app bug")
    assert cl.backend.read(t0, box) == 99         # the write landed
    with box.write(t0) as w:                      # borrow did not leak
        w.set(100)
    assert cl.backend.read(t0, box) == 100


@pytest.mark.parametrize("backend", BACKENDS)
def test_raising_mutex_critical_section_still_unlocks(backend):
    cl, ths = make(backend)
    mtx = DMutex(cl, ths[0], value=0)
    with pytest.raises(ZeroDivisionError):
        mtx.with_lock(ths[1], lambda obj: 1 / 0)
    # a later acquirer must not serialize behind the dead holder forever
    t2 = ths[2]
    t2.t_us = ths[1].t_us + 1.0
    out = mtx.with_lock(t2, lambda obj: "ok")
    assert out == "ok"
    assert mtx.acquisitions == 2


# --------------------------------------------------------------------------
#  Region semantics
# --------------------------------------------------------------------------
def test_region_exit_flushes_registered_derefs():
    cl, ths = make(coalesce="auto")
    t1 = ths[1]
    boxes = [cl.backend.alloc(ths[0], 256, k) for k in range(3)]
    co = cl.drust.coalescer
    rt0 = cl.sim.net.round_trips
    with cl.region(t1):
        for b in boxes:
            with b.read(t1):
                pass
        assert co.pending, "derefs should register inside the region"
        assert cl.sim.net.round_trips == rt0
    assert not co.pending
    assert co.flushes == 1 and co.flushed_derefs == 3
    assert cl.sim.net.round_trips > rt0           # the doorbell went out


def test_region_exit_settles_only_this_threads_staged_sends():
    cl, ths = make(coalesce="auto")
    t1, t2, t3 = ths[1], ths[2], ths[3]
    ch = Channel(cl)
    ch.recv_server = t3.server
    msgs0 = cl.sim.net.two_sided_msgs
    with cl.region(t1):
        ch.send(t1, "from-t1")                    # staged (reference send)
        ch.send(t2, "from-t2")                    # staged, other sender
        assert len(ch.q) == 0
    assert len(ch.q) == 1, "t1's staged send should ring at region exit"
    assert cl.sim.net.two_sided_msgs > msgs0
    assert len(ch._staged) == 1, "t2's staged send must stay staged"
    assert ch._staged[0][1] is t2


def test_region_pin_holds_cache_copies_for_the_scope():
    cl, ths = make()
    t1 = ths[1]
    box = cl.backend.alloc(ths[0], 512, b"p" * 512)
    reads0 = cl.sim.net.one_sided_reads
    with cl.region(t1, pin=[box]):
        assert cl.sim.net.one_sided_reads == reads0 + 1
        # pinned: a pressure sweep cannot reclaim the copy
        cl.drust.evict_caches(t1.server)
        assert box.g in cl.drust.caches[t1.server].entries
        with box.read(t1) as v:                   # warm hit, no new READ
            assert v == b"p" * 512
        assert cl.sim.net.one_sided_reads == reads0 + 1
    # pin released: the copy is evictable now
    cl.drust.evict_caches(t1.server)
    assert box.g not in cl.drust.caches[t1.server].entries


def test_region_prefetch_hint_posts_speculative_doorbells():
    cl, ths = make()
    t1 = ths[1]
    box = cl.backend.alloc(ths[0], 512, b"s" * 512)
    with cl.region(t1, prefetch=[box]):
        assert cl.sim.net.speculative_fetches == 1
        with box.read(t1) as v:
            assert v == b"s" * 512
    assert cl.sim.net.late_fences == 1
    assert cl.sim.net.wasted_prefetches == 0


def test_region_hints_after_exit_rejected():
    cl, ths = make()
    box = cl.backend.alloc(ths[0], 64, 1)
    with cl.region(ths[1]) as r:
        pass
    with pytest.raises(BorrowError):
        r.prefetch([box])
    with pytest.raises(BorrowError):
        r.pin([box])


def test_failed_region_entry_hint_releases_taken_pins():
    """Regression (review): ``__enter__`` raising means ``__exit__`` never
    runs — a failing pin hint must release the pins already taken, or the
    borrows leak forever."""
    cl, ths = make()
    t1 = ths[1]
    a = cl.backend.alloc(ths[0], 64, 1)
    b = cl.backend.alloc(ths[0], 64, 2)
    m = b.borrow_mut(ths[0])                      # b is mutably borrowed
    with pytest.raises(BorrowError):
        with cl.region(t1, pin=[a, b]):           # pinning b must fail
            pass
    m.deref_mut(ths[0])
    m.drop(ths[0])
    # a's pin was released on the failure path — a is freely borrowable
    with a.write(ths[0]) as w:
        w.set(10)
    assert cl.backend.read(ths[0], a) == 10


def test_failed_read_does_not_leak_borrow_on_baselines():
    """Regression (review): the guard layer must count the borrow only
    after the read succeeds — a raising read (e.g. on a dropped handle)
    may not leave the handle permanently read-borrowed."""
    cl, ths = make("gam")
    h = cl.backend.alloc(ths[0], 64, 1)
    h2 = cl.backend.alloc(ths[0], 64, 2)
    cl.backend.drop(ths[0], h)
    with pytest.raises(Exception):
        with h.read(ths[1]):
            pass
    assert h.live_refs == 0, "failed read leaked a guard-layer borrow"
    with h2.write(ths[1]) as w:                   # other handles unaffected
        w.set(3)


def test_region_pin_is_a_real_borrow_under_auto_coalescing():
    """Regression (review): under ``coalesce="auto"`` a pin must take the
    eager held borrow, NOT a coalescer registration — a registration would
    flush on a conflicting write instead of excluding it, silently
    dropping the pin's stability guarantee."""
    cl, ths = make(coalesce="auto")
    t1 = ths[1]
    box = cl.backend.alloc(ths[0], 256, ("v", 0))
    co = cl.drust.coalescer
    reads0 = cl.sim.net.one_sided_reads
    with cl.region(t1, pin=[box]):
        assert not co.pending, "pin was deferred to the coalescer"
        assert cl.sim.net.one_sided_reads == reads0 + 1   # fetched + pinned
        assert box.live_refs == 1
        with pytest.raises(BorrowError):
            box.borrow_mut(ths[0])                # pin EXCLUDES the writer
    assert box.live_refs == 0
    cl.backend.write(ths[0], box, ("v", 1))       # released at exit


def test_region_noop_on_baselines():
    for b in ("gam", "grappa"):
        cl, ths = make(b)
        h = cl.backend.alloc(ths[0], 64, 1)
        with cl.region(ths[1], prefetch=[h]) as r:
            assert r.prefetch([h]) == 0           # no safe speculation
            with h.read(ths[1]) as v:
                assert v == 1
        assert cl.sim.net.speculative_fetches == 0


# --------------------------------------------------------------------------
#  CoalescePolicy latency-exposure SLO
# --------------------------------------------------------------------------
def test_max_expose_us_forces_flush():
    cl, ths = make(coalesce="auto",
                   coalesce_policy=CoalescePolicy(max_expose_us=0.5))
    t1 = ths[1]
    boxes = [cl.backend.alloc(ths[0], 256, k) for k in range(2)]
    co = cl.drust.coalescer
    with boxes[0].read(t1):
        pass                                      # registers at age 0
    assert co.pending
    cl.sim.compute(t1, 10_000)                    # ~3.8us of virtual time
    with boxes[1].read(t1):
        pass                                      # oldest deref now > 0.5us
    assert not co.pending, "SLO breach did not close the quantum"
    assert co.flushes == 1 and co.expose_flushes == 1
    assert co.flushed_derefs == 2


def test_no_expose_slo_keeps_quantum_open():
    cl, ths = make(coalesce="auto")               # adaptive, no SLO
    t1 = ths[1]
    boxes = [cl.backend.alloc(ths[0], 256, k) for k in range(2)]
    co = cl.drust.coalescer
    with boxes[0].read(t1):
        pass
    cl.sim.compute(t1, 10_000)
    with boxes[1].read(t1):
        pass
    assert co.pending and co.flushes == 0
    assert co.expose_flushes == 0
    cl.close_quanta()


def test_expose_slo_bounds_exposure_in_a_sweep_trace():
    """The bench-sweep configuration: the SLO policy flushes strictly more
    often than the unconstrained adaptive policy on the same trace, never
    letting a registered deref age past the budget."""
    from benchmarks.protocol_micro import EXPOSE_THINK_CYCLES, _coalesce_run
    auto_cl, _ = _coalesce_run("bulk", "auto", n_objects=48,
                               think_cycles=EXPOSE_THINK_CYCLES)
    slo_cl, _ = _coalesce_run("bulk", "expose", n_objects=48,
                              think_cycles=EXPOSE_THINK_CYCLES)
    auto_cl.makespan_us()                         # settle trailing quanta
    slo_cl.makespan_us()
    auto_co, slo_co = auto_cl.drust.coalescer, slo_cl.drust.coalescer
    assert slo_co.expose_flushes > 0
    assert slo_co.flushes > auto_co.flushes
    # identical work either way: same derefs materialized
    assert slo_co.flushed_derefs == auto_co.flushed_derefs == 48
