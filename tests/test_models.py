"""Per-architecture smoke tests (reduced same-family configs) + semantic
checks: decode-vs-prefill consistency, chunked-vs-naive recurrences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward, init_cache, init_params,
                          loss_fn)

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, T=32):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)}
    b["labels"] = jnp.roll(b["tokens"], -1, axis=1)
    if cfg.prefix_len:
        b["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.prefix_len, cfg.d_model)) * 0.1,
            jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_loss_decode(arch):
    cfg = configs.smoke(arch)
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux = forward(cfg, params, batch)
    B, T = batch["tokens"].shape
    assert logits.shape == (B, T + cfg.prefix_len, cfg.vocab) if cfg.prefix_len \
        else logits.shape == (B, T, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), "NaN in logits"
    loss = loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))

    cache = init_cache(cfg, B, 64)
    lg, cache2 = decode_step(cfg, params, cache,
                             batch["tokens"][:, :1])
    assert lg.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert int(cache2["length"]) == 1


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "rwkv6_3b",
                                  "recurrentgemma_9b", "musicgen_medium"])
def test_decode_matches_prefill(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = configs.smoke(arch)
    params = init_params(cfg, KEY)
    rng = np.random.default_rng(1)
    B, T = 2, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    full_logits, _ = forward(cfg, params, {"tokens": toks})

    cache = init_cache(cfg, B, 64)
    step_logits = []
    for t in range(T):
        lg, cache = decode_step(cfg, params, cache, toks[:, t:t + 1])
        step_logits.append(lg[:, 0])
    got = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(full_logits, np.float32),
        rtol=5e-2, atol=5e-2)


def test_moe_routing_conservation():
    """Every token's combined gate weights sum to ~1 (post-normalization)."""
    from repro.models.moe import moe_block, moe_params
    cfg = configs.smoke("qwen3_moe_235b")
    p = moe_params(cfg, KEY, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe_block(cfg, p, x)
    assert y.shape == x.shape
    assert float(aux) > 0.0
    assert np.isfinite(np.asarray(y)).all()


def test_rwkv_chunked_matches_naive():
    from repro.kernels import ref
    from repro.models.rwkv import _wkv_chunk
    rng = np.random.default_rng(0)
    B, H, T, M = 2, 2, 64, 16
    f = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    r, k, v = f(B, H, T, M), f(B, H, T, M), f(B, H, T, M)
    logw = -0.105 * jax.nn.sigmoid(f(B, H, T, M))
    u = f(H, M) * 0.1
    o_ref, S_ref = ref.rwkv_scan(r, k, v, logw, u)
    o, S = _wkv_chunk(r, k, v, logw, u, jnp.zeros((B, H, M, M)))
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref),
                               rtol=1e-4, atol=1e-4)


def test_rglru_block_matches_ref_recurrence():
    from repro.kernels import ref
    from repro.models.rglru import rglru_params, rglru_block
    cfg = configs.smoke("recurrentgemma_9b")
    p = rglru_params(cfg, KEY, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model)) * 0.1
    y, state = rglru_block(cfg, p, x)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()
    # streaming in two halves must equal one shot
    y1, st1 = rglru_block(cfg, p, x[:, :16])
    y2, st2 = rglru_block(cfg, p, x[:, 16:], state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y), rtol=2e-3, atol=2e-3)


def test_window_attention_masks_far_context():
    """Tokens beyond the sliding window must not influence the output."""
    from repro.models.layers import attention
    rng = np.random.default_rng(3)
    B, H, T, hd, W = 1, 2, 32, 16, 8
    f = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    q, k, v = f(B, T, H, hd), f(B, T, H, hd), f(B, T, H, hd)
    pos = jnp.arange(T)
    out1 = attention(q, k, v, pos, pos, window=W, chunk=16)
    k2 = k.at[:, :T - W - 1].set(99.0)          # mutate far context
    v2 = v.at[:, :T - W - 1].set(-99.0)
    out2 = attention(q, k2, v2, pos, pos, window=W, chunk=16)
    np.testing.assert_allclose(np.asarray(out1[:, -1]),
                               np.asarray(out2[:, -1]), rtol=1e-5, atol=1e-5)


def test_param_count_analytic_close_to_actual():
    for arch in ["gemma_7b", "qwen3_0_6b", "starcoder2_3b"]:
        cfg = configs.get(arch)
        abstract = jax.eval_shape(lambda k: init_params(cfg, k), KEY)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(abstract))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.1, \
            f"{arch}: analytic {analytic} vs actual {actual}"
