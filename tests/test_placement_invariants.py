"""Migration-safety property suite for telemetry-driven placement.

Random schedules interleave live owner migration (``migrate_here``) with
scoped borrows held across operations, speculative prefetch (in-flight
cids), ownership ``transfer``, ``drop_box``, writes, and quantum epoch
ticks over a small box population; after every operation:

  * Value Safety: a read NEVER observes pre-migration / pre-write bytes —
    every deref returns the oracle's current version, wherever the owner
    currently lives.
  * Borrow Safety: a migration attempted while any borrow in the moving
    closure is live refuses (returns False) and leaves the owner where it
    was; a successful migration lands the whole closure on the caller.
  * Exactly-Once Disposition: every speculative cid posted during the
    schedule is fenced or invalidated exactly once — migrations fence the
    in-flight cids of the boxes they move, exactly like ``transfer``.
  * Digest Equality: the same schedule replayed on ``placement="static"``
    (migrations skipped — they are placement-transparent by contract)
    folds byte-identical read values.

Each property runs twice: hypothesis-generated (200 schedules,
derandomized under the CI profile — see ``_hypcompat``) and a seeded
deterministic twin that executes on machines without hypothesis.
"""

from __future__ import annotations

import random

from _hypcompat import given, settings, st

from repro.core import Cluster, addr as A

N_SERVERS = 4
N_THREADS = 4
N_BOXES = 3

KINDS = ["read", "read", "write", "prefetch", "migrate", "migrate",
         "hold", "release", "transfer", "drop", "tick"]


def run_placement_schedule(ops, tied: bool = False,
                           auto: bool = True) -> int:
    """Execute a migration/borrow/prefetch schedule; returns the digest of
    every value read in schedule order.  ``auto=True`` runs under
    ``placement="auto"`` (guard closes feed the tracker, so reads can also
    trigger policy-driven migrations on top of the explicit ``migrate``
    ops); ``auto=False`` replays the identical schedule on the static
    plane with migrations skipped — the digests must match."""
    cl = Cluster(N_SERVERS, backend="drust",
                 placement="auto" if auto else "static")
    rt = cl.drust
    ths = []
    for i in range(N_THREADS):
        th = cl.main_thread(0)
        th.server = i % N_SERVERS
        ths.append(th)
    version = [0] * N_BOXES
    boxes = [cl.backend.alloc(ths[0], 256, ("v", 0, 0))]
    boxes.append(cl.backend.alloc(ths[1 % N_THREADS], 256, ("v", 1, 0),
                                  tie_to=boxes[0] if tied else None))
    boxes += [cl.backend.alloc(ths[i % N_THREADS], 256, ("v", i, 0))
              for i in range(2, N_BOXES)]
    held: dict[tuple[int, int], object] = {}     # (box idx, tid) -> ref
    digest = 0

    def group(i):
        idxs = {i}
        if tied and i in (0, 1):
            idxs = {0, 1}                        # box 1 is a TBox child of 0
        return [boxes[j] for j in idxs]

    def live(i):
        return any(b.live_refs or b.live_mut for b in group(i))

    for kind, t, o, p in ops:
        th, i = ths[t % N_THREADS], o % N_BOXES
        box = boxes[i]
        if box.dropped:                          # incl. cascaded TBox drops
            continue
        if kind == "read":
            with box.read(th) as val:            # guard: feeds the tracker
                assert val == ("v", i, version[i]), \
                    f"stale deref: saw {val}, current is {version[i]}"
                digest = (digest * 1000003 + hash(val)) & ((1 << 61) - 1)
        elif kind == "write":
            if live(i):
                continue                         # would be a borrow error
            version[i] += 1
            cl.backend.write(th, box, ("v", i, version[i]))
        elif kind == "prefetch":
            rt.prefetch(th, [box])
        elif kind == "migrate":
            if not auto:
                continue                         # static twin: transparent
            src = A.server_of(box.g)
            moved = rt.migrate_here(th, box)
            if live(i):
                assert not moved, "migration ran under a live borrow"
            if moved:
                assert A.server_of(box.g) == th.server
                for b in group(i):
                    if not b.dropped:
                        assert A.server_of(b.g) == th.server, \
                            "closure split: tied member left behind"
            else:
                assert A.server_of(box.g) in (src, th.server)
        elif kind == "hold":
            if (i, th.tid) not in held and not box.live_mut:
                held[(i, th.tid)] = box.borrow(th)
        elif kind == "release":
            ref = held.pop((i, th.tid), None)
            if ref is not None:
                ref.drop(th)
        elif kind == "transfer":
            if live(i):
                continue
            rt.transfer(th, box, p % N_SERVERS)
        elif kind == "drop":
            if live(i):
                continue
            for key in [k for k in held if boxes[k[0]] in group(i)]:
                held.pop(key)                    # cascaded TBox drop frees
            rt.drop_box(th, box)
        elif kind == "tick":
            cl.close_quanta()                    # quantum epoch boundary
        for how in rt.spec_log.values():
            assert how in ("fenced", "invalidated")
    for (i, tid), ref in held.items():
        if not boxes[i].dropped:
            ref.drop(ths[tid % N_THREADS])
    for i in range(N_BOXES):
        if not boxes[i].dropped:
            rt.drop_box(ths[0], boxes[i])
    # Exactly-once disposition over the whole schedule — migrations fence
    # or invalidate in-flight speculative cids exactly like transfers.
    assert len(rt.spec_cids) == len(set(rt.spec_cids))
    assert set(rt.spec_cids) == set(rt.spec_log), \
        "a speculative cid was neither fenced nor invalidated"
    net = cl.sim.net
    fenced = sum(1 for v in rt.spec_log.values() if v == "fenced")
    wasted = sum(1 for v in rt.spec_log.values() if v == "invalidated")
    assert net.late_fences == fenced
    assert net.wasted_prefetches == wasted
    assert net.speculative_fetches == len(rt.spec_cids)
    if not auto:
        assert net.owner_migrations == 0, "static plane migrated"
    assert net.migration_round_trips >= net.owner_migrations
    cl.sim.wb.fence_all(ths[0])
    assert not cl.sim.wb._pending, "completion plane leaked pending verbs"
    return digest


placement_ops = st.lists(
    st.tuples(st.sampled_from(KINDS),
              st.integers(0, N_THREADS - 1),
              st.integers(0, N_BOXES - 1),
              st.integers(0, N_SERVERS - 1)),
    min_size=1, max_size=40)


@settings(max_examples=200, deadline=None)
@given(placement_ops, st.booleans())
def test_migration_safety_property(ops, tied):
    digest_auto = run_placement_schedule(ops, tied, auto=True)
    digest_static = run_placement_schedule(ops, tied, auto=False)
    assert digest_auto == digest_static, \
        "placement changed the bytes a read observes"


def test_migration_safety_200_seeded_schedules():
    """Deterministic twin of the hypothesis suite: 200 seeded random
    schedules (half with a TBox-tied pair), so the property is exercised
    even without hypothesis."""
    rng = random.Random(3)
    for _ in range(200):
        tied = rng.random() < 0.5
        ops = [(rng.choice(KINDS), rng.randrange(N_THREADS),
                rng.randrange(N_BOXES), rng.randrange(N_SERVERS))
               for _ in range(rng.randint(1, 40))]
        digest_auto = run_placement_schedule(ops, tied, auto=True)
        digest_static = run_placement_schedule(ops, tied, auto=False)
        assert digest_auto == digest_static


def test_migration_fences_inflight_prefetch_exactly_once():
    """Directed: a migration of a box with an unused in-flight speculative
    READ disposes the cid exactly once before the payload moves."""
    cl = Cluster(N_SERVERS, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0)
    t1.server = 1
    t2 = cl.main_thread(0)
    t2.server = 2
    box = cl.backend.alloc(t0, 512, b"m" * 512)
    cl.drust.prefetch(t2, [box])
    cid = box.fetch_cid
    assert cid in cl.sim.wb._pending
    assert cl.drust.migrate_here(t1, box) is True
    assert cid not in cl.sim.wb._pending, "migration left the cid in flight"
    assert cl.drust.spec_log[cid] in ("fenced", "invalidated")
    assert list(cl.drust.spec_log).count(cid) == 1
    assert cl.backend.read(t2, box) == b"m" * 512
