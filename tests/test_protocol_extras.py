"""Protocol corners: D.1 stack/partial borrows, lazy cache eviction under
memory pressure, allocator spill, cache hit accounting."""

import numpy as np

from repro.core import Cluster, StackRef, addr as A


def make(n=3, **kw):
    cl = Cluster(n, backend="drust", **kw)
    ths = []
    for s in range(n):
        th = cl.main_thread(0)
        th.server = s
        ths.append(th)
    return cl, ths


def test_stackref_copy_and_writeback():
    """D.1: a mutable borrow of a stack value copies it to the borrower and
    writes back on drop; the parent's color bumps so caches miss."""
    cl, (t0, t1, t2) = make()
    parent = cl.drust.stack_val(t0, 64, {"field": 1})
    color0 = A.get_color(parent.g)
    ref = StackRef(cl.drust, parent, {"field": 1}, 64, src_server=0)
    val = ref.deref_mut(t1)
    val["field"] = 42
    ref.drop(t1)                         # write-back + parent color bump
    assert A.get_color(parent.g) == color0 + 1
    assert cl.sim.net.one_sided_writes >= 1   # cross-server write-back


def test_cache_eviction_under_pressure():
    cl, (t0, t1, t2) = make()
    boxes = [cl.backend.alloc(t0, 1024, bytes(1024)) for _ in range(8)]
    for b in boxes:
        cl.backend.read(t1, b)           # fill server 1's cache
    H = cl.drust.caches[1]
    assert len(H.entries) == 8
    freed = cl.drust.evict_caches(1)     # all refcounts are 0 -> reclaim
    assert freed == 8 * 1024
    assert len(H.entries) == 0
    # pinned entries survive eviction
    r = boxes[0].borrow(t1)
    r.deref(t1)
    cl.drust.evict_caches(1)
    assert len(H.entries) == 1
    r.drop(t1)


def test_allocator_spill_to_most_vacant():
    cl, ths = make(partition_bytes=1 << 16)
    t0 = ths[0]
    cl.backend.alloc(t0, 60000, b"")     # fill server 0 past watermark
    target = cl.controller.pick_alloc_server(0, 8192)
    assert target != 0


def test_cache_hit_rate_accounting():
    cl, (t0, t1, t2) = make()
    b = cl.backend.alloc(t0, 256, b"v")
    for _ in range(5):
        cl.backend.read(t1, b)
    H = cl.drust.caches[1]
    assert H.misses == 1 and H.hits == 4


def test_group_bytes_and_tie_closure():
    cl, (t0, *_ ) = make()
    head = cl.backend.alloc(t0, 100, b"h")
    c1 = cl.backend.alloc(t0, 200, b"c1", tie_to=head)
    cl.backend.alloc(t0, 300, b"c2", tie_to=c1)        # nested tie
    raw = A.clear_color(head.g)
    assert len(cl.drust.heap.tie_closure(raw)) == 3
    assert cl.drust.heap.group_bytes(raw) == 600


def test_quarantine_delays_address_reuse():
    from repro.core.heap import Partition
    cl, (t0, *_ ) = make()
    part = cl.drust.heap.partitions[0]
    b = cl.backend.alloc(t0, 64, b"x")
    raw = A.clear_color(b.g)
    cl.backend.free(t0, b)
    # immediately reallocating must not reuse the quarantined address
    b2 = cl.backend.alloc(t0, 64, b"y")
    assert A.clear_color(b2.g) != raw
