"""Colored-address and pointer-layout unit tests (paper Fig. 4/8)."""

import pytest
from _hypcompat import given, st

from repro.core import addr as A


def test_color_roundtrip():
    g = A.append_color(0x1234, 7)
    assert A.get_color(g) == 7
    assert A.clear_color(g) == 0x1234


def test_bump_and_overflow():
    g = A.append_color(42, A.MAX_COLOR - 1)
    g2, ov = A.bump_color(g)
    assert not ov and A.get_color(g2) == A.MAX_COLOR
    g3, ov = A.bump_color(g2)
    assert ov and A.get_color(g3) == 0      # move-on-overflow resets


def test_u_bit():
    ext = 0xdeadbeef
    assert not A.color_updated(ext)
    ext = A.set_u_bit(ext)
    assert A.color_updated(ext)
    assert A.clear_u_bit(ext) == 0xdeadbeef


def test_server_of_partitions():
    for s in range(8):
        base, limit = A.partition_range(s)
        assert A.server_of(base) == s
        assert A.server_of(limit - 1) == s


def test_stack_addresses_have_no_home():
    assert A.is_stack(A.STACK_BASE + 100)
    with pytest.raises(ValueError):
        A.server_of(A.STACK_BASE + 100)


@given(st.integers(0, A.ADDR_MASK), st.integers(0, A.MAX_COLOR))
def test_color_never_leaks_into_address(raw, color):
    g = A.append_color(raw, color)
    assert A.clear_color(g) == raw
    assert A.get_color(g) == color
