"""AST borrow lint (`repro.analysis.lint`): corpus coverage, shipped-tree
cleanliness, suppressions, and the CI-facing CLI.

The corpus under ``tests/data/lint_corpus/`` has one fixture per rule; every
violating line carries an inline ``# E1xx:`` marker, so coverage is asserted
as *exact* (line, code) set equality — a fixture line the linter misses or a
clean line it flags both fail.
"""

from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import default_targets
from repro.analysis.linter import RULES, lint_file, lint_paths

REPO = Path(__file__).resolve().parents[1]
CORPUS = REPO / "tests" / "data" / "lint_corpus"
_MARK = re.compile(r"#\s*(E1\d\d):")


def _expected(path: Path) -> set[tuple[int, str]]:
    out = set()
    for i, line in enumerate(path.read_text().splitlines(), 1):
        m = _MARK.search(line)
        if m:
            out.add((i, m.group(1)))
    return out


def _lint_source(tmp_path: Path, src: str):
    f = tmp_path / "case.py"
    f.write_text(textwrap.dedent(src))
    return lint_file(f)


# --------------------------------------------------------------------------
#  Corpus: 100% of the seeded violations, nothing else
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "fixture", sorted(CORPUS.glob("*.py")), ids=lambda p: p.stem)
def test_corpus_fixture_exactly_flagged(fixture):
    got = {(v.line, v.code) for v in lint_file(fixture)}
    want = _expected(fixture)
    assert want, f"{fixture.name} has no # E1xx: markers"
    assert got == want, (
        f"missed: {sorted(want - got)}  spurious: {sorted(got - want)}")


def test_corpus_covers_every_rule():
    stems = {p.stem.split("_")[0].upper() for p in CORPUS.glob("*.py")}
    assert stems == set(RULES), "one corpus fixture per rule"


# --------------------------------------------------------------------------
#  Shipped tree: zero violations on the CI target set
# --------------------------------------------------------------------------
def test_shipped_tree_is_clean():
    vs = lint_paths(default_targets())
    assert vs == [], "\n".join(v.format() for v in vs)


def test_default_targets_cover_the_guard_surface():
    names = {Path(t).name for t in default_targets()}
    assert {"apps", "serve", "sync.py", "examples"} <= names


# --------------------------------------------------------------------------
#  Regression: the pre-fix apps/dataframe.py escape (payload aliased in the
#  last statement of an else-branch, iterated after the enclosing if) must
#  be flagged — the block-local scan missed it until the runtime sanitizer
#  caught the same bug live.
# --------------------------------------------------------------------------
def test_branch_tail_escape_is_flagged(tmp_path):
    vs = _lint_source(tmp_path, """
        def probe(index, col, th, choreograph, cl):
            if choreograph:
                srcs = cl.backend.read_many(th, [index[0]])[-1]
            else:
                with index[0].read(th) as v:
                    srcs = v
            acc = 0.0
            for s_idx in srcs:
                with col[s_idx].read(th) as chunk:
                    acc += sum(chunk)
            return acc
        """)
    assert [v.code for v in vs] == ["E102"]
    assert "srcs" in vs[0].message


def test_copy_inside_guard_is_clean(tmp_path):
    # The shipped fix: list(v) is a new object, not a payload alias.
    vs = _lint_source(tmp_path, """
        def probe(index, col, th):
            with index[0].read(th) as v:
                srcs = list(v)
            return [s for s in srcs]
        """)
    assert vs == []


# --------------------------------------------------------------------------
#  Suppressions
# --------------------------------------------------------------------------
def test_allow_comment_suppresses_one_rule(tmp_path):
    vs = _lint_source(tmp_path, """
        def f(cl, th, h):
            cl.backend.borrow(th, h)  # lint: allow(raw-verb)
            cl.backend.deref(th, h)
        """)
    assert [v.code for v in vs] == ["E101"]
    assert vs[0].line == 4  # only the unsuppressed call


def test_allow_all_suppresses_everything(tmp_path):
    vs = _lint_source(tmp_path, """
        def f(cl, th, h):
            cl.backend.borrow(th, h)  # lint: allow(all)
        """)
    assert vs == []


def test_shipped_suppressions_are_documented():
    # The reader-lease grant in core/sync.py is the one sanctioned
    # guard-no-with site; its allow comments must survive refactors.
    src = (REPO / "src/repro/core/sync.py").read_text()
    assert src.count("lint: allow(guard-no-with)") == 2


# --------------------------------------------------------------------------
#  CLI (what CI runs)
# --------------------------------------------------------------------------
def _run_cli(*args: str):
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *args],
        capture_output=True, text=True, env=env, cwd=REPO)


def test_cli_clean_tree_exits_zero():
    p = _run_cli()
    assert p.returncode == 0, p.stdout + p.stderr
    assert "0 violations" in p.stderr  # summary goes to stderr


def test_cli_github_format_annotates_and_fails():
    p = _run_cli("--format=github", str(CORPUS / "e101_raw_verb.py"))
    assert p.returncode == 1
    lines = [l for l in p.stdout.splitlines() if l.startswith("::error ")]
    assert len(lines) == len(_expected(CORPUS / "e101_raw_verb.py"))
    assert "file=" in lines[0] and "line=" in lines[0]


def test_cli_json_format_is_parseable():
    p = _run_cli("--format=json", str(CORPUS / "e105_spawn_capture.py"))
    assert p.returncode == 1
    rows = json.loads(p.stdout)
    assert {r["code"] for r in rows} == {"E105"}
