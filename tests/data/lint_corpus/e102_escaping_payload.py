"""Corpus fixture: E102 escaping-payload — guard payloads read after close."""


def stale_read(node, th):
    with node.read(th) as v:
        degree = len(v["edges"])
    return v["edges"][0], degree  # E102: v read after its guard closed


def stale_alias(node, th):
    with node.write(th) as w:
        snap = w.value  # pure access chain: `snap` aliases the payload
        w.value["n"] += 1
    snap["n"] += 1  # E102: alias written through after close
    return snap


def branch_escape(index, col, th, choreograph, cl):
    # Regression: the guard is the *last* statement of an else-branch, and
    # the stale read happens after the enclosing `if`.  A block-local scan
    # misses this; the scan must climb the parent chain.  (This is the exact
    # shape of a real bug the runtime sanitizer caught in apps/dataframe.py.)
    if choreograph:
        srcs = cl.backend.read_many(th, [index[0]])[-1]
    else:
        with index[0].read(th) as v:
            srcs = v
    acc = 0.0
    for s_idx in srcs:  # E102: srcs aliases the closed guard's payload
        with col[s_idx].read(th) as chunk:
            acc += float(sum(chunk))
    return acc


def not_flagged(node, th, fn):
    with node.write(th) as w:
        result = w.update(fn)  # a method's return value is a new object
    with node.read(th) as v:
        copied = list(v)
    return result, copied  # fine: neither aliases the dead payload
