"""Corpus fixture: E101 raw-verb — raw protocol verbs outside core/.

These are the call pairs the old CI grep hunted with a regex; the AST
lint sees through the formatting tricks that fooled it.
"""


def leaky_sum(cl, th, handles):
    total = 0
    for h in handles:
        cl.backend.borrow(th, h)  # E101: raw verb
        total += cl.backend.deref(th, h)  # E101: raw verb
        cl.backend.drop(th, h)  # E101: raw verb
    return total


def leaky_update(cl, th, h):
    cl.backend.borrow_mut(th, h)  # E101: raw verb
    v = cl.backend.deref_mut(th, h)  # E101: raw verb
    v["k"] = 1
    cl.backend.drop_ref(th, h)  # E101: raw verb


def not_flagged(df, th, h):
    # kwarg / zero-arg drop is some other API, not the protocol verb
    df.drop(columns=["a"])
    h.drop()
