"""Corpus fixture: E104 guard-no-with — guards opened without `with`."""

from repro.core.protocol import ReadGuard, WriteGuard


def manual_guard(backend, th, h):
    g = ReadGuard(backend, th, h)  # E104: constructed outside `with`
    g.__enter__()  # E104: explicit enter, no structural release
    try:
        return g.value
    finally:
        g.__exit__(None, None, None)


def dangling_open(node, th):
    g = node.write(th)  # E104: guard opened, never a with-context
    g.value["n"] += 1
    return g


def not_flagged(backend, th, h, node, f, state, tree):
    with WriteGuard(backend, th, h) as w:  # with-context: fine
        w.value["n"] = 1
    with node.read(th):  # with-context: fine
        pass
    backend.read(th, h)  # 2-arg legacy shim, not a guard constructor
    f.read()  # 0-arg file-style read
    state.write(state.read())  # value plumbing: arg is a call, not a thread
    state.write(tree)  # lint: allow(guard-no-with) — suppression honored
