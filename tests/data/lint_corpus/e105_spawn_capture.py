"""Corpus fixture: E105 spawn-capture — handles captured without routing."""


def unrouted(cl, boot):
    shard = cl.backend.alloc(boot, 64, data=[0] * 8)
    tile = cl.backend.alloc(boot, 64, data=[1] * 8)

    def work(th):
        with shard.read(th) as v:
            return sum(v)

    cl.scheduler.spawn(work, parent=boot)  # fine: no handle in the args
    cl.scheduler.spawn(lambda th: shard, parent=boot)  # E105: shard captured
    cl.scheduler.spawn(work, tile, parent=boot)  # E105: tile captured


def routed(cl, boot):
    shard = cl.backend.alloc(boot, 64, data=[0] * 8)

    def work(th):
        with shard.read(th) as v:
            return sum(v)

    # explicit placement: the closure runs where the data lives
    cl.scheduler.spawn(work, shard, server=cl.backend.locate(shard), parent=boot)
    cl.scheduler.spawn_near(shard, work, parent=boot)
    cl.scheduler.spawn_to(shard, work, parent=boot)
