"""Corpus fixture: E103 guard-live-conflict — disposal under a live guard."""


def free_under_guard(cl, th, page):
    with page.read(th) as v:
        total = sum(v)
        cl.backend.free(th, page)  # E103: free while page's guard is live
    return total


def transfer_under_guard(cl, th, box, dst):
    with box.write(th) as w:
        w.value["owner"] = dst
        cl.backend.transfer(th, box, dst)  # E103: transfer under live guard


def not_flagged(cl, th, page, other):
    with page.read(th) as v:
        total = sum(v)
        cl.backend.free(th, other)  # a *different* handle: fine
    cl.backend.free(th, page)  # after the guard closed: fine
    return total
