"""Unit tests for the coherence protocol (Algorithms 1-8)."""

import pytest

from repro.core import BorrowError, Cluster, addr as A


def make():
    cl = Cluster(4, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    t2 = cl.main_thread(0); t2.server = 2
    return cl, t0, t1, t2


def test_remote_read_fills_cache_and_counts():
    cl, t0, t1, _ = make()
    b = cl.backend.alloc(t0, 128, b"x" * 128)
    cl.backend.read(t1, b)
    H = cl.drust.caches[1]
    assert len(H.entries) == 1
    assert cl.sim.net.one_sided_reads == 1
    cl.backend.read(t1, b)                      # second read: cache hit
    assert cl.sim.net.one_sided_reads == 1
    assert H.hits >= 1


def test_remote_write_moves_object():
    cl, t0, t1, _ = make()
    b = cl.backend.alloc(t0, 128, b"old")
    g0 = A.clear_color(b.g)
    cl.backend.write(t1, b, b"new")
    assert A.server_of(b.g) == 1                # moved to the writer
    assert A.clear_color(b.g) != g0             # address change = invalidation
    assert not cl.drust.heap.contains(g0)       # old storage deallocated


def test_local_write_bumps_color_once_per_epoch():
    cl, t0, _, _ = make()
    b = cl.backend.alloc(t0, 64, 1)
    assert A.get_color(b.g) == 0
    cl.backend.write(t0, b, 2)                  # first write: bump
    assert A.get_color(b.g) == 1
    cl.backend.write(t0, b, 3)                  # same epoch (U set): no bump
    assert A.get_color(b.g) == 1
    cl.backend.read(t0, b)                      # reader resets U
    cl.backend.write(t0, b, 4)                  # new epoch: bump
    assert A.get_color(b.g) == 2


def test_stale_cache_not_read_after_write():
    cl, t0, t1, t2 = make()
    b = cl.backend.alloc(t0, 64, b"v1")
    assert cl.backend.read(t1, b) == b"v1"      # cached on server 1
    cl.backend.write(t2, b, b"v2")              # moves to server 2
    assert cl.backend.read(t1, b) == b"v2"      # MUST see the new value


def test_owner_adopts_local_cache_copy():
    cl, t0, t1, _ = make()
    b = cl.backend.alloc(t0, 64, b"v1", server=1)
    cl.backend.read(t0, b)                      # cache copy on server 0
    reads_before = cl.sim.net.one_sided_reads
    cl.drust.owner_write(t0, b, data=b"v2")     # Algorithm 8 lines 11-16
    assert cl.sim.net.one_sided_reads == reads_before   # no re-copy
    assert A.server_of(b.g) == 0
    assert cl.backend.read(t0, b) == b"v2"


def test_borrow_rules_enforced():
    cl, t0, _, _ = make()
    b = cl.backend.alloc(t0, 64, 0)
    r = b.borrow(t0)
    with pytest.raises(BorrowError):
        b.borrow_mut(t0)
    r.drop(t0)
    m = b.borrow_mut(t0)
    with pytest.raises(BorrowError):
        b.borrow(t0)
    m.deref_mut(t0)
    m.drop(t0)
    b.borrow(t0).drop(t0)


def test_mutable_borrow_writeback_updates_owner():
    cl, t0, t1, _ = make()
    b = cl.backend.alloc(t0, 64, 10)
    m = b.borrow_mut(t1)
    m.deref_mut(t1)
    cl.drust.heap.get(A.clear_color(m.g)).data = 11
    old_owner_g = b.g
    m.drop(t1)
    assert b.g == m.g and b.g != old_owner_g
    assert cl.backend.read(t0, b) == 11


def test_transfer_evicts_source_cache(capsys):
    cl, t0, t1, _ = make()
    b = cl.backend.alloc(t0, 64, b"v", server=2)
    cl.backend.read(t0, b)
    assert len(cl.drust.caches[0].entries) == 1
    # owner's pin was dropped at read end; transfer must clear residual copy
    cl.drust.transfer(t0, b, 1)
    assert b.home == 1


def test_drop_deallocates_and_invalidates():
    cl, t0, t1, _ = make()
    b = cl.backend.alloc(t0, 64, b"v")
    cl.backend.read(t1, b)
    raw = A.clear_color(b.g)
    cl.backend.free(t0, b)
    assert not cl.drust.heap.contains(raw)
    assert all(A.clear_color(g) != raw
               for g in cl.drust.caches[1].entries)


def test_tbox_group_fetch_single_rtt():
    cl, t0, t1, _ = make()
    head = cl.backend.alloc(t0, 64, b"head")
    c1 = cl.backend.alloc(t0, 64, b"c1", tie_to=head)
    c2 = cl.backend.alloc(t0, 64, b"c2", tie_to=c1)
    reads_before = cl.sim.net.one_sided_reads
    assert cl.backend.read(t1, head) == b"head"
    assert cl.sim.net.one_sided_reads == reads_before + 1   # one batched READ
    # children now local to server 1: no further network reads
    assert cl.backend.read(t1, c1) == b"c1"
    assert cl.backend.read(t1, c2) == b"c2"
    assert cl.sim.net.one_sided_reads == reads_before + 1


def test_tbox_moves_with_owner():
    cl, t0, t1, _ = make()
    head = cl.backend.alloc(t0, 64, b"head")
    child = cl.backend.alloc(t0, 64, b"child", tie_to=head)
    cl.backend.write(t1, head, b"head2")        # move the group
    assert A.server_of(head.g) == 1
    assert A.server_of(child.g) == 1            # tied child moved too


def test_move_on_overflow():
    cl, t0, _, _ = make()
    b = cl.backend.alloc(t0, 64, 0)
    b.g = A.append_color(b.g, A.MAX_COLOR)      # force the edge
    cl.drust._mirror_color(b.g)
    raw0 = A.clear_color(b.g)
    cl.backend.write(t0, b, 1)
    assert A.get_color(b.g) == 0                # reset
    assert A.clear_color(b.g) != raw0           # relocated
