"""Checkpointing (color-versioned, elastic) and sharding-rule tests."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro import configs
from repro.checkpoint import CheckpointManager, restore, save
from repro.core.jaxstate import OwnedState
from repro.dist.sharding import (_fit, activation_spec, batch_specs,
                                 param_specs)
from repro.models import init_params

KEY = jax.random.PRNGKey(0)


def fake_mesh(**shape):
    return types.SimpleNamespace(shape=shape)


# ---------------------------------------------------------------- sharding
def test_fit_drops_nondividing_axes():
    m = fake_mesh(data=16, model=16)
    assert _fit(m, P("data", "model"), (32, 32)) == P("data", "model")
    assert _fit(m, P("data", "model"), (8, 32)) == P(None, "model")
    m2 = fake_mesh(pod=2, data=16, model=16)
    assert _fit(m2, P(("pod", "data"), None), (7,)) == P(None)


def test_fit_keeps_divisible_prefix_of_tuple():
    m = fake_mesh(pod=2, data=16, model=16)
    # 16 % (2*16) != 0 but 16 % 2 == 0: keep the pod prefix only
    spec = _fit(m, P(("pod", "data")), (16,))
    assert spec == P(("pod",))


def test_param_specs_cover_every_leaf():
    m = fake_mesh(data=16, model=16)
    for arch in ["qwen3_moe_235b", "recurrentgemma_9b", "rwkv6_3b"]:
        cfg = configs.get(arch)
        abstract = jax.eval_shape(lambda k: init_params(cfg, k), KEY)
        specs = param_specs(m, abstract)
        flat_p = jax.tree.leaves(abstract)
        flat_s = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            for dim, axes in zip(leaf.shape, tuple(spec)):
                if axes is None:
                    continue
                n = 1
                for a in (axes if isinstance(axes, tuple) else (axes,)):
                    n *= m.shape[a]
                assert dim % n == 0, f"{arch}: {leaf.shape} vs {spec}"


def test_experts_sharded_over_model():
    m = fake_mesh(data=16, model=16)
    cfg = configs.get("qwen3_moe_235b")
    abstract = jax.eval_shape(lambda k: init_params(cfg, k), KEY)
    specs = param_specs(m, abstract)
    wg = specs["layers"]["moe"]["w_gate"]
    assert tuple(wg)[1] == "model"      # leading L dim, then experts


def test_activation_and_batch_specs():
    m = fake_mesh(data=16, model=16)
    assert activation_spec(m, (256, 4096, 1024)) == P(("data",), "model", None)
    assert activation_spec(m, (1, 1, 1024)) == P(None, None, None)
    b = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    spec = jax.tree.leaves(batch_specs(m, b),
                           is_leaf=lambda x: isinstance(x, P))[0]
    assert spec[0] in (("data",), "data") and len(spec) <= 2


# -------------------------------------------------------------- checkpoint
def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    save(tmp_path / "ck", tree, color=7, step=7)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out, manifest = restore(tmp_path / "ck", like)
    assert manifest["color"] == 7
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_quantized_save_restore_roundtrip(tmp_path):
    """PR-2 follow-on: int8 on disk via ``repro.dist.compression``, exact
    small leaves, transparent dequantize on restore, error-feedback bound
    honored, and a real size win."""
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32),
            "b": {"scale": jnp.asarray([1.5, -2.0], jnp.float32),  # small
                  "h": jnp.asarray(rng.standard_normal(256),
                                   jnp.bfloat16)},
            "step": jnp.asarray(17, jnp.int32)}
    save(tmp_path / "q", tree, color=3, step=3, quantize=True)
    save(tmp_path / "full", tree, color=3, step=3)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    out, manifest = restore(tmp_path / "q", like)
    # dtypes/structure restored; large float leaves are marked quantized
    assert manifest["leaves"]["w"]["quantized"]
    assert manifest["leaves"]["b/h"]["quantized"]
    assert "quantized" not in manifest["leaves"]["b/scale"]
    assert "quantized" not in manifest["leaves"]["step"]
    assert out["w"].dtype == jnp.float32
    assert out["b"]["h"].dtype == jnp.bfloat16
    # small/integer leaves are bit-exact
    np.testing.assert_array_equal(np.asarray(out["b"]["scale"]),
                                  np.asarray(tree["b"]["scale"]))
    assert int(out["step"]) == 17
    # error-feedback bound: |x - deq| <= scale/2 = amax/254
    w = np.asarray(tree["w"], np.float32)
    bound = np.abs(w).max() / 254 + 1e-7
    assert np.abs(np.asarray(out["w"], np.float32) - w).max() <= bound
    # the quantized snapshot is genuinely smaller on disk (~4x on floats)
    q_bytes = (tmp_path / "q.npz").stat().st_size
    full_bytes = (tmp_path / "full.npz").stat().st_size
    assert q_bytes < full_bytes * 0.5


def test_manager_quantized_checkpoints(tmp_path):
    state = OwnedState("s", {"w": jnp.linspace(-1.0, 1.0, 128)})
    mgr = CheckpointManager(tmp_path, state, quantize=True)
    with state.borrow_mut() as m:
        m.set({"w": jnp.linspace(-2.0, 2.0, 128)})
    assert mgr.saved
    like = {"w": jax.ShapeDtypeStruct((128,), jnp.float32)}
    tree, manifest = mgr.restore_latest(like)
    assert manifest["leaves"]["w"]["quantized"]
    np.testing.assert_allclose(np.asarray(tree["w"]),
                               np.linspace(-2.0, 2.0, 128), atol=2.0 / 127)


def test_manager_epoch_batched(tmp_path):
    state = OwnedState("s", {"w": jnp.zeros(4)})
    mgr = CheckpointManager(tmp_path, state, every_n_epochs=2, keep=2)
    for i in range(6):
        with state.borrow_mut() as m:
            m.set({"w": jnp.full(4, float(i))})
    assert len(mgr.saved) == 2          # keep=2 enforced
    colors = [c for c, _ in mgr.saved]
    assert colors == [4, 6]             # every 2nd epoch
    tree, man = mgr.restore_latest({"w": jax.ShapeDtypeStruct((4,),
                                                              jnp.float32)})
    assert man["color"] == 6
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.full(4, 5.0))
    assert state.color == 6


def test_restore_resumes_training(tmp_path):
    """Kill-and-restart: restored state continues from the saved epoch."""
    from repro.train import OptConfig, TrainState, synthetic_batches
    cfg = configs.smoke("qwen3_0_6b")
    params = init_params(cfg, KEY)
    opt = OptConfig(lr=3e-3, warmup=2, decay_steps=50)
    ts = TrainState(cfg, opt, params)
    mgr = CheckpointManager(tmp_path, ts.state, every_n_epochs=1)
    data = synthetic_batches(cfg.vocab, 4, 32)
    batches = [jax.tree.map(jnp.asarray, next(data)) for _ in range(4)]
    for b in batches[:3]:
        ts.step(b)
    # "crash": build a new TrainState and restore
    ts2 = TrainState(cfg, opt, init_params(cfg, jax.random.PRNGKey(9)))
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        ts.state.read())
    tree, man = restore(mgr.saved[-1][1], like)
    ts2.state._tree = tree
    assert man["color"] == 3
    m = ts2.step(batches[3])
    assert np.isfinite(float(m["loss"]))
