"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
in interpret mode (the TPU dataflow executed in Python)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypcompat import given, settings, st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def arr(*shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


@pytest.mark.parametrize("B,H,Hkv,T,hd,bq,bk", [
    (1, 2, 2, 128, 64, 64, 64),       # MHA
    (2, 4, 2, 256, 64, 128, 128),     # GQA
    (1, 4, 1, 128, 128, 64, 64),      # MQA
    (1, 2, 2, 192, 64, 64, 64),       # non-power-of-two T
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, Hkv, T, hd, bq, bk, dtype):
    q = arr(B, H, T, hd, dtype=dtype)
    k = arr(B, Hkv, T, hd, dtype=dtype)
    v = arr(B, Hkv, T, hd, dtype=dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    exp = ref.attention(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    q, k, v = arr(1, 2, 64, 64), arr(1, 2, 64, 64), arr(1, 2, 64, 64)
    out = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    exp = ref.attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("B,H,Hkv,S,hd", [
    (2, 4, 2, 256, 64),
    (1, 8, 1, 512, 128),              # MQA long cache
    (3, 6, 6, 128, 64),
])
def test_decode_attention(B, H, Hkv, S, hd):
    q = arr(B, H, hd)
    k = arr(B, Hkv, S, hd)
    v = arr(B, Hkv, S, hd)
    lengths = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    out = ops.decode_attention(q, k, v, lengths, block_k=128)
    exp = ref.decode_attention(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(e=st.sampled_from([1, 2, 4]), c=st.sampled_from([64, 128]),
       d=st.sampled_from([128, 256]), f=st.sampled_from([64, 128]))
def test_moe_gmm_property(e, c, d, f):
    x = arr(e, c, d)
    w = arr(e, d, f)
    out = ops.moe_gmm(x, w, block_c=64, block_f=64, block_d=64)
    exp = ref.moe_gmm(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-3, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_dtypes(dtype):
    x = arr(2, 128, 128, dtype=dtype)
    w = arr(2, 128, 128, dtype=dtype)
    out = ops.moe_gmm(x, w, block_c=64, block_f=64, block_d=64)
    exp = ref.moe_gmm(x, w)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("B,H,T,M,chunk", [
    (1, 1, 64, 16, 16),
    (2, 2, 128, 32, 32),
    (1, 2, 96, 16, 32),               # ragged chunk count
])
def test_rwkv_scan(B, H, T, M, chunk):
    r, k, v = arr(B, H, T, M), arr(B, H, T, M), arr(B, H, T, M)
    logw = -0.105 * jax.nn.sigmoid(arr(B, H, T, M))
    u = arr(H, M, scale=0.1)
    o, S = ops.rwkv_scan(r, k, v, logw, u, chunk=chunk)
    oe, Se = ref.rwkv_scan(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oe),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(S), np.asarray(Se),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("B,T,D,chunk,bd", [
    (1, 64, 64, 32, 64),
    (2, 128, 128, 32, 64),
    (2, 256, 64, 64, 32),
])
def test_rglru_scan(B, T, D, chunk, bd):
    a = jax.nn.sigmoid(arr(B, T, D))
    b = arr(B, T, D)
    h = ops.rglru_scan(a, b, chunk=chunk, block_d=bd)
    he = ref.rglru_scan(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he),
                               rtol=2e-3, atol=2e-3)


def test_rglru_scan_strong_decay_stability():
    """Near-zero a (strong decay) must not overflow/NaN."""
    B, T, D = 1, 128, 32
    a = jnp.full((B, T, D), 1e-4, jnp.float32)
    b = arr(B, T, D)
    h = ops.rglru_scan(a, b, chunk=32, block_d=32)
    assert np.isfinite(np.asarray(h)).all()
    np.testing.assert_allclose(np.asarray(h), np.asarray(ref.rglru_scan(a, b)),
                               rtol=1e-4, atol=1e-4)
