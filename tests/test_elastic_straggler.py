"""Elastic scaling (mesh-to-mesh checkpoint restore, live protocol
rescale) and straggler mitigation (controller drains slow servers)."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import Cluster


def test_straggler_detection_and_mitigation():
    cl = Cluster(4, backend="drust")
    ths = []
    for s in range(4):
        for _ in range(2):
            th = cl.main_thread(0)
            th.server = s
            ths.append(th)
    cl.sim.degrade(3, 8.0)               # server 3 throttled 8x
    assert cl.controller.detect_stragglers() == [3]
    moved = cl.controller.mitigate_stragglers()
    assert moved == 2                     # both of server 3's threads drained
    assert all(t.server != 3 for t in ths)


def test_straggler_mitigation_improves_makespan():
    def run(mitigate: bool) -> float:
        cl = Cluster(4, backend="drust")
        ths = []
        for s in range(4):
            th = cl.main_thread(0)
            th.server = s
            ths.append(th)
        cl.sim.degrade(2, 10.0)
        if mitigate:
            cl.controller.mitigate_stragglers()
        for i in range(40):               # 40 equal work items, round robin
            cl.sim.compute(ths[i % 4], 2.6e6)   # 1 ms healthy
        return cl.makespan_us()

    assert run(True) < run(False) * 0.5   # >2x makespan win


def test_straggler_heap_stays_readable():
    """Mitigation moves compute only — the straggler's partition serves."""
    cl = Cluster(3, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    box = cl.backend.alloc(t0, 64, b"data", server=2)
    cl.sim.degrade(2, 50.0)
    cl.controller.mitigate_stragglers()
    assert cl.backend.read(t1, box) == b"data"


def test_live_protocol_rescale_in_process():
    """Shrink (crash + probe-declare + fail-over) then grow (add_server):
    the full driver behind ``python -m repro.launch.elastic --protocol``."""
    from repro.launch.elastic import run_protocol
    assert run_protocol(n_servers=4, verbose=False)


def test_probe_ladder_declares_after_miss_limit():
    """The controller declares a failing-undeclared server only after
    PROBE_MISS_LIMIT consecutive missed probes, charging the retry-timeout
    ladder to the prober's clock (degraded mode, not an instant oracle)."""
    cl = Cluster(3, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    box = cl.backend.alloc(t1, 64, b"x", server=1)
    cl.replicator.flush_epoch()
    cl.recovery.crash(1)
    limit = cl.controller.PROBE_MISS_LIMIT
    t_before = t0.t_us
    for i in range(limit - 1):
        assert cl.controller.probe_failures(t0) == []
    assert 1 in cl.sim.failing and 1 not in cl.sim.failed
    assert cl.controller.probe_failures(t0) == [1]       # strike `limit`
    # declared + failed over: compute is lost, partition index rehosted
    assert 1 in cl.sim.lost and 1 in cl.sim.rehosted
    assert 1 not in cl.sim.failing and 1 not in cl.sim.failed
    assert cl.sim.net.degraded_retries >= limit
    assert t0.t_us >= t_before + limit * cl.sim.cost.retry_timeout_us
    assert cl.recovery.reports[-1].server == 1
    # sync verbs to a FAILING server burned the ladder; now that it is
    # declared and rehosted, the address serves from the promoted backup
    assert cl.backend.read(t0, box) == b"x"


def test_grow_after_shrink_controller_uses_new_server():
    """After a shrink the controller never places work on the dead member;
    after a grow it allocates on the new one."""
    cl = Cluster(3, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    for s in range(3):
        cl.backend.alloc(t0, 64, s, server=s)
    cl.replicator.flush_epoch()
    cl.recovery.fail_and_recover(2, t0)
    assert cl.sim.alive_servers() == [0, 1]
    for _ in range(8):                    # placement avoids the dead server
        assert cl.controller.pick_alloc_server(0, 64) != 2
        assert cl.controller.pick_spawn_server() != 2
    s_new = cl.add_server()
    assert s_new == 3
    assert cl.sim.alive_servers() == [0, 1, 3]
    th_new = cl.main_thread(s_new)
    nb = cl.backend.alloc(th_new, 64, "fresh", server=s_new)
    assert cl.backend.read(t0, nb) == "fresh"
    # replication covers the new member too
    cl.backend.write(th_new, nb, "fresh2")
    cl.replicator.flush_epoch()
    rep2 = cl.recovery.fail_and_recover(s_new, t0)
    assert rep2.rehomed_boxes >= 1
    assert cl.backend.read(t0, nb) == "fresh2"


def test_elastic_reshard_subprocess():
    """Checkpoint on a 2x4 mesh, restore onto 4x2 and 8x1."""
    env = dict(os.environ, PYTHONPATH="src")
    cwd = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for to in ("4x2", "8x1"):
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.elastic",
             "--from-mesh", "2x4", "--to-mesh", to],
            capture_output=True, text=True, timeout=600, env=env, cwd=cwd)
        assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
        assert "OK" in out.stdout
