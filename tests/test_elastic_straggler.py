"""Elastic scaling (mesh-to-mesh checkpoint restore) and straggler
mitigation (controller drains slow servers)."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import Cluster


def test_straggler_detection_and_mitigation():
    cl = Cluster(4, backend="drust")
    ths = []
    for s in range(4):
        for _ in range(2):
            th = cl.main_thread(0)
            th.server = s
            ths.append(th)
    cl.sim.degrade(3, 8.0)               # server 3 throttled 8x
    assert cl.controller.detect_stragglers() == [3]
    moved = cl.controller.mitigate_stragglers()
    assert moved == 2                     # both of server 3's threads drained
    assert all(t.server != 3 for t in ths)


def test_straggler_mitigation_improves_makespan():
    def run(mitigate: bool) -> float:
        cl = Cluster(4, backend="drust")
        ths = []
        for s in range(4):
            th = cl.main_thread(0)
            th.server = s
            ths.append(th)
        cl.sim.degrade(2, 10.0)
        if mitigate:
            cl.controller.mitigate_stragglers()
        for i in range(40):               # 40 equal work items, round robin
            cl.sim.compute(ths[i % 4], 2.6e6)   # 1 ms healthy
        return cl.makespan_us()

    assert run(True) < run(False) * 0.5   # >2x makespan win


def test_straggler_heap_stays_readable():
    """Mitigation moves compute only — the straggler's partition serves."""
    cl = Cluster(3, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    box = cl.backend.alloc(t0, 64, b"data", server=2)
    cl.sim.degrade(2, 50.0)
    cl.controller.mitigate_stragglers()
    assert cl.backend.read(t1, box) == b"data"


def test_elastic_reshard_subprocess():
    """Checkpoint on a 2x4 mesh, restore onto 4x2 and 8x1."""
    env = dict(os.environ, PYTHONPATH="src")
    cwd = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for to in ("4x2", "8x1"):
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.elastic",
             "--from-mesh", "2x4", "--to-mesh", to],
            capture_output=True, text=True, timeout=600, env=env, cwd=cwd)
        assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
        assert "OK" in out.stdout
