"""End-to-end behaviour tests for the whole system."""

import subprocess
import sys

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs


def test_end_to_end_train_with_failure_recovery():
    """Train a reduced model, inject a failure mid-run, recover from the
    epoch backup, and still end with a lower loss than we started."""
    from repro.models import init_params
    from repro.train import OptConfig, TrainState, synthetic_batches
    cfg = configs.smoke("starcoder2_3b")
    ts = TrainState(cfg, OptConfig(lr=3e-3, warmup=2, decay_steps=60),
                    init_params(cfg, jax.random.PRNGKey(0)))
    ts.replicate()
    data = synthetic_batches(cfg.vocab, 8, 64)
    losses = []
    for step in range(14):
        losses.append(float(ts.step(jax.tree.map(jnp.asarray,
                                                 next(data)))["loss"]))
        if step == 7:
            ts.restore_from_backup()    # simulated node failure
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_end_to_end_serve_with_online_weight_update():
    """Serve while a writer bumps the weight color: replicas refresh via the
    colored cache, requests complete, zero invalidation traffic."""
    from repro.core.jaxstate import OwnedState
    from repro.models import init_params
    from repro.serve import ServeEngine
    cfg = configs.smoke("qwen3_0_6b")
    weights = OwnedState("w", init_params(cfg, jax.random.PRNGKey(0)))
    eng = ServeEngine(cfg, weights, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab, 6)), max_new=3)
            for _ in range(4)]
    steps = 0
    while eng.queue or eng.active:
        eng.step()
        steps += 1
        if steps == 3:                  # online update mid-serving
            with weights.borrow_mut() as m:
                m.set(jax.tree.map(lambda x: x, m.deref_mut()))
        assert steps < 100
    assert all(r.done for r in reqs)
    assert eng.weight_cache.refreshes == 2


def test_dsm_and_ml_stack_share_protocol_semantics():
    """The same coherence rules govern both layers: a write epoch changes
    the colored address in the DSM *and* in the JAX state store."""
    from repro.core import Cluster
    from repro.core.jaxstate import OwnedState
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    box = cl.backend.alloc(t0, 64, b"v0")
    g_seen = box.g
    cl.backend.read(t1, box)
    cl.backend.write(t1, box, b"v1")
    assert box.g != g_seen

    state = OwnedState("params", {"w": jnp.zeros(2)})
    addr_seen = state.addr
    with state.borrow_mut() as m:
        m.set({"w": jnp.ones(2)})
    assert state.addr != addr_seen


def test_dryrun_smoke_subprocess():
    """The dry-run harness itself: 8 host devices, 2x4 mesh, reduced arch."""
    import os
    env = dict(os.environ,
               DRYRUN_XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen3-0.6b",
         "--shape", "train_4k", "--mesh", "2x4", "--smoke",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, timeout=600, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "ALL 1 cells OK" in out.stdout


def test_collective_bytes_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[16,256,4096]{2,1,0} all-gather(%x), channel_id=1, replica_groups=[2,4]<=[8], dimensions={1}, metadata={op_name="jit(f)/while/body/ag"}
  %ar = f32[1024]{0} all-reduce(%y), channel_id=2, replica_groups=[4,2]<=[8], to_apply=%add, metadata={op_name="jit(f)/ar"}
  %rs = f32[64,64]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[2,4]<=[8], dimensions={0}
"""
    out = collective_bytes(hlo, while_mult=10)
    ag = 16 * 256 * 4096 * 2 * (3 / 4) * 10        # in while: x10
    ar = 1024 * 4 * 2 * (1 / 2)
    rs = 64 * 64 * 4 * 3
    assert abs(out["all-gather"] - ag) / ag < 1e-6
    assert abs(out["all-reduce"] - ar) / ar < 1e-6
    assert abs(out["reduce-scatter"] - rs) / rs < 1e-6
