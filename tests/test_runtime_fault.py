"""Runtime layer: scheduler/migration, controller policies, channels,
shared-state sync, and fault tolerance (replication + promotion +
crash-consistent fail-over)."""

import numpy as np
import pytest

from repro.core import (Channel, Cluster, DAtomic, DMutex, ServerLostError,
                        addr as A)
from repro.core.fault import Replicator


def test_spawn_and_spawn_to():
    cl = Cluster(4, backend="drust")
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"x", server=2)
    th = cl.scheduler.spawn_to(box, lambda th: th.server, parent=t0)
    assert th.server == 2
    assert cl.scheduler.join(th) == 2


def test_migration_latency_and_state():
    cl = Cluster(8, backend="drust")
    th = cl.main_thread(0)
    th.stack_bytes = 1 << 20
    lat = cl.scheduler.migrate(th, 5)
    assert th.server == 5
    assert 150 <= lat <= 300            # paper: ~218 us for ~1 MiB stacks
    assert cl.controller.thread_table[th.tid] == 5


def test_controller_alloc_spills_under_pressure():
    cl = Cluster(2, backend="drust", partition_bytes=1 << 20)
    t0 = cl.main_thread(0)
    # fill server 0 past the 90% watermark
    cl.backend.alloc(t0, int(0.95 * (1 << 20)), b"")
    target = cl.controller.pick_alloc_server(0, 1 << 16)
    assert target == 1


def test_controller_migrates_remote_heavy_thread():
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t0.remote_accesses[1] = 500
    cl.sim.servers[0].cpu_busy_us = 1e6     # server 0 saturated
    moved = cl.controller.balance(horizon_us=1e4)
    assert moved == 1 and t0.server == 1


def test_channel_passes_references_without_serialization():
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    box = cl.backend.alloc(t0, 4096, b"payload" * 512)
    ch = Channel(cl)
    ch.recv_server = 1
    bytes_before = cl.sim.net.bytes_moved
    ch.send(t0, box)
    got = ch.recv(t1)
    wire = cl.sim.net.bytes_moved - bytes_before
    assert wire <= 64                   # pointer bytes only, not the payload
    assert cl.backend.read(t1, got) == b"payload" * 512


def test_atomics_serialize_at_home():
    cl = Cluster(2, backend="drust")
    ths = []
    for s in range(2):
        th = cl.main_thread(0); th.server = s
        ths.append(th)
    a = DAtomic(cl, ths[0], init=0)
    for i in range(10):
        a.fetch_add(ths[i % 2], 1)
    assert a.load(ths[0]) == 10


def test_mutex_mutual_exclusion_clock():
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    m = DMutex(cl, t0, value=0)

    def crit(obj, th):
        cl.sim.busy(th, 10.0)
        obj.data += 1
        return obj.data

    m.with_lock(t0, lambda o: crit(o, t0))
    m.with_lock(t1, lambda o: crit(o, t1))
    assert cl.heap.get(A.clear_color(m.h.g) if hasattr(m.h, "g")
                       else m.h.raw).data == 2
    assert m.acquisitions == 2


def test_replication_flush_and_promote():
    cl = Cluster(3, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    b1 = cl.backend.alloc(t0, 64, b"committed")
    b2 = cl.backend.alloc(t0, 64, b"other", server=1)
    cl.replicator.flush_epoch()
    cl.backend.write(t0, b1, b"dirty-after-flush")   # not yet flushed
    cl.replicator.fail(0)
    restored = cl.replicator.promote(0)
    assert restored >= 1
    t1 = cl.main_thread(0); t1.server = 1
    # flushed epoch survives; the unflushed write is lost (epoch semantics)
    val = cl.backend.read(t1, b1)
    assert val == b"committed"
    assert cl.backend.read(t1, b2) == b"other"


def test_writeback_batched_until_transfer():
    cl = Cluster(2, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    b = cl.backend.alloc(t0, 64, 0)
    flushes0 = cl.replicator.flushes
    for i in range(5):
        cl.backend.write(t0, b, i)      # writes batch, no flush yet
    assert cl.replicator.flushes == flushes0
    cl.drust.transfer(t0, b, 1)         # visibility point -> flush
    assert cl.replicator.flushes == flushes0 + 1


def test_writeback_state_cleared_on_retire_and_tid_reuse():
    """Per-thread completion state is keyed by thread id; after an elastic
    rescale the ids are reused, so a retiring thread must clear its state —
    otherwise the next thread with the same id inherits stale completion
    tails and gets charged for write-backs it never posted."""
    cl = Cluster(2, backend="drust", ooo=True, qps_per_thread=2)
    t0 = cl.main_thread(0)
    cid = cl.sim.wb.post(t0, 1, 1 << 20)      # completes far in the future
    late = cl.sim.wb.pending_completion_us
    assert late > 100
    cl.scheduler.retire(t0)
    # the retiree's in-flight cost still bounds the makespan ...
    assert cl.makespan_us() >= late
    # ... its per-thread QP state is gone ...
    assert (t0.tid, 0) not in cl.sim._qp_tail
    assert (t0.tid, 0) not in cl.sim._qp_done
    # ... and a live thread depending on the retiree's write-back still
    # waits for it (cids are global; retirement does not lose dependencies)
    waiter = cl.main_thread(0)
    cl.sim.wb.fence(waiter, cid)
    assert waiter.t_us >= late - 1e-9
    # rescale boundary: snapshot ends the epoch, then a thread reusing the
    # id starts with a clean slate
    cl.sim.snapshot()
    t1 = cl.main_thread(0)
    t1.tid = t0.tid                           # elastic rescale reuses the id
    cl.sim.wb.fence_all(t1)
    assert t1.t_us == 0.0                     # no inherited completion tail


def test_snapshot_ends_epoch_and_clears_writeback_tails():
    """``Sim.snapshot()`` closes an observation epoch: pending per-thread
    write-back state is cleared so reused thread ids in the next epoch
    cannot observe it (makespan must be computed before snapshotting)."""
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    cl.sim.wb.post(t0, 1, 4096)
    assert cl.sim.wb.pending_completion_us > 0
    span = cl.makespan_us()
    snap = cl.sim.snapshot()
    assert snap["net"]["async_writebacks"] == 1
    assert span >= 3.5                        # wb completion was in the span
    assert cl.sim.wb.pending_completion_us == 0.0
    t1 = cl.main_thread(0)
    t1.tid = t0.tid
    cl.sim.wb.fence_all(t1)
    assert t1.t_us == 0.0
    # Sim.reset() also clears the plane and zeroes the stats
    cl.sim.wb.post(t1, 1, 4096)
    cl.sim.reset()
    assert cl.sim.wb.pending_completion_us == 0.0
    assert cl.sim.net.async_writebacks == 0


# --------------------------------------------------------------------------
#  Replicator regressions (promote sizing, hook chaining, cache quarantine)
# --------------------------------------------------------------------------
def test_promote_restores_exact_sizes():
    """Regression: promote must restore each object with the size captured
    at flush time.  Recomputing it at promote time drifts for payloads with
    no intrinsic byte measure (a list allocated as 1000 bytes re-measures
    as the 64-byte default), corrupting ``partition.used`` accounting."""
    cl = Cluster(3, backend="drust", replicate=True)
    t1 = cl.main_thread(0); t1.server = 1
    box = cl.backend.alloc(t1, 1000, list(range(10)), server=1)
    cl.replicator.flush_epoch()
    part = cl.heap.partitions[1]
    used_before = part.used
    cl.replicator.fail(1)
    assert part.used == 0
    cl.replicator.promote(1)
    assert part.used == used_before
    assert part.get(A.clear_color(box.g)).size == 1000


def test_replicator_chains_hooks_and_rejects_second():
    """Regression: attaching the replicator must CHAIN the runtime's FT
    hooks (a pre-installed observer keeps firing), not clobber them; and a
    second replicator on the same runtime is a configuration error."""
    cl = Cluster(2, backend="drust")
    seen = []
    cl.drust.on_alloc = lambda raw: seen.append(("alloc", raw))
    cl.drust.on_free = lambda raw: seen.append(("free", raw))
    rep = Replicator(cl)
    cl.replicator = rep
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"x")
    raw = A.clear_color(box.g)
    assert ("alloc", raw) in seen        # pre-installed hook still fired ...
    assert raw in rep.pending            # ... and so did the replicator's
    cl.backend.drop(t0, box)
    assert ("free", raw) in seen
    assert raw not in rep.pending
    with pytest.raises(RuntimeError):
        Replicator(cl)


def test_fail_quarantines_surviving_cache_copies():
    """Regression: ``Replicator.fail`` must scrub surviving servers' cached
    copies of the dead server's boxes — they may hold writes that died
    unflushed.  Unpinned copies invalidate on the spot; pinned copies (open
    ReadGuards) go *suspect*: the holder keeps its frozen snapshot, new
    lookups miss, and the copy frees at the last unpin."""
    cl = Cluster(3, backend="drust", replicate=True)
    t1 = cl.main_thread(0); t1.server = 1
    t0 = cl.main_thread(0)
    box_a = cl.backend.alloc(t1, 64, b"a0", server=1)
    box_b = cl.backend.alloc(t1, 64, b"b0", server=1)
    cl.replicator.flush_epoch()
    cl.backend.write(t1, box_a, b"a1-dirty")
    cl.backend.write(t1, box_b, b"b1-dirty")
    # warm (unpinned) copies of the dirty bytes on server 0 ...
    assert cl.backend.read(t0, box_a) == b"a1-dirty"
    assert cl.backend.read(t0, box_b) == b"b1-dirty"
    cache = cl.drust.caches[0]
    assert box_a.g in cache.entries and box_b.g in cache.entries
    # ... and a pinned one: an open ReadGuard freezes box_b's snapshot
    g = box_b.read(t0)
    frozen = g.__enter__()
    cl.replicator.fail(1)
    assert box_a.g not in cache.entries          # unpinned -> invalidated
    assert cache.entries[box_b.g].suspect        # pinned -> suspect
    assert g.value == frozen == b"b1-dirty"      # holder keeps the snapshot
    assert cache.lookup(box_b.g) is None         # new lookups miss
    assert cl.sim.net.suspect_invalidations == 2
    g.close()
    assert box_b.g not in cache.entries          # freed at the last unpin


def test_int8_checkpoint_fallback_restores_unreplicated():
    """Objects that never reached the replica map restore from the int8
    partition checkpoint: lossy (quantized) for float ndarrays, exact for
    everything else."""
    cl = Cluster(2, backend="drust", replicate=True)
    t1 = cl.main_thread(0); t1.server = 1
    arr = np.linspace(-1.0, 1.0, 64, dtype=np.float32)
    fbox = cl.backend.alloc(t1, arr.nbytes, arr, server=1)
    ibox = cl.backend.alloc(t1, 64, [7, 8, 9], server=1)
    cl.replicator.checkpoint_epoch()             # never flush_epoch'd
    t0 = cl.main_thread(0)
    rep = cl.recovery.fail_and_recover(1, t0)
    assert rep.rehomed_boxes == 2 and rep.lost_boxes == 0
    got = cl.backend.read(t0, fbox)
    assert np.allclose(got, arr, atol=1.0 / 127 + 1e-6)   # quantized
    assert cl.backend.read(t0, ibox) == [7, 8, 9]         # exact


def test_moved_object_replica_follows_not_resurrects():
    """Regression: a remote mutable deref MOVES the object to the writer's
    partition; the replica keyed by the old (freed) address must follow it.
    A crash of the old home must not restore stale bytes at a freed —
    possibly reused — address, and a crash of the NEW home must still
    revert to the last flushed epoch."""
    cl = Cluster(3, backend="drust", replicate=True)
    t1 = cl.main_thread(0); t1.server = 1
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t1, 64, b"v0", server=1)
    old_raw = A.clear_color(box.g)
    cl.replicator.flush_epoch()
    cl.backend.write(t0, box, b"v1")          # remote write: moves to server 0
    new_raw = A.clear_color(box.g)
    assert A.server_of(new_raw) == 0 and new_raw != old_raw
    assert old_raw not in cl.replicator.replicas[1]   # replica followed
    cl.recovery.fail_and_recover(1, t0)
    # nothing resurrected at the freed old address; the live copy is intact
    assert not cl.heap.partitions[1].contains(old_raw)
    assert cl.backend.read(t0, box) == b"v1"
    # now flush at the NEW home and crash it: reverts to the flushed epoch
    cl.replicator.flush_epoch()
    assert cl.replicator.backup_of[0] not in cl.sim.lost   # re-enlisted
    cl.backend.write(t0, box, b"v2-dirty")    # local write, no move
    t2 = cl.main_thread(0); t2.server = 2
    rep2 = cl.recovery.fail_and_recover(0, t2)
    assert rep2.lost_writes == 1
    assert cl.backend.read(t2, box) == b"v1"


# --------------------------------------------------------------------------
#  Fail-over x scoped guards (crash-consistency at the API surface)
# --------------------------------------------------------------------------
def test_crash_breaks_open_write_guard():
    """A surviving holder's open WriteGuard on a dead-home box: the
    write-back can never land, so the guard surfaces a structured
    ``ServerLostError`` and releases the borrow WITHOUT writing back —
    the box reverts to its last flushed epoch."""
    cl = Cluster(3, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"flushed", server=2)
    cl.replicator.flush_epoch()
    g = box.write(t0)
    g.__enter__()                                # borrow taken, not deref'd
    rep = cl.recovery.fail_and_recover(2, t0)
    assert rep.broken_guards == 1
    with pytest.raises(ServerLostError) as ei:
        g.set(b"never lands")
    assert ei.value.server == 2
    with pytest.raises(ServerLostError):
        g.close()                                # drop raises, does NOT leak
    assert not box.live_mut and not box.mut_broken and box.mut_tid is None
    # the borrow is fully released: reads and fresh writes work again
    assert cl.backend.read(t0, box) == b"flushed"
    cl.backend.write(t0, box, b"post-recovery")
    assert cl.backend.read(t0, box) == b"post-recovery"


def test_crash_inside_region_keeps_pinned_snapshots():
    """Crash inside ``cluster.region`` with pins: the pinned ReadGuards
    keep serving their frozen (possibly dirty) snapshots for the rest of
    the scope; after the region exits, readers see the restored epoch."""
    cl = Cluster(3, backend="drust", replicate=True, coalesce="auto")
    t2 = cl.main_thread(0); t2.server = 2
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t2, 64, b"epoch0", server=2)
    cl.replicator.flush_epoch()
    cl.backend.write(t2, box, b"epoch1-dirty")   # dirty past the flush
    with cl.region(t0, pin=[box]) as r:
        assert r._pins[0].value == b"epoch1-dirty"
        rep = cl.recovery.fail_and_recover(2, t0)
        assert rep.lost_writes == 1
        # the pin still serves the frozen snapshot inside the scope
        assert r._pins[0].value == b"epoch1-dirty"
    # region exited, pins released: the stale copy is gone — readers get
    # the restored flushed epoch, never the resurrected dirty bytes
    assert cl.backend.read(t0, box) == b"epoch0"


def test_unflushed_writes_reported_not_resurrected():
    """Crash between ``flush_epoch`` boundaries: the dirty write is LOST
    (reported in the recovery receipt), and a pre-crash warm cache copy of
    the dirty bytes must not resurrect it."""
    cl = Cluster(3, backend="drust", replicate=True)
    t1 = cl.main_thread(0); t1.server = 1
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t1, 64, b"v0", server=1)
    cl.replicator.flush_epoch()                  # epoch boundary
    cl.backend.write(t1, box, b"v1")             # dirty, unflushed
    assert cl.backend.read(t0, box) == b"v1"     # warm copy of dirty bytes
    rep = cl.recovery.fail_and_recover(1, t0)
    assert rep.lost_writes == 1
    assert cl.sim.net.lost_writes == 1
    assert rep.dead_threads == 1                 # t1 died with the server
    assert cl.backend.read(t0, box) == b"v0"     # reverted, not resurrected


def test_unreplicated_unflushed_box_is_lost():
    """No replica, no checkpoint: the box is gone — uses raise a structured
    ``ServerLostError`` instead of returning garbage."""
    cl = Cluster(3, backend="drust", replicate=True)
    t1 = cl.main_thread(0); t1.server = 1
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t1, 64, b"never-flushed", server=1)
    rep = cl.recovery.fail_and_recover(1, t0)
    assert rep.lost_boxes == 1 and box.lost
    with pytest.raises(ServerLostError):
        cl.backend.read(t0, box)
    with pytest.raises(ServerLostError):
        box.write(t0).__enter__()


def test_crash_breaks_dead_holders_lock():
    """A DMutex held by a thread that died with its server is broken with
    lock-state reconstruction: the holder slot clears, later acquirers
    serialize behind the recovery barrier instead of deadlocking."""
    cl = Cluster(3, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    t2 = cl.main_thread(0); t2.server = 2
    m = DMutex(cl, t0, value=0)
    assert m in cl.mutexes

    def section(obj):
        cl.sim.busy(t2, 50.0)
        rep = cl.recovery.fail_and_recover(2, t0)
        assert rep.broken_locks == 1
        raise ServerLostError(2, "holder died mid-critical-section")

    with pytest.raises(ServerLostError):
        m.with_lock(t2, section)
    assert m.broken == 1 and m._holder is None
    assert cl.sim.net.broken_locks == 1
    # a survivor acquires; its hold starts at/after the recovery barrier
    m.with_lock(t0, lambda o: o)
    assert m.acquisitions == 2


def test_dead_thread_borrows_force_released():
    """Borrows held by threads that died with the server are force-released
    through the per-tid ledger — survivors can re-borrow (no leak), even
    when the box itself lives on a SURVIVING server."""
    cl = Cluster(3, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    t2 = cl.main_thread(0); t2.server = 2
    box = cl.backend.alloc(t0, 64, b"home-on-0", server=0)
    cl.replicator.flush_epoch()
    r = box.borrow(t2)                           # dead-thread-to-be's borrow
    assert box.live_refs == 1
    rep = cl.recovery.fail_and_recover(2, t0)
    assert rep.released_borrows == 1
    assert box.live_refs == 0 and not box.ref_tids
    # the survivor takes a write borrow: nothing leaked
    cl.backend.write(t0, box, b"after")
    assert cl.backend.read(t0, box) == b"after"


def test_mem_pressure_evicts_incrementally_to_watermark():
    """mem>90% policy reclaims only the excess above the high-water mark
    (CLOCK partial eviction), not every unpinned copy (the old full sweep)."""
    cap = 1 << 20
    cl = Cluster(2, backend="drust", partition_bytes=cap)
    t0 = cl.main_thread(0)
    boxes = [cl.backend.alloc(t0, 60_000, b"x" * 60_000, server=1)
             for _ in range(16)]
    for b in boxes:                       # cache a copy of each on server 0
        cl.backend.read(t0, b)
    assert cl.controller.mem_frac(0) > cl.controller.MEM_HI
    n_before = len(cl.drust.caches[0].entries)
    assert n_before == 16
    cl.controller.balance(horizon_us=1e6)
    # back under the watermark ...
    assert cl.controller.mem_frac(0) <= cl.controller.MEM_HI + 1e-9
    # ... but warm copies below the mark survived (incremental, not a sweep)
    n_after = len(cl.drust.caches[0].entries)
    assert 0 < n_after < n_before
    assert n_after >= n_before - 2        # only the excess was reclaimed
