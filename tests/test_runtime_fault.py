"""Runtime layer: scheduler/migration, controller policies, channels,
shared-state sync, and fault tolerance (replication + promotion)."""

import numpy as np
import pytest

from repro.core import (Channel, Cluster, DAtomic, DMutex, addr as A)


def test_spawn_and_spawn_to():
    cl = Cluster(4, backend="drust")
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"x", server=2)
    th = cl.scheduler.spawn_to(box, lambda th: th.server, parent=t0)
    assert th.server == 2
    assert cl.scheduler.join(th) == 2


def test_migration_latency_and_state():
    cl = Cluster(8, backend="drust")
    th = cl.main_thread(0)
    th.stack_bytes = 1 << 20
    lat = cl.scheduler.migrate(th, 5)
    assert th.server == 5
    assert 150 <= lat <= 300            # paper: ~218 us for ~1 MiB stacks
    assert cl.controller.thread_table[th.tid] == 5


def test_controller_alloc_spills_under_pressure():
    cl = Cluster(2, backend="drust", partition_bytes=1 << 20)
    t0 = cl.main_thread(0)
    # fill server 0 past the 90% watermark
    cl.backend.alloc(t0, int(0.95 * (1 << 20)), b"")
    target = cl.controller.pick_alloc_server(0, 1 << 16)
    assert target == 1


def test_controller_migrates_remote_heavy_thread():
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t0.remote_accesses[1] = 500
    cl.sim.servers[0].cpu_busy_us = 1e6     # server 0 saturated
    moved = cl.controller.balance(horizon_us=1e4)
    assert moved == 1 and t0.server == 1


def test_channel_passes_references_without_serialization():
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    box = cl.backend.alloc(t0, 4096, b"payload" * 512)
    ch = Channel(cl)
    ch.recv_server = 1
    bytes_before = cl.sim.net.bytes_moved
    ch.send(t0, box)
    got = ch.recv(t1)
    wire = cl.sim.net.bytes_moved - bytes_before
    assert wire <= 64                   # pointer bytes only, not the payload
    assert cl.backend.read(t1, got) == b"payload" * 512


def test_atomics_serialize_at_home():
    cl = Cluster(2, backend="drust")
    ths = []
    for s in range(2):
        th = cl.main_thread(0); th.server = s
        ths.append(th)
    a = DAtomic(cl, ths[0], init=0)
    for i in range(10):
        a.fetch_add(ths[i % 2], 1)
    assert a.load(ths[0]) == 10


def test_mutex_mutual_exclusion_clock():
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    m = DMutex(cl, t0, value=0)

    def crit(obj, th):
        cl.sim.busy(th, 10.0)
        obj.data += 1
        return obj.data

    m.with_lock(t0, lambda o: crit(o, t0))
    m.with_lock(t1, lambda o: crit(o, t1))
    assert cl.heap.get(A.clear_color(m.h.g) if hasattr(m.h, "g")
                       else m.h.raw).data == 2
    assert m.acquisitions == 2


def test_replication_flush_and_promote():
    cl = Cluster(3, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    b1 = cl.backend.alloc(t0, 64, b"committed")
    b2 = cl.backend.alloc(t0, 64, b"other", server=1)
    cl.replicator.flush_epoch()
    cl.backend.write(t0, b1, b"dirty-after-flush")   # not yet flushed
    cl.replicator.fail(0)
    restored = cl.replicator.promote(0)
    assert restored >= 1
    t1 = cl.main_thread(0); t1.server = 1
    # flushed epoch survives; the unflushed write is lost (epoch semantics)
    val = cl.backend.read(t1, b1)
    assert val == b"committed"
    assert cl.backend.read(t1, b2) == b"other"


def test_writeback_batched_until_transfer():
    cl = Cluster(2, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    b = cl.backend.alloc(t0, 64, 0)
    flushes0 = cl.replicator.flushes
    for i in range(5):
        cl.backend.write(t0, b, i)      # writes batch, no flush yet
    assert cl.replicator.flushes == flushes0
    cl.drust.transfer(t0, b, 1)         # visibility point -> flush
    assert cl.replicator.flushes == flushes0 + 1


def test_writeback_state_cleared_on_retire_and_tid_reuse():
    """Per-thread completion state is keyed by thread id; after an elastic
    rescale the ids are reused, so a retiring thread must clear its state —
    otherwise the next thread with the same id inherits stale completion
    tails and gets charged for write-backs it never posted."""
    cl = Cluster(2, backend="drust", ooo=True, qps_per_thread=2)
    t0 = cl.main_thread(0)
    cid = cl.sim.wb.post(t0, 1, 1 << 20)      # completes far in the future
    late = cl.sim.wb.pending_completion_us
    assert late > 100
    cl.scheduler.retire(t0)
    # the retiree's in-flight cost still bounds the makespan ...
    assert cl.makespan_us() >= late
    # ... its per-thread QP state is gone ...
    assert (t0.tid, 0) not in cl.sim._qp_tail
    assert (t0.tid, 0) not in cl.sim._qp_done
    # ... and a live thread depending on the retiree's write-back still
    # waits for it (cids are global; retirement does not lose dependencies)
    waiter = cl.main_thread(0)
    cl.sim.wb.fence(waiter, cid)
    assert waiter.t_us >= late - 1e-9
    # rescale boundary: snapshot ends the epoch, then a thread reusing the
    # id starts with a clean slate
    cl.sim.snapshot()
    t1 = cl.main_thread(0)
    t1.tid = t0.tid                           # elastic rescale reuses the id
    cl.sim.wb.fence_all(t1)
    assert t1.t_us == 0.0                     # no inherited completion tail


def test_snapshot_ends_epoch_and_clears_writeback_tails():
    """``Sim.snapshot()`` closes an observation epoch: pending per-thread
    write-back state is cleared so reused thread ids in the next epoch
    cannot observe it (makespan must be computed before snapshotting)."""
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    cl.sim.wb.post(t0, 1, 4096)
    assert cl.sim.wb.pending_completion_us > 0
    span = cl.makespan_us()
    snap = cl.sim.snapshot()
    assert snap["net"]["async_writebacks"] == 1
    assert span >= 3.5                        # wb completion was in the span
    assert cl.sim.wb.pending_completion_us == 0.0
    t1 = cl.main_thread(0)
    t1.tid = t0.tid
    cl.sim.wb.fence_all(t1)
    assert t1.t_us == 0.0
    # Sim.reset() also clears the plane and zeroes the stats
    cl.sim.wb.post(t1, 1, 4096)
    cl.sim.reset()
    assert cl.sim.wb.pending_completion_us == 0.0
    assert cl.sim.net.async_writebacks == 0


def test_mem_pressure_evicts_incrementally_to_watermark():
    """mem>90% policy reclaims only the excess above the high-water mark
    (CLOCK partial eviction), not every unpinned copy (the old full sweep)."""
    cap = 1 << 20
    cl = Cluster(2, backend="drust", partition_bytes=cap)
    t0 = cl.main_thread(0)
    boxes = [cl.backend.alloc(t0, 60_000, b"x" * 60_000, server=1)
             for _ in range(16)]
    for b in boxes:                       # cache a copy of each on server 0
        cl.backend.read(t0, b)
    assert cl.controller.mem_frac(0) > cl.controller.MEM_HI
    n_before = len(cl.drust.caches[0].entries)
    assert n_before == 16
    cl.controller.balance(horizon_us=1e6)
    # back under the watermark ...
    assert cl.controller.mem_frac(0) <= cl.controller.MEM_HI + 1e-9
    # ... but warm copies below the mark survived (incremental, not a sweep)
    n_after = len(cl.drust.caches[0].entries)
    assert 0 < n_after < n_before
    assert n_after >= n_before - 2        # only the excess was reclaimed
