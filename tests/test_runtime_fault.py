"""Runtime layer: scheduler/migration, controller policies, channels,
shared-state sync, and fault tolerance (replication + promotion)."""

import numpy as np
import pytest

from repro.core import (Channel, Cluster, DAtomic, DMutex, addr as A)


def test_spawn_and_spawn_to():
    cl = Cluster(4, backend="drust")
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, b"x", server=2)
    th = cl.scheduler.spawn_to(box, lambda th: th.server, parent=t0)
    assert th.server == 2
    assert cl.scheduler.join(th) == 2


def test_migration_latency_and_state():
    cl = Cluster(8, backend="drust")
    th = cl.main_thread(0)
    th.stack_bytes = 1 << 20
    lat = cl.scheduler.migrate(th, 5)
    assert th.server == 5
    assert 150 <= lat <= 300            # paper: ~218 us for ~1 MiB stacks
    assert cl.controller.thread_table[th.tid] == 5


def test_controller_alloc_spills_under_pressure():
    cl = Cluster(2, backend="drust", partition_bytes=1 << 20)
    t0 = cl.main_thread(0)
    # fill server 0 past the 90% watermark
    cl.backend.alloc(t0, int(0.95 * (1 << 20)), b"")
    target = cl.controller.pick_alloc_server(0, 1 << 16)
    assert target == 1


def test_controller_migrates_remote_heavy_thread():
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t0.remote_accesses[1] = 500
    cl.sim.servers[0].cpu_busy_us = 1e6     # server 0 saturated
    moved = cl.controller.balance(horizon_us=1e4)
    assert moved == 1 and t0.server == 1


def test_channel_passes_references_without_serialization():
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    box = cl.backend.alloc(t0, 4096, b"payload" * 512)
    ch = Channel(cl)
    ch.recv_server = 1
    bytes_before = cl.sim.net.bytes_moved
    ch.send(t0, box)
    got = ch.recv(t1)
    wire = cl.sim.net.bytes_moved - bytes_before
    assert wire <= 64                   # pointer bytes only, not the payload
    assert cl.backend.read(t1, got) == b"payload" * 512


def test_atomics_serialize_at_home():
    cl = Cluster(2, backend="drust")
    ths = []
    for s in range(2):
        th = cl.main_thread(0); th.server = s
        ths.append(th)
    a = DAtomic(cl, ths[0], init=0)
    for i in range(10):
        a.fetch_add(ths[i % 2], 1)
    assert a.load(ths[0]) == 10


def test_mutex_mutual_exclusion_clock():
    cl = Cluster(2, backend="drust")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0); t1.server = 1
    m = DMutex(cl, t0, value=0)

    def crit(obj, th):
        cl.sim.busy(th, 10.0)
        obj.data += 1
        return obj.data

    m.with_lock(t0, lambda o: crit(o, t0))
    m.with_lock(t1, lambda o: crit(o, t1))
    assert cl.heap.get(A.clear_color(m.h.g) if hasattr(m.h, "g")
                       else m.h.raw).data == 2
    assert m.acquisitions == 2


def test_replication_flush_and_promote():
    cl = Cluster(3, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    b1 = cl.backend.alloc(t0, 64, b"committed")
    b2 = cl.backend.alloc(t0, 64, b"other", server=1)
    cl.replicator.flush_epoch()
    cl.backend.write(t0, b1, b"dirty-after-flush")   # not yet flushed
    cl.replicator.fail(0)
    restored = cl.replicator.promote(0)
    assert restored >= 1
    t1 = cl.main_thread(0); t1.server = 1
    # flushed epoch survives; the unflushed write is lost (epoch semantics)
    val = cl.backend.read(t1, b1)
    assert val == b"committed"
    assert cl.backend.read(t1, b2) == b"other"


def test_writeback_batched_until_transfer():
    cl = Cluster(2, backend="drust", replicate=True)
    t0 = cl.main_thread(0)
    b = cl.backend.alloc(t0, 64, 0)
    flushes0 = cl.replicator.flushes
    for i in range(5):
        cl.backend.write(t0, b, i)      # writes batch, no flush yet
    assert cl.replicator.flushes == flushes0
    cl.drust.transfer(t0, b, 1)         # visibility point -> flush
    assert cl.replicator.flushes == flushes0 + 1


def test_mem_pressure_evicts_incrementally_to_watermark():
    """mem>90% policy reclaims only the excess above the high-water mark
    (CLOCK partial eviction), not every unpinned copy (the old full sweep)."""
    cap = 1 << 20
    cl = Cluster(2, backend="drust", partition_bytes=cap)
    t0 = cl.main_thread(0)
    boxes = [cl.backend.alloc(t0, 60_000, b"x" * 60_000, server=1)
             for _ in range(16)]
    for b in boxes:                       # cache a copy of each on server 0
        cl.backend.read(t0, b)
    assert cl.controller.mem_frac(0) > cl.controller.MEM_HI
    n_before = len(cl.drust.caches[0].entries)
    assert n_before == 16
    cl.controller.balance(horizon_us=1e6)
    # back under the watermark ...
    assert cl.controller.mem_frac(0) <= cl.controller.MEM_HI + 1e-9
    # ... but warm copies below the mark survived (incremental, not a sweep)
    n_after = len(cl.drust.caches[0].entries)
    assert 0 < n_after < n_before
    assert n_after >= n_before - 2        # only the excess was reclaimed
