"""Application-level tests: every app runs on every backend, GEMM's result
is numerically checked against the single-shot oracle (inside run_gemm),
and the paper's qualitative ordering holds at 8 nodes."""

import pytest

from repro.apps import APPS
from repro.apps.dataframe import run_dataframe
from repro.apps.gemm import run_gemm
from repro.apps.kvstore import run_kvstore
from repro.apps.socialnet import run_socialnet

SMALL = {
    "gemm": dict(n=256, tile=64),
    "dataframe": dict(n_columns=4, chunks_per_column=8, n_ops=2),
    "kvstore": dict(n_keys=64, n_ops=200),
    "socialnet": dict(n_requests=40),
}


@pytest.mark.parametrize("app", list(APPS))
@pytest.mark.parametrize("backend", ["drust", "gam", "grappa"])
@pytest.mark.parametrize("n", [1, 2])
def test_app_runs(app, backend, n):
    r = APPS[app](n, backend=backend, **SMALL[app])
    assert r.makespan_us > 0
    assert r.ops > 0


def test_gemm_numerics_all_backends():
    for backend in ["drust", "gam", "grappa"]:
        run_gemm(2, backend=backend, n=128, tile=64, check=True)


def test_drust_beats_baselines_at_scale():
    """Fig. 5 ordering: DRust fastest on every app at 8 nodes."""
    for app, fn in APPS.items():
        spans = {b: fn(8, backend=b, **SMALL[app]).makespan_us
                 for b in ["drust", "gam", "grappa"]}
        assert spans["drust"] < spans["gam"], f"{app}: drust !< gam"
        assert spans["drust"] < spans["grappa"], f"{app}: drust !< grappa"


def test_affinity_annotations_help():
    base = run_dataframe(8, "drust").makespan_us
    both = run_dataframe(8, "drust", use_tbox=True,
                         use_spawn_to=True).makespan_us
    assert both < base                  # Fig. 6: +TBox+spawn_to speeds up


def test_single_node_overhead_small():
    """DRust adds <= ~5% over the plain program on one node (paper: 2.42%)."""
    from repro.apps.gemm import plain_gemm_us
    r = run_gemm(1, backend="drust", n=512, tile=128)
    plain = plain_gemm_us(n=512, tile=128)
    overhead = r.makespan_us / plain - 1.0
    assert overhead < 0.05, f"single-node overhead {overhead:.1%}"


def test_kvstore_two_node_dip():
    """Fig. 5d: every DSM dips when going 1 -> 2 nodes."""
    for backend in ["drust", "gam", "grappa"]:
        one = run_kvstore(1, backend=backend, n_keys=512, n_ops=600)
        two = run_kvstore(2, backend=backend, n_keys=512, n_ops=600)
        tput1 = one.ops / one.makespan_us
        tput2 = two.ops / two.makespan_us
        assert tput2 < tput1 * 1.35, f"{backend}: no 2-node pressure visible"


def test_socialnet_reference_passing_beats_by_value():
    ref_run = run_socialnet(4, backend="drust", n_requests=60)
    val_run = run_socialnet(4, backend="drust", n_requests=60, by_value=True)
    assert ref_run.makespan_us < val_run.makespan_us


# --------------------------------------------------------------------------
#  Auto/manual coalescing equivalence goldens
# --------------------------------------------------------------------------
EQUIV_KW = {
    "socialnet": dict(n_requests=120),
    "dataframe": dict(n_columns=4, chunks_per_column=8, n_ops=4),
}
EQUIV_FNS = {"socialnet": run_socialnet, "dataframe": run_dataframe}
DIGEST_KEY = {"socialnet": "payload_digest", "dataframe": "result_digest"}


@pytest.mark.parametrize("app", ["socialnet", "dataframe"])
@pytest.mark.parametrize("n", [2, 4, 8])
def test_auto_coalescing_matches_or_beats_manual(app, n):
    """The runtime policy (zero app-level drain/fetch choreography) must
    never cost more round trips or traffic bytes than the hand-batched
    choreography, and the application results must be byte-identical."""
    auto = EQUIV_FNS[app](n, "drust", coalesce="auto", **EQUIV_KW[app])
    manual = EQUIV_FNS[app](n, "drust", coalesce="manual", **EQUIV_KW[app])
    assert auto.extra["coalesce"] == "auto"
    assert manual.extra["coalesce"] == "manual"
    assert auto.net["round_trips"] <= manual.net["round_trips"], \
        f"{app}@{n}: auto needs more round trips than the manual choreography"
    assert auto.net["bytes_moved"] <= manual.net["bytes_moved"]
    assert auto.extra[DIGEST_KEY[app]] == manual.extra[DIGEST_KEY[app]], \
        f"{app}@{n}: coalescing changed the application result"
    if n == 8:        # acceptance: match-or-beat the hand-batched makespan
        assert auto.makespan_us <= manual.makespan_us


def test_auto_coalescing_acceptance_at_8_servers():
    """ISSUE acceptance: socialnet at 8 servers — the auto policy matches
    or beats the hand-batched plane on round trips AND makespan."""
    auto = run_socialnet(8, "drust", n_requests=120, coalesce="auto")
    manual = run_socialnet(8, "drust", n_requests=120, coalesce="manual")
    assert auto.net["round_trips"] <= manual.net["round_trips"]
    assert auto.makespan_us <= manual.makespan_us


def test_auto_falls_back_to_manual_outside_drust_batched():
    for r in (run_socialnet(2, "gam", n_requests=40, coalesce="auto"),
              run_socialnet(2, "drust", n_requests=40, coalesce="auto",
                            batch_io=False),
              run_socialnet(2, "drust", n_requests=40, coalesce="auto",
                            by_value=True)):
        assert r.extra["coalesce"] == "manual"


def test_socialnet_drain_order_deterministic():
    """Regression (golden counters must not depend on dict iteration): the
    manual recv sub-phase drains classes in sorted (k, src server) order
    whatever order the class map was built in."""
    from repro.apps.socialnet import drain_order
    scrambled = {(3, 1): [3], (0, 2): [0], (2, 0): [2], (1, 2): [1],
                 (2, 3): [99]}
    assert drain_order(scrambled) == [(0, 2), (1, 2), (2, 0), (2, 3), (3, 1)]
    # and the full manual trace is replay-identical across server counts
    for n in (2, 4, 8):
        a = run_socialnet(n, "drust", n_requests=48, coalesce="manual")
        b = run_socialnet(n, "drust", n_requests=48, coalesce="manual")
        assert a.net == b.net
        assert a.makespan_us == b.makespan_us


# --------------------------------------------------------------------------
#  GEMM / KV-store direct app-level coverage (incl. prefetch-driven modes)
# --------------------------------------------------------------------------
def test_gemm_prefetch_mode_hides_round_trips():
    """Speculative tile prefetch: numerics unchanged (checked in-run vs the
    A@B oracle), strictly fewer synchronous round trips, every speculative
    fetch consumed by a deferred fence, none wasted (tiles are immutable)."""
    base = run_gemm(4, "drust", n=256, tile=64)
    pre = run_gemm(4, "drust", n=256, tile=64, prefetch=True)
    assert pre.net["speculative_fetches"] > 0
    assert pre.net["late_fences"] == pre.net["speculative_fetches"]
    assert pre.net["wasted_prefetches"] == 0
    assert pre.net["round_trips"] < base.net["round_trips"]
    assert pre.makespan_us < base.makespan_us


def test_gemm_prefetch_noop_on_baselines():
    r = run_gemm(2, "gam", n=128, tile=64, prefetch=True)
    assert r.net["speculative_fetches"] == 0
    assert r.makespan_us > 0


def test_kvstore_prefetch_window_overlaps_fetches():
    """Lookahead value prefetch under the zipf mix: most speculative
    fetches materialize with a late fence, and the 10% SET traffic racing
    the window wastes some — the staleness machinery is exercised, and the
    workload still gets faster."""
    base = run_kvstore(4, "drust", n_keys=256, n_ops=600)
    pre = run_kvstore(4, "drust", n_keys=256, n_ops=600, prefetch_window=8)
    assert pre.net["speculative_fetches"] > 0
    assert pre.net["late_fences"] > 0
    assert pre.net["wasted_prefetches"] > 0
    assert (pre.net["late_fences"] + pre.net["wasted_prefetches"]
            == pre.net["speculative_fetches"])
    assert pre.makespan_us < base.makespan_us


def test_kvstore_prefetch_scales_with_servers():
    for n in (2, 8):
        r = run_kvstore(n, "drust", n_keys=256, n_ops=400, prefetch_window=4)
        assert r.ops == 400
        assert r.makespan_us > 0
