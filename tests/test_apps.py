"""Application-level tests: every app runs on every backend, GEMM's result
is numerically checked against the single-shot oracle (inside run_gemm),
and the paper's qualitative ordering holds at 8 nodes."""

import pytest

from repro.apps import APPS
from repro.apps.dataframe import run_dataframe
from repro.apps.gemm import run_gemm
from repro.apps.kvstore import run_kvstore
from repro.apps.socialnet import run_socialnet

SMALL = {
    "gemm": dict(n=256, tile=64),
    "dataframe": dict(n_columns=4, chunks_per_column=8, n_ops=2),
    "kvstore": dict(n_keys=64, n_ops=200),
    "socialnet": dict(n_requests=40),
}


@pytest.mark.parametrize("app", list(APPS))
@pytest.mark.parametrize("backend", ["drust", "gam", "grappa"])
@pytest.mark.parametrize("n", [1, 2])
def test_app_runs(app, backend, n):
    r = APPS[app](n, backend=backend, **SMALL[app])
    assert r.makespan_us > 0
    assert r.ops > 0


def test_gemm_numerics_all_backends():
    for backend in ["drust", "gam", "grappa"]:
        run_gemm(2, backend=backend, n=128, tile=64, check=True)


def test_drust_beats_baselines_at_scale():
    """Fig. 5 ordering: DRust fastest on every app at 8 nodes."""
    for app, fn in APPS.items():
        spans = {b: fn(8, backend=b, **SMALL[app]).makespan_us
                 for b in ["drust", "gam", "grappa"]}
        assert spans["drust"] < spans["gam"], f"{app}: drust !< gam"
        assert spans["drust"] < spans["grappa"], f"{app}: drust !< grappa"


def test_affinity_annotations_help():
    base = run_dataframe(8, "drust").makespan_us
    both = run_dataframe(8, "drust", use_tbox=True,
                         use_spawn_to=True).makespan_us
    assert both < base                  # Fig. 6: +TBox+spawn_to speeds up


def test_single_node_overhead_small():
    """DRust adds <= ~5% over the plain program on one node (paper: 2.42%)."""
    from repro.apps.gemm import plain_gemm_us
    r = run_gemm(1, backend="drust", n=512, tile=128)
    plain = plain_gemm_us(n=512, tile=128)
    overhead = r.makespan_us / plain - 1.0
    assert overhead < 0.05, f"single-node overhead {overhead:.1%}"


def test_kvstore_two_node_dip():
    """Fig. 5d: every DSM dips when going 1 -> 2 nodes."""
    for backend in ["drust", "gam", "grappa"]:
        one = run_kvstore(1, backend=backend, n_keys=512, n_ops=600)
        two = run_kvstore(2, backend=backend, n_keys=512, n_ops=600)
        tput1 = one.ops / one.makespan_us
        tput2 = two.ops / two.makespan_us
        assert tput2 < tput1 * 1.35, f"{backend}: no 2-node pressure visible"


def test_socialnet_reference_passing_beats_by_value():
    ref_run = run_socialnet(4, backend="drust", n_requests=60)
    val_run = run_socialnet(4, backend="drust", n_requests=60, by_value=True)
    assert ref_run.makespan_us < val_run.makespan_us
