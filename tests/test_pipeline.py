"""Pipeline-parallel tests: degenerate single-stage path in-process, real
2-stage pipeline in a 2-device subprocess."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.pipeline import pipeline_apply
from repro.launch.mesh import make_mesh


def test_single_stage_degenerate():
    mesh = make_mesh((1,), ("pod",))
    w = jnp.full((1, 4, 4), 2.0)          # one stage: y = x @ 2I-ish
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 3, 4)),
                    jnp.float32)
    y = pipeline_apply(lambda p, xb: xb @ p, mesh, w, x, n_microbatches=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w[0]),
                               rtol=1e-5)


def test_two_stage_pipeline_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.pipeline import pipeline_apply
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2,), ("pod",))
        rng = np.random.default_rng(0)
        W = jnp.asarray(rng.standard_normal((2, 4, 4)) * 0.5, jnp.float32)
        x = jnp.asarray(rng.standard_normal((8, 3, 4)), jnp.float32)
        stage = lambda p, xb: jnp.tanh(xb @ p)
        y = pipeline_apply(stage, mesh, W, x, n_microbatches=4)
        expected = jnp.tanh(jnp.tanh(x @ W[0]) @ W[1])
        np.testing.assert_allclose(np.asarray(y), np.asarray(expected),
                                   rtol=1e-4, atol=1e-5)
        print("PIPELINE_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
    assert "PIPELINE_OK" in out.stdout
