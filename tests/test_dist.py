"""Direct unit tests for the repro.dist subsystem: sharding rules under
odd mesh sizes, int8 compression error bounds, and the pipeline schedule
(bubble accounting + microbatch semantics)."""

import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import compression, pipeline, sharding
from repro.dist.sharding import (_fit, activation_spec, batch_specs,
                                 cache_specs, opt_state_specs, param_specs,
                                 set_mesh, set_rule_flags, ulysses_heads)
from repro.launch.mesh import make_mesh
from repro.models import init_cache, init_params

KEY = jax.random.PRNGKey(0)


def fake_mesh(**shape):
    return types.SimpleNamespace(shape=shape)


def teardown_function(_fn=None):
    set_mesh(None)
    set_rule_flags(ulysses=False, dp_only=False, serve_weights=False)


def _check_divisible(mesh, abstract, specs):
    flat_p = jax.tree.leaves(abstract)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is None:
                continue
            n = 1
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                n *= mesh.shape[a]
            assert dim % n == 0, f"{leaf.shape} vs {spec}"


# ---------------------------------------------------------------- sharding
@pytest.mark.parametrize("shape", [dict(data=3, model=5),
                                   dict(data=7, model=2),
                                   dict(pod=3, data=2, model=9),
                                   dict(data=1, model=1)])
@pytest.mark.parametrize("arch", ["qwen3_0_6b", "qwen3_moe_235b", "rwkv6_3b",
                                  "recurrentgemma_9b"])
def test_param_specs_fit_odd_meshes(shape, arch):
    """Every rule degrades to a dividing (or replicated) spec on meshes
    whose sizes share no factor with the tensor dims."""
    m = fake_mesh(**shape)
    cfg = configs.smoke(arch)
    abstract = jax.eval_shape(lambda k: init_params(cfg, k), KEY)
    _check_divisible(m, abstract, param_specs(m, abstract))


def test_fit_handles_absent_axes_and_long_specs():
    m = fake_mesh(data=4)
    # unknown axis drops; spec longer than rank truncates
    assert _fit(m, P("model", "data"), (8,)) == P(None)
    assert _fit(m, P(("data", "model")), (8,)) == P(("data",))
    assert _fit(m, P("data"), (8, 8)) == P("data", None)


def test_opt_state_specs_tie_moments_to_params():
    from repro.train.optimizer import OptConfig, init_opt_state
    m = fake_mesh(data=2, model=4)
    cfg = configs.smoke("qwen3_0_6b")
    params = jax.eval_shape(lambda k: init_params(cfg, k), KEY)
    for name in ("adamw", "adafactor"):
        opt = jax.eval_shape(
            lambda p: init_opt_state(OptConfig(name=name), p), params)
        specs = opt_state_specs(m, opt, params)
        assert specs["count"] == P()
        _check_divisible(m, opt[[k for k in opt if k != "count"][0]],
                         specs[[k for k in specs if k != "count"][0]])
        if name == "adafactor":
            # collapsed factored dims (size 1) must never stay sharded
            for leaf, spec in zip(
                    jax.tree.leaves(opt["vr"]),
                    jax.tree.leaves(specs["vr"],
                                    is_leaf=lambda x: isinstance(x, P))):
                for dim, axes in zip(leaf.shape, tuple(spec)):
                    assert not (dim == 1 and axes is not None)


def test_cache_specs_shard_sequence_over_model():
    m = fake_mesh(data=2, model=4)
    cfg = configs.smoke("qwen3_0_6b")
    cache = jax.eval_shape(lambda: init_cache(cfg, 4, 128))
    specs = cache_specs(m, cache)
    _check_divisible(m, cache, specs)
    k_spec = jax.tree.leaves(
        cache_specs(m, {"k": jax.ShapeDtypeStruct((4, 128, 4, 32),
                                                  jnp.bfloat16)}),
        is_leaf=lambda x: isinstance(x, P))[0]
    assert k_spec[1] == "model" and k_spec[0] in (("data",), "data")


def test_cache_specs_dp_only_never_duplicates_axes():
    """Under dp_only the batch spreads over every axis — the sequence dim
    must not reuse `model` (NamedSharding rejects duplicate axes)."""
    mesh = make_mesh((1, 1), ("data", "model"))
    set_rule_flags(dp_only=True)
    spec = jax.tree.leaves(
        cache_specs(mesh, {"k": jax.ShapeDtypeStruct((4, 128, 4, 32),
                                                     jnp.bfloat16)}),
        is_leaf=lambda x: isinstance(x, P))[0]
    jax.sharding.NamedSharding(mesh, spec)        # raises on duplicates
    set_rule_flags(dp_only=False)


def test_serve_weights_flag_drops_fsdp_axes():
    m = fake_mesh(data=8, model=4)
    cfg = configs.smoke("gemma_7b")
    abstract = jax.eval_shape(lambda k: init_params(cfg, k), KEY)
    set_rule_flags(serve_weights=True)
    specs = param_specs(m, abstract)
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        for axes in tuple(spec):
            axes = axes if isinstance(axes, tuple) else (axes,)
            assert "data" not in axes and "pod" not in axes
    set_rule_flags(serve_weights=False)


def test_ulysses_flag_shards_sequence_in_batch_specs():
    m = fake_mesh(data=2, model=4)
    b = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    base = jax.tree.leaves(batch_specs(m, b),
                           is_leaf=lambda x: isinstance(x, P))[0]
    assert base[1] is None
    set_rule_flags(ulysses=True)
    uly = jax.tree.leaves(batch_specs(m, b),
                          is_leaf=lambda x: isinstance(x, P))[0]
    assert uly[1] == "model"
    set_rule_flags(ulysses=False)


def test_activation_spec_odd_dims_replicate():
    m = fake_mesh(data=3, model=5)
    assert activation_spec(m, (9, 25, 7)) == P(("data",), "model", None)
    assert activation_spec(m, (8, 24, 7)) == P(None, None, None)
    set_rule_flags(dp_only=True)
    assert activation_spec(m, (15, 25, 7)) == P(("data", "model"), None, None)
    set_rule_flags(dp_only=False)


def test_ulysses_heads_identity_off_mesh():
    x = jnp.ones((2, 8, 4, 16))
    np.testing.assert_array_equal(np.asarray(ulysses_heads(x)),
                                  np.asarray(x))


def test_set_rule_flags_rejects_unknown():
    with pytest.raises(ValueError):
        set_rule_flags(zeRO=True)


# -------------------------------------------------------------- compression
def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    for scale_exp in (-3, 0, 4):
        x = jnp.asarray(rng.standard_normal(4096) * 10.0 ** scale_exp,
                        jnp.float32)
        q, s = compression.quantize_int8(x)
        assert q.dtype == jnp.int8
        err = np.abs(np.asarray(x) - np.asarray(
            compression.dequantize_int8(q, s)))
        assert err.max() <= float(s) / 2 + 1e-12 * 10.0 ** scale_exp


def test_int8_axiswise_tightens_error():
    rng = np.random.default_rng(1)
    # one huge row blows up the global scale; per-row scales stay tight
    x = np.asarray(rng.standard_normal((8, 512)), np.float32)
    x[0] *= 1000.0
    xg = jnp.asarray(x)
    qg, sg = compression.quantize_int8(xg)
    qa, sa = compression.quantize_int8(xg, axis=1)
    err_g = np.abs(x[1:] - np.asarray(compression.dequantize_int8(qg, sg))[1:])
    err_a = np.abs(x[1:] - np.asarray(compression.dequantize_int8(qa, sa))[1:])
    assert err_a.max() < err_g.max() / 10


def test_int8_zero_tensor_safe():
    q, s = compression.quantize_int8(jnp.zeros(16))
    np.testing.assert_array_equal(
        np.asarray(compression.dequantize_int8(q, s)), np.zeros(16))


def test_tree_quantize_roundtrip_and_wire_bytes():
    tree = {"w": jnp.asarray(np.random.default_rng(2)
                             .standard_normal((64, 32)), jnp.float32),
            "norm": jnp.ones(4, jnp.float32),
            "step": jnp.zeros((), jnp.int32)}
    packed = compression.quantize_tree(tree, min_size=64)
    assert isinstance(packed["w"], dict)          # large leaf quantized
    assert isinstance(packed["norm"], jnp.ndarray)  # small leaf exact
    out = compression.dequantize_tree(packed)
    assert out["w"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(tree["w"]),
                               atol=float(packed["w"]["scale"]))
    assert compression.wire_bytes(packed) < compression.wire_bytes(tree) / 3


# ----------------------------------------------------------------- pipeline
def test_bubble_accounting():
    assert pipeline.schedule_steps(1, 4) == 4
    assert pipeline.schedule_steps(4, 8) == 11
    assert pipeline.bubble_stage_steps(1, 4) == 0
    assert pipeline.bubble_stage_steps(4, 8) == 4 * 3
    assert pipeline.bubble_fraction(1, 16) == 0.0
    np.testing.assert_allclose(pipeline.bubble_fraction(4, 8), 3 / 11)
    # more microbatches shrink the bubble monotonically
    fracs = [pipeline.bubble_fraction(4, m) for m in (1, 2, 4, 16, 64)]
    assert all(a > b for a, b in zip(fracs, fracs[1:]))


def test_pipeline_apply_validates_microbatching():
    mesh = make_mesh((1,), ("pod",))
    w = jnp.ones((1, 4, 4))
    x = jnp.ones((6, 4))
    with pytest.raises(ValueError):
        pipeline.pipeline_apply(lambda p, xb: xb @ p, mesh, w, x,
                                n_microbatches=4)   # 6 % 4 != 0
    with pytest.raises(ValueError):
        pipeline.pipeline_apply(lambda p, xb: xb @ p, mesh,
                                jnp.ones((3, 4, 4)), x)  # no axis of size 3


def test_pipeline_single_stage_microbatch_counts_agree():
    mesh = make_mesh((1,), ("pod",))
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((1, 4, 4)) * 0.5, jnp.float32)
    x = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    outs = [pipeline.pipeline_apply(lambda p, xb: jnp.tanh(xb @ p), mesh, w,
                                    x, n_microbatches=m) for m in (1, 2, 8)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-6)
