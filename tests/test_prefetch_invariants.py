"""Staleness-safety property suite for speculative prefetch + the deref
coalescer.

Random schedules interleave speculative prefetch, reads (materialization),
owner writes, ownership transfer, and drop over a small box population;
after every operation the invariants below must hold:

  * Staleness-Safety: a deref NEVER observes pre-transfer / pre-write
    bytes — every read returns the payload version current at
    materialization time.  (The oracle versions every write; a speculative
    copy fetched before a mutation must be invalidated, never served.)
  * Exactly-Once Disposition: every speculative completion id is *fenced*
    (materialized at first use, counted in ``late_fences``) or
    *invalidated* (killed before use, counted in ``wasted_prefetches``)
    exactly once — ``DrustRuntime.spec_log`` is checked against the posted
    cid ledger after the schedule drains.
  * Counter Consistency: ``speculative_fetches`` equals the posted cids,
    and the fenced/invalidated split equals the disposition log.
  * Materialized entries are no longer speculative, and the completion
    plane fully drains at the end (no leaked pending verbs).

Each property runs twice: hypothesis-generated (200 schedules, derandomized
under the CI profile — see ``_hypcompat``) and a seeded deterministic twin
that executes on machines without hypothesis.

The suite also pins the coalescer's conflict discipline: a mutable op on a
box with registered (unflushed) derefs closes those quanta instead of
raising ``BorrowError``, and a registered deref returns exactly the bytes
the flush later materializes (the borrow freezes the payload).
"""

from __future__ import annotations

import random

from _hypcompat import given, settings, st

from repro.core import BorrowError, Cluster, CoalescePolicy

N_SERVERS = 4
N_THREADS = 4
N_BOXES = 3

KINDS = ["prefetch", "prefetch", "read", "read", "owner_read", "write",
         "transfer", "drop"]


def make(qps: int = 1, ooo: bool = False):
    cl = Cluster(N_SERVERS, backend="drust", qps_per_thread=qps, ooo=ooo)
    ths = []
    for i in range(N_THREADS):
        th = cl.main_thread(0)
        th.server = i % N_SERVERS
        ths.append(th)
    return cl, ths


def run_spec_schedule(ops, qps: int = 1, ooo: bool = False,
                      tied: bool = False) -> None:
    """Execute a prefetch/transfer/drop schedule, checking the staleness
    and disposition invariants after every op.  With ``tied=True`` box 1
    is a TBox child of box 0, so group prefetches cover two owners and a
    drop of the parent cascades."""
    cl, ths = make(qps, ooo)
    rt = cl.drust
    version = [0] * N_BOXES
    boxes = [cl.backend.alloc(ths[0], 256, ("v", 0, 0))]
    boxes.append(cl.backend.alloc(ths[1 % N_THREADS], 256, ("v", 1, 0),
                                  tie_to=boxes[0] if tied else None))
    boxes += [cl.backend.alloc(ths[i % N_THREADS], 256, ("v", i, 0))
              for i in range(2, N_BOXES)]
    for kind, t, o, p in ops:
        th, i = ths[t % N_THREADS], o % N_BOXES
        box = boxes[i]
        if box.dropped:                          # incl. cascaded TBox drops
            continue
        if kind == "prefetch":
            rt.prefetch(th, [box])
        elif kind == "read":
            val = cl.backend.read(th, box)
            assert val == ("v", i, version[i]), \
                f"stale deref: saw {val}, current is version {version[i]}"
            e = rt.caches[th.server].entries.get(box.g)
            if e is not None:
                assert not e.speculative, "materialized entry still marked"
        elif kind == "owner_read":
            val = rt.owner_read(th, box)
            assert val == ("v", i, version[i]), \
                f"stale owner read: saw {val}, current {version[i]}"
        elif kind == "write":
            version[i] += 1
            cl.backend.write(th, box, ("v", i, version[i]))
        elif kind == "transfer":
            rt.transfer(th, box, p % N_SERVERS)
        elif kind == "drop":
            rt.drop_box(th, box)
        for how in rt.spec_log.values():
            assert how in ("fenced", "invalidated")
    for i in range(N_BOXES):
        if not boxes[i].dropped:
            rt.drop_box(ths[0], boxes[i])
    # Exactly-once disposition over the whole schedule.
    assert len(rt.spec_cids) == len(set(rt.spec_cids))
    assert set(rt.spec_cids) == set(rt.spec_log), \
        "a speculative cid was neither fenced nor invalidated"
    net = cl.sim.net
    fenced = sum(1 for v in rt.spec_log.values() if v == "fenced")
    wasted = sum(1 for v in rt.spec_log.values() if v == "invalidated")
    assert net.late_fences == fenced
    assert net.wasted_prefetches == wasted
    assert net.speculative_fetches == len(rt.spec_cids)
    cl.sim.wb.fence_all(ths[0])
    assert not cl.sim.wb._pending, "completion plane leaked pending verbs"


spec_ops = st.lists(
    st.tuples(st.sampled_from(KINDS),
              st.integers(0, N_THREADS - 1),
              st.integers(0, N_BOXES - 1),
              st.integers(0, N_SERVERS - 1)),
    min_size=1, max_size=40)


@settings(max_examples=200, deadline=None)
@given(spec_ops, st.sampled_from([1, 2, 4]), st.booleans(), st.booleans())
def test_prefetch_staleness_safety_property(ops, qps, ooo, tied):
    run_spec_schedule(ops, qps, ooo, tied)


def test_prefetch_staleness_safety_200_seeded_schedules():
    """Deterministic twin of the hypothesis suite: 200 seeded random
    schedules (half with a TBox-tied pair), so the property is exercised
    even without hypothesis."""
    rng = random.Random(3)
    for _ in range(200):
        qps = rng.choice([1, 2, 4])
        ooo = rng.random() < 0.5
        tied = rng.random() < 0.5
        ops = [(rng.choice(KINDS), rng.randrange(N_THREADS),
                rng.randrange(N_BOXES), rng.randrange(N_SERVERS))
               for _ in range(rng.randint(1, 40))]
        run_spec_schedule(ops, qps, ooo, tied)


# --------------------------------------------------------------------------
#  Directed prefetch mechanics
# --------------------------------------------------------------------------
def test_prefetch_fences_lazily_at_first_use():
    cl, ths = make()
    t1 = ths[1]
    box = cl.backend.alloc(ths[0], 512, b"x" * 512)
    assert cl.drust.prefetch(t1, [box]) == 1
    net = cl.sim.net
    assert net.speculative_fetches == 1
    assert net.late_fences == 0                  # not fenced at post time
    assert box.fetch_cid in cl.sim.wb._pending   # verb in flight
    assert cl.backend.read(t1, box) == b"x" * 512
    assert net.late_fences == 1                  # fence deferred to use
    assert net.wasted_prefetches == 0
    assert box.fetch_cid == 0
    cl.backend.read(t1, box)                     # warm: no second fence
    assert net.late_fences == 1


def test_prefetch_skips_local_cached_and_inflight():
    cl, ths = make()
    t1 = ths[1]
    local = cl.backend.alloc(t1, 64, 1, server=t1.server)
    warm = cl.backend.alloc(ths[0], 64, 2)
    cl.backend.read(t1, warm)                    # now cached on t1's server
    cold = cl.backend.alloc(ths[0], 64, 3)
    assert cl.drust.prefetch(t1, [local, warm, cold]) == 1
    assert cl.drust.prefetch(t1, [cold]) == 0    # already in flight
    cl.backend.read(t1, cold)


def test_transfer_invalidates_unused_prefetch_and_fences_cid():
    cl, ths = make()
    t1 = ths[1]
    box = cl.backend.alloc(ths[0], 512, b"y" * 512)
    cl.drust.prefetch(t1, [box])
    cid = box.fetch_cid
    done = cl.sim.wb._pending[cid].done_us
    cl.drust.transfer(ths[0], box, 2)
    assert cid not in cl.sim.wb._pending         # fenced like a write-back
    assert ths[0].t_us >= done - 1e-9            # transfer waited for the READ
    assert cl.sim.net.wasted_prefetches == 1
    assert cl.drust.spec_log[cid] == "invalidated"
    assert box.g not in cl.drust.caches[t1.server].entries


def test_owner_mutation_wastes_unused_prefetch():
    cl, ths = make()
    t1 = ths[1]
    box = cl.backend.alloc(ths[0], 512, ("v", 0))
    cl.drust.prefetch(t1, [box])
    cl.backend.write(ths[0], box, ("v", 1))      # mutate before first use
    assert cl.sim.net.wasted_prefetches == 1
    assert cl.backend.read(t1, box) == ("v", 1)  # fresh fetch, not the stale copy
    assert cl.sim.net.late_fences == 0


def test_tbox_group_prefetch_one_doorbell():
    """A TBox chain prefetches as ONE doorbell (n_verbs = group size); any
    member's first use runs the single deferred fence for the whole cid."""
    cl, ths = make()
    t1 = ths[1]
    head = cl.backend.alloc(ths[0], 128, b"h")
    mid = cl.backend.alloc(ths[0], 128, b"m", tie_to=head)
    tail = cl.backend.alloc(ths[0], 128, b"t", tie_to=mid)
    assert cl.drust.prefetch(t1, [head]) == 1
    net = cl.sim.net
    assert net.speculative_fetches == 1          # one doorbell for the group
    assert cl.backend.read(t1, mid) == b"m"      # child use fences the cid
    assert net.late_fences == 1
    assert cl.backend.read(t1, head) == b"h"     # sibling: no second fence
    assert net.late_fences == 1
    assert net.wasted_prefetches == 0


def test_tied_child_mutation_wastes_whole_group_prefetch():
    """Regression: a group prefetch records its cid on EVERY fetched
    member — mutating a tied child (even with U set, i.e. no color bump)
    before first use must kill the whole doorbell's entries, or a remote
    reader would observe the pre-write child bytes."""
    cl, ths = make()
    t1 = ths[1]
    parent = cl.backend.alloc(ths[0], 128, b"p")
    child = cl.backend.alloc(ths[0], 128, b"v1", tie_to=parent)
    cl.backend.write(ths[0], child, b"v1")       # sets child's U bit
    cl.drust.prefetch(t1, [parent])              # snapshots p + v1
    assert child.fetch_cid == parent.fetch_cid != 0
    cl.backend.write(ths[0], child, b"v2")       # U set: no color bump
    assert cl.sim.net.wasted_prefetches == 1
    assert cl.backend.read(t1, child) == b"v2", "stale tied-child bytes"


def test_sibling_materialization_waits_for_read_completion():
    """Regression: the deferred fence is once-per-cid for *counting*, but
    every thread materializing an entry of the doorbell must still wait
    for the READ's completion time (retired cids keep theirs)."""
    cl, ths = make()
    t1, t2 = ths[1], ths[2]
    t2.server = t1.server                        # share the prefetched cache
    head = cl.backend.alloc(ths[0], 4096, b"h" * 4096)
    tail = cl.backend.alloc(ths[0], 4096, b"t" * 4096, tie_to=head)
    cl.drust.prefetch(t1, [head])
    done = cl.sim.wb._pending[head.fetch_cid].done_us
    cl.backend.read(t1, tail)                    # first use: fences the cid
    assert t1.t_us >= done - 1e-9
    assert t2.t_us < done                        # t2 hasn't waited yet
    cl.backend.read(t2, head)                    # sibling entry, same cid
    assert t2.t_us >= done - 1e-9, \
        "sibling materialization consumed bytes before the READ completed"
    assert cl.sim.net.late_fences == 1           # counter stays once-per-cid


def test_eviction_does_not_permanently_disable_prefetch():
    """Regression: a speculative entry dying through eviction (refcount 0)
    records its disposition via ``on_spec_drop``, which cannot reach the
    box handle — the stale ``fetch_cid`` must clear lazily so the box can
    be prefetched again."""
    cl, ths = make()
    t1 = ths[1]
    box = cl.backend.alloc(ths[0], 512, b"e" * 512)
    cl.drust.prefetch(t1, [box])
    cid = box.fetch_cid
    cl.drust.evict_caches(t1.server)             # memory pressure sweep
    assert cl.drust.spec_log[cid] == "invalidated"
    assert cl.sim.net.wasted_prefetches == 1
    assert cl.drust.prefetch(t1, [box]) == 1, "dead cid blocked re-prefetch"
    assert cl.backend.read(t1, box) == b"e" * 512


def test_registered_deref_returns_snapshot_not_alias():
    """Regression: the coalescer's registered deref must hand back a
    snapshot (the manual plane's clone semantics), never an alias of the
    owner's live heap object."""
    cl = Cluster(N_SERVERS, backend="drust", coalesce="auto")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0)
    t1.server = 1
    box = cl.backend.alloc(t0, 256, [1, 2, 3])
    val = cl.backend.read(t1, box)               # registered (pending)
    import repro.core.addr as A
    assert val == [1, 2, 3]
    assert val is not cl.drust.heap.get(A.clear_color(box.g)).data
    val.append(99)                               # reader-side mutation
    cl.drust.coalescer.flush(t1)
    assert cl.backend.read(t1, box) == [1, 2, 3], \
        "reader mutation leaked into the owner's heap object"


def test_drop_box_fences_inflight_prefetch_before_free():
    cl, ths = make()
    t1 = ths[1]
    box = cl.backend.alloc(ths[0], 512, b"z" * 512)
    cl.drust.prefetch(t1, [box])
    cid = box.fetch_cid
    cl.drust.drop_box(ths[0], box)               # B.4: fence before free
    assert cid not in cl.sim.wb._pending
    assert cl.drust.spec_log[cid] == "invalidated"
    assert cl.sim.net.wasted_prefetches == 1


# --------------------------------------------------------------------------
#  Coalescer conflict discipline
# --------------------------------------------------------------------------
def test_registered_deref_flushes_on_write_conflict():
    """A mutable op on a box with registered derefs closes the quantum
    instead of tripping the borrow checker; the registered value equals
    what the flush materializes (the borrow froze the payload)."""
    cl = Cluster(N_SERVERS, backend="drust", coalesce="auto")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0)
    t1.server = 1
    box = cl.backend.alloc(t0, 256, ("v", 0))
    other = cl.backend.alloc(t0, 256, ("o", 0))
    co = cl.drust.coalescer
    val = cl.backend.read(t1, box)               # registers, returns frozen bytes
    cl.backend.read(t1, other)
    assert val == ("v", 0)
    assert co.pending and box.live_refs == 1
    cl.backend.write(t0, box, ("v", 1))          # conflict -> quantum closes
    assert not co.pending and box.live_refs == 0
    assert co.flushes == 1 and co.flushed_derefs == 2
    assert cl.backend.read(t1, box) == ("v", 1)  # post-write deref: fresh


def test_registered_deref_flushes_on_transfer_and_drop():
    cl = Cluster(N_SERVERS, backend="drust", coalesce="auto")
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0)
    t1.server = 1
    a = cl.backend.alloc(t0, 128, 1)
    b = cl.backend.alloc(t0, 128, 2)
    cl.backend.read(t1, a)
    cl.drust.transfer(t0, a, 2)                  # flushes t1's quantum
    assert a.live_refs == 0
    cl.backend.read(t1, b)
    cl.drust.drop_box(t0, b)                     # flushes, then drops
    assert b.dropped


def test_static_budget_closes_quantum():
    cl = Cluster(N_SERVERS, backend="drust", coalesce="auto",
                 coalesce_policy=CoalescePolicy(max_pending=4))
    t0 = cl.main_thread(0)
    t1 = cl.main_thread(0)
    t1.server = 3
    boxes = [cl.backend.alloc(t0, 128, i, server=i % 3) for i in range(10)]
    rt0 = cl.sim.net.round_trips                 # setup allocs paid RPCs
    for b in boxes[:3]:
        cl.backend.read(t1, b)
    assert cl.sim.net.round_trips == rt0         # still pending
    cl.backend.read(t1, boxes[3])                # 4th deref hits the budget
    assert cl.sim.net.round_trips == rt0 + 3     # one doorbell per source
    assert cl.drust.coalescer.flushes == 1


def test_manual_mode_keeps_borrow_errors():
    """Without the coalescer the borrow checker still fires — the conflict
    flush must not mask genuine violations."""
    import pytest
    cl = Cluster(2, backend="drust")             # coalesce="manual"
    t0 = cl.main_thread(0)
    box = cl.backend.alloc(t0, 64, 0)
    r = box.borrow(t0)
    with pytest.raises(BorrowError):
        box.borrow_mut(t0)
    r.drop(t0)
