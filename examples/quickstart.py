"""Quickstart: the ownership-guided DSM in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's accumulator example (Listing 1/2) on a simulated 4-server
cluster, then shows the same protocol driving a JAX training state.
"""

import jax.numpy as jnp

from repro.core import Cluster, addr as A
from repro.core.jaxstate import OwnedState, StateCache


def main():
    # --- Listing 2: the accumulator, distributed without code rewriting ----
    cl = Cluster(4, backend="drust")
    main_th = cl.main_thread(0)

    val = cl.backend.alloc(main_th, 8, 5)          # Box::new(5)
    b = cl.backend.alloc(main_th, 8, 10)           # Box::new(10)

    # local add: a.val += *b  (immutable borrow of b, mutable of val)
    delta = cl.backend.read(main_th, b)
    cl.backend.update(main_th, val, lambda v: v + delta)
    print(f"local add  -> a.val == {cl.backend.read(main_th, val)}")

    # spawn on another server: only the *pointers* ship (16 bytes)
    worker = cl.scheduler.spawn_to(b, lambda th: None, parent=main_th)
    delta = cl.backend.read(worker, b)             # local on its home
    cl.backend.update(worker, val, lambda v: v + delta)  # moves val to worker
    print(f"remote add -> a.val == {cl.backend.read(main_th, val)} "
          f"(object now lives on server {A.server_of(val.g)})")
    print(f"network: {cl.sim.net.one_sided_reads} one-sided reads, "
          f"{cl.sim.net.invalidations} invalidations "
          f"(coherence came from ownership, not messages)\n")

    # --- the same protocol as a JAX state store ----------------------------
    weights = OwnedState("weights", {"w": jnp.zeros(4)})
    replica = StateCache()
    replica.fetch(weights)                         # replica caches color 0
    replica.fetch(weights)                         # zero-communication hit
    with weights.borrow_mut() as m:                # one write epoch
        m.set({"w": jnp.ones(4)})
    replica.fetch(weights)                         # color changed: refetch
    print(f"weight cache: {replica.hits} zero-comm hits, "
          f"{replica.refreshes} refreshes, 0 invalidation messages")


if __name__ == "__main__":
    main()
