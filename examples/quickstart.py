"""Quickstart: the ownership-guided DSM in 60 lines.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's accumulator example (Listing 1/2) on a simulated 4-server
cluster, then shows the same protocol driving a JAX training state.
"""

import jax.numpy as jnp

from repro.core import Cluster, addr as A
from repro.core.jaxstate import OwnedState, StateCache


def main():
    # --- Listing 2: the accumulator, distributed without code rewriting ----
    cl = Cluster(4, backend="drust")
    main_th = cl.main_thread(0)

    val = cl.backend.alloc(main_th, 8, 5)          # Box::new(5)
    b = cl.backend.alloc(main_th, 8, 10)           # Box::new(10)

    # local add: a.val += *b — the guard scopes ARE the borrow lifetimes
    # (read guard = immutable borrow of b, write guard = mutable of val)
    with b.read(main_th) as delta:
        with val.write(main_th) as w:
            w.update(lambda v: v + delta)
    with val.read(main_th) as v:
        print(f"local add  -> a.val == {v}")

    # spawn on another server: only the *pointers* ship (16 bytes)
    worker = cl.scheduler.spawn_to(b, lambda th: None, parent=main_th)
    with b.read(worker) as delta:                  # local on its home
        with val.write(worker) as w:               # moves val to worker
            w.update(lambda v: v + delta)
    print(f"remote add -> a.val == {cl.backend.read(main_th, val)} "
          f"(object now lives on server {A.server_of(val.g)})")
    print(f"network: {cl.sim.net.one_sided_reads} one-sided reads, "
          f"{cl.sim.net.invalidations} invalidations "
          f"(coherence came from ownership, not messages)\n")

    # --- the same protocol as a JAX state store ----------------------------
    weights = OwnedState("weights", {"w": jnp.zeros(4)})
    replica = StateCache()
    replica.fetch(weights)                         # replica caches color 0
    replica.fetch(weights)                         # zero-communication hit
    weights.write({"w": jnp.ones(4)})              # one write epoch
    replica.fetch(weights)                         # color changed: refetch
    print(f"weight cache: {replica.hits} zero-comm hits, "
          f"{replica.refreshes} refreshes, 0 invalidation messages")


if __name__ == "__main__":
    main()
