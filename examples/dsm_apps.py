"""Reproduce the paper's headline comparison on your laptop:

    PYTHONPATH=src python examples/dsm_apps.py [app]

Runs the chosen application (default: all four) on 1..8 simulated servers
under all three DSM protocols and prints the Fig. 5-style table.
"""

import sys

from repro.apps import APPS
from repro.apps.dataframe import plain_dataframe_us
from repro.apps.gemm import plain_gemm_us
from repro.apps.kvstore import plain_kvstore_us
from repro.apps.socialnet import plain_socialnet_us

PLAIN = {"gemm": plain_gemm_us, "dataframe": plain_dataframe_us,
         "kvstore": plain_kvstore_us, "socialnet": plain_socialnet_us}


def main():
    apps = sys.argv[1:] or list(APPS)
    for app in apps:
        plain = PLAIN[app]()
        print(f"\n== {app} (normalized to the original single-node program)")
        print(f"   {'backend':8s} " + "".join(f"{n}n      " for n in (1, 2, 4, 8)))
        for backend in ("drust", "gam", "grappa"):
            row = []
            for n in (1, 2, 4, 8):
                r = APPS[app](n, backend=backend)
                row.append(f"{plain / r.makespan_us:5.2f}x ")
            print(f"   {backend:8s} " + " ".join(row))


if __name__ == "__main__":
    main()
