"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
with ownership-epoch checkpointing and failure recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 512]

(Defaults are sized for a laptop-class CPU run; on TPU use
``repro.launch.train --full`` with a real arch id.)
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    from repro import configs
    from repro.checkpoint import CheckpointManager
    from repro.models import init_params
    from repro.train import OptConfig, TrainState, synthetic_batches

    cfg = dataclasses.replace(
        configs.get("qwen3-0.6b"), n_layers=args.layers,
        d_model=args.d_model, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=args.d_model * 3, vocab=8192, attn_chunk=128,
        max_target_len=args.seq)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"model: {args.layers}L d={args.d_model} -> {n/1e6:.1f}M params")

    ts = TrainState(cfg, OptConfig(lr=1e-3, warmup=20,
                                   decay_steps=args.steps), params)
    ts.replicate()
    mgr = CheckpointManager("/tmp/repro_train_lm", ts.state,
                            every_n_epochs=50)
    data = synthetic_batches(cfg.vocab, args.batch, args.seq)

    t0 = time.time()
    losses = []
    for step in range(1, args.steps + 1):
        m = ts.step(jax.tree.map(jnp.asarray, next(data)))
        losses.append(float(m["loss"]))
        if step % 20 == 0:
            rate = args.batch * args.seq * step / (time.time() - t0)
            print(f"step {step:4d}  loss {losses[-1]:.4f}  "
                  f"{rate/1e3:.1f}k tok/s  color {ts.color}")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"checkpoints at colors {[c for c, _ in mgr.saved]}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
