"""Serving example: batched decode with the ownership-paged KV cache,
prefix sharing across requests, zero-invalidation online weight refresh —
then the same engine DSM-backed on a simulated 4-server cluster under
open-loop load (see docs/serving.md).

    PYTHONPATH=src python examples/serve_kv.py
"""

import jax
import numpy as np

from repro import configs
from repro.core import Cluster
from repro.core.jaxstate import OwnedState
from repro.models import init_params
from repro.serve import (OpenLoopDriver, ServeEngine, ServeFleet,
                         poisson_trace, synth_prompts)


def local_plane():
    cfg = configs.smoke("granite-34b")      # MQA: maximal KV read sharing
    weights = OwnedState("weights", init_params(cfg, jax.random.PRNGKey(0)))
    engine = ServeEngine(cfg, weights, slots=4, max_len=256)

    rng = np.random.default_rng(0)
    system_prompt = list(rng.integers(0, cfg.vocab, size=cfg.attn_chunk))
    for i in range(12):
        user = list(rng.integers(0, cfg.vocab, size=6 + i % 5))
        engine.submit(system_prompt + user, max_new=12)

    ticks = 0
    while engine.queue or engine.active:
        engine.step()
        ticks += 1
        if ticks % 10 == 0:             # online trainer pushes new weights:
            # one write epoch — the color bump IS the invalidation, no
            # messages to any replica (the guard-era spelling of the old
            # borrow_mut/deref_mut dance)
            weights.write(weights.read())

    st = engine.stats()
    print(f"decode ticks: {st['steps']}")
    print(f"kv cache: {st['kv']} — the shared system prompt is ONE page "
          f"retained by every request")
    print(f"weight refreshes {st['weight_refreshes']} vs zero-comm hits "
          f"{st['weight_hits']} (no invalidation messages, ever)\n")


def dsm_plane():
    # The same engine over the DSM runtime: pages are protocol objects
    # (appends = scoped write guards, prefix reads = batched immutable
    # borrows inside each tick's region), weights refresh in int8 over the
    # wire, and an open-loop Poisson trace supplies production-shaped load.
    cl = Cluster(4, backend="drust", ooo=True, qps_per_thread=2)
    weights = OwnedState("serve_w", {"w": np.ones((64, 64), np.float32)})

    def stub_step(params, cache, tokens):   # deterministic decode stand-in
        return (tokens * 7 + 3) % 256, cache

    fleet = ServeFleet(cl, step_fn=stub_step, page_size=8, slots=4,
                       max_len=64, weights=weights, wire="int8",
                       decode_cycles=390_000.0)    # ~150 us/tick at 2.6 GHz
    n = 48
    driver = OpenLoopDriver(fleet, poisson_trace(2500.0, n, seed=7),
                            synth_prompts(n, seed=7), max_new=8,
                            weight_push_every=8)
    driver.run()
    r = driver.result(slo_us=5000.0)
    st = fleet.stats()
    print(f"open-loop serve on 4 servers: {r.completed} requests, "
          f"p50 {r.p50_us:.0f} us, p99 {r.p99_us:.0f} us "
          f"(queueing included), goodput {r.goodput_tok_s:.0f} tok/s")
    print(f"protocol: {cl.sim.net.round_trips} round trips, "
          f"{st['wire_bytes']} int8 wire bytes over "
          f"{st['weight_refreshes']} weight refreshes, "
          f"kv hits/misses {st['kv']['hits']}/{st['kv']['misses']}")


def main():
    local_plane()
    dsm_plane()


if __name__ == "__main__":
    main()
