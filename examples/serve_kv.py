"""Serving example: batched decode with ownership-paged KV cache, prefix
sharing across requests, and zero-invalidation online weight refresh.

    PYTHONPATH=src python examples/serve_kv.py
"""

import jax
import numpy as np

from repro import configs
from repro.core.jaxstate import OwnedState
from repro.models import init_params
from repro.serve import ServeEngine


def main():
    cfg = configs.smoke("granite-34b")      # MQA: maximal KV read sharing
    weights = OwnedState("weights", init_params(cfg, jax.random.PRNGKey(0)))
    engine = ServeEngine(cfg, weights, slots=4, max_len=256)

    rng = np.random.default_rng(0)
    system_prompt = list(rng.integers(0, cfg.vocab, size=cfg.attn_chunk))
    for i in range(12):
        user = list(rng.integers(0, cfg.vocab, size=6 + i % 5))
        engine.submit(system_prompt + user, max_new=12)

    ticks = 0
    while engine.queue or engine.active:
        engine.step()
        ticks += 1
        if ticks % 10 == 0:             # online trainer pushes new weights
            with weights.borrow_mut() as m:
                m.set(m.deref_mut())

    st = engine.stats()
    print(f"decode ticks: {st['steps']}")
    print(f"kv cache: {st['kv']} — the shared system prompt is ONE page "
          f"borrowed by every request")
    print(f"weight refreshes {st['weight_refreshes']} vs zero-comm hits "
          f"{st['weight_hits']} (no invalidation messages, ever)")


if __name__ == "__main__":
    main()
